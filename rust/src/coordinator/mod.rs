//! The streaming coordinator (L3's top layer).
//!
//! Two entry points:
//!
//! * [`scenarios`] — the paper's §5 use-case: replay a recording through
//!   the four (feed × transfer) scenarios of Fig. 4 against the
//!   XLA/PJRT edge detector, measuring frames processed and HtoD copy
//!   cost;
//! * [`stream`] — the generic `input → filters → output` orchestrator
//!   behind the CLI's free composition (Fig. 2B).

pub mod scenarios;
pub mod stream;

pub use scenarios::{
    run_scenario, run_scenario_fused, run_scenario_source, FeedMode, ScenarioConfig,
    ScenarioReport, SessionSink,
};
pub use stream::{
    lower_to_graph, run_graph, run_stream, run_stream_with, run_topology, AdaptiveConfig,
    AdaptiveReport, BranchSpec, ControllerKind, FusionLayout, Input, ReportTarget, RoutePolicy,
    Sink, Source, StreamConfig, StreamDriver, StreamReport, TopologyOptions,
};
