//! The four Fig. 4 scenarios: {threads, coroutines} × {dense, sparse}.
//!
//! Faithful to §5 of the paper:
//!
//! 1. **threads + dense** — a producer thread paces events per their
//!    timestamps, fills fixed-size buffers, and *under a lock* bins each
//!    full buffer onto a shared CPU frame tensor; the consumer loop
//!    swaps the tensor out under the same lock and ships the full dense
//!    frame to the device.
//! 2. **coroutines + dense** — producer/consumer coroutines on one
//!    cooperative executor share the accumulation frame with no lock;
//!    still ships dense frames.
//! 3. **threads + sparse** — as (1), but the shared structure is the raw
//!    event list; the device's Pallas scatter kernel builds the frame.
//! 4. **coroutines + sparse** — as (2) with the event list; this is the
//!    full AEStream configuration.
//!
//! "We are *not* limiting the number of tensors the GPU can process per
//! second" — the consumer free-runs, grabbing whatever accumulated; a
//! grab with zero events does not count as a frame. Frames processed
//! (Fig. 4C) and HtoD copy time (Fig. 4B) come from the session's
//! [`TransferStats`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::aer::Event;
use crate::pipeline::fusion::SourceLayout;
use crate::rt::{yield_now, LocalExecutor};
use crate::runtime::{Device, DetectorSession, TransferMode, TransferStats};
use crate::stream::{EventSource, FusedSource, SliceSource};

/// Events per [`EventSource`] batch when replaying a RAM-cached
/// recording through [`run_scenario`].
const REPLAY_CHUNK: usize = 4096;

/// How events travel from the paced producer to the device loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedMode {
    /// OS thread + mutex-guarded shared buffer, filled in fixed-size
    /// chunks (the paper's conventional baseline).
    Threaded {
        /// Events per fill chunk (paper uses fixed-size buffers).
        buffer_size: usize,
    },
    /// Cooperative coroutines on a single executor, per-event handoff,
    /// no locks.
    Coroutine,
}

impl FeedMode {
    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            FeedMode::Threaded { .. } => "threads",
            FeedMode::Coroutine => "coro",
        }
    }
}

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Feed mechanism (threads vs coroutines).
    pub feed: FeedMode,
    /// Transfer strategy (dense vs sparse).
    pub transfer: TransferMode,
    /// Replay speed: 1.0 = respect timestamps in real time; larger is
    /// faster (benches use >1 to keep wall time short); `f64::INFINITY`
    /// floods without pacing.
    pub time_scale: f64,
    /// Read edge maps back each frame (off reproduces the paper's
    /// free-running loop most closely).
    pub fetch_outputs: bool,
}

impl ScenarioConfig {
    /// The paper's four scenarios, in Fig. 4 order.
    pub fn paper_four(time_scale: f64) -> [ScenarioConfig; 4] {
        let buf = 4096;
        [
            ScenarioConfig {
                feed: FeedMode::Threaded { buffer_size: buf },
                transfer: TransferMode::Dense,
                time_scale,
                fetch_outputs: false,
            },
            ScenarioConfig {
                feed: FeedMode::Coroutine,
                transfer: TransferMode::Dense,
                time_scale,
                fetch_outputs: false,
            },
            ScenarioConfig {
                feed: FeedMode::Threaded { buffer_size: buf },
                transfer: TransferMode::Sparse,
                time_scale,
                fetch_outputs: false,
            },
            ScenarioConfig {
                feed: FeedMode::Coroutine,
                transfer: TransferMode::Sparse,
                time_scale,
                fetch_outputs: false,
            },
        ]
    }

    /// Scenario label, e.g. `"coro+sparse"`.
    pub fn label(&self) -> String {
        format!(
            "{}+{}",
            self.feed.label(),
            match self.transfer {
                TransferMode::Dense => "dense",
                TransferMode::Sparse => "sparse",
            }
        )
    }
}

/// Results of one scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Scenario label.
    pub label: String,
    /// Frames that went through the edge detector.
    pub frames: u64,
    /// Events delivered to the device path.
    pub events: u64,
    /// Events dropped for exceeding sparse capacity.
    pub dropped: u64,
    /// Total wall time.
    pub wall: Duration,
    /// Device transfer/execution statistics.
    pub stats: TransferStats,
    /// Nanoseconds the *producer* spent binning/copying into the shared
    /// structure (the CPU-side cost the sparse path avoids).
    pub host_prepare_ns: u64,
}

impl ScenarioReport {
    /// HtoD copy share of total runtime (Fig. 4B's percentage).
    pub fn htod_percent(&self) -> f64 {
        100.0 * self.stats.htod_fraction(self.wall.as_nanos() as u64)
    }

    /// Frames per second of wall time.
    pub fn fps(&self) -> f64 {
        self.frames as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// An [`EventSink`](crate::stream::EventSink) over a
/// [`DetectorSession`] — the ROADMAP's multi-device fan-out: in a
/// topology graph, each branch can terminate in its *own* device
/// session, so one merged sensor stream feeds several detectors at once
/// (see `examples/graph_topology.rs`). Sparse sessions chunk each batch
/// to the device's event capacity; dense sessions bin the batch into a
/// host frame first. Events outside the detector's fixed plane are
/// dropped and counted, never shipped.
pub struct SessionSink<'d> {
    session: DetectorSession<'d>,
    frames: u64,
    events: u64,
    dropped: u64,
}

impl<'d> SessionSink<'d> {
    /// Wrap an open session.
    pub fn new(session: DetectorSession<'d>) -> Self {
        SessionSink { session, frames: 0, events: 0, dropped: 0 }
    }

    /// Open a free-running sparse session on `device` (the full
    /// AEStream configuration) and wrap it.
    pub fn sparse(device: &'d Device) -> Result<Self> {
        Ok(Self::new(DetectorSession::with_outputs(device, TransferMode::Sparse, false)?))
    }

    /// Events that reached the device path.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Events dropped (outside the detector plane, or over sparse
    /// capacity on-device).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Recover the session (its [`TransferStats`] above all). Only
    /// reachable when the caller still owns the sink — i.e. when
    /// driving it by hand; a sink moved into a topology graph reports
    /// through its `NodeReport` instead (frames, dropped).
    pub fn into_session(self) -> DetectorSession<'d> {
        self.session
    }
}

impl crate::stream::EventSink for SessionSink<'_> {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let (h, w) = self.session.geometry();
        let in_plane: Vec<Event> = batch
            .iter()
            .copied()
            .filter(|ev| (ev.x as usize) < w && (ev.y as usize) < h)
            .collect();
        self.dropped += (batch.len() - in_plane.len()) as u64;
        if in_plane.is_empty() {
            return Ok(());
        }
        match self.session.mode() {
            TransferMode::Sparse => {
                for chunk in in_plane.chunks(self.session.max_events().max(1)) {
                    let out = self.session.step_sparse(chunk)?;
                    self.frames += 1;
                    self.events += chunk.len() as u64;
                    self.dropped += out.dropped_events as u64;
                }
            }
            TransferMode::Dense => {
                let mut frame = vec![0f32; h * w];
                for ev in &in_plane {
                    frame[ev.pixel_index(w as u16)] += ev.p.signum();
                }
                self.session.step_dense(&frame)?;
                self.frames += 1;
                self.events += in_plane.len() as u64;
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<crate::stream::SinkSummary> {
        Ok(crate::stream::SinkSummary {
            frames: self.frames,
            dropped: self.dropped,
            ..Default::default()
        })
    }

    fn describe(&self) -> String {
        match self.session.mode() {
            TransferMode::Sparse => "session(sparse)".into(),
            TransferMode::Dense => "session(dense)".into(),
        }
    }
}

/// Pace helper: sleep until event `t_us` (scaled) has elapsed since
/// `start`. Infinite scale skips pacing entirely.
#[inline]
fn pace(start: Instant, t_us: u64, scale: f64) {
    if !scale.is_finite() {
        return;
    }
    let due = Duration::from_nanos((t_us as f64 * 1000.0 / scale) as u64);
    let elapsed = start.elapsed();
    if due > elapsed {
        std::thread::sleep(due - elapsed);
    }
}

/// Run one scenario over a RAM-cached recording (borrowed, chunked —
/// no copy of the recording is made).
pub fn run_scenario(
    device: &Device,
    recording: &[Event],
    cfg: &ScenarioConfig,
) -> Result<ScenarioReport> {
    let mut source = SliceSource::new(recording, REPLAY_CHUNK);
    run_scenario_source(device, &mut source, cfg)
}

/// Run one scenario over several sources at once — the paper's §6
/// multi-sensor fusion ("sending multiple inputs to a single
/// neuromorphic compute platform"): the sources are merged by the
/// streaming timestamp-ordered [`FusedSource`] on an
/// [overlay](SourceLayout::overlay) layout (every sensor shares the
/// detector's fixed address plane), then driven through the ordinary
/// scenario path. Each source must itself be time-ordered.
pub fn run_scenario_fused(
    device: &Device,
    sources: Vec<&mut dyn EventSource>,
    cfg: &ScenarioConfig,
) -> Result<ScenarioReport> {
    anyhow::ensure!(!sources.is_empty(), "fused scenario needs at least one source");
    // The overlay layout is cut from each source's claimed resolution; a
    // live source still reporting its observed placeholder would get a
    // near-empty placement and lose its events silently. Refuse instead.
    anyhow::ensure!(
        sources.iter().all(|s| s.geometry_known()),
        "fused scenario sources must declare their geometry \
         (a live source reported observed-only bounds)"
    );
    let resolutions: Vec<_> = sources.iter().map(|s| s.resolution()).collect();
    let layout = SourceLayout::overlay(&resolutions);
    let mut fused = FusedSource::new(sources, Some(layout), REPLAY_CHUNK);
    run_scenario_source(device, &mut fused, cfg)
}

/// Run one scenario over any [`EventSource`] — files, UDP, synthetic
/// cameras — without materializing the stream; the producer pulls
/// bounded batches and paces individual events per their timestamps.
pub fn run_scenario_source(
    device: &Device,
    source: &mut dyn EventSource,
    cfg: &ScenarioConfig,
) -> Result<ScenarioReport> {
    let mut session =
        DetectorSession::with_outputs(device, cfg.transfer, cfg.fetch_outputs)?;
    let (h, w) = session.geometry();
    let cap = session.max_events();

    let report = match cfg.feed {
        FeedMode::Threaded { buffer_size } => {
            run_threaded(&mut session, source, cfg, buffer_size, h, w, cap)?
        }
        FeedMode::Coroutine => run_coro(&mut session, source, cfg, h, w, cap)?,
    };
    Ok(report)
}

/// Shared accumulation for the threaded scenarios: either a dense frame
/// or an event list, guarded by one mutex (the lock the paper's
/// conventional path pays).
struct ThreadShared {
    frame: Mutex<(Vec<f32>, u64)>, // (accumulated frame, events in it)
    events: Mutex<Vec<Event>>,
    prepare_ns: std::sync::atomic::AtomicU64,
    done: AtomicBool,
    /// Consumer → producer cancellation: set on a device error so a
    /// live/endless source stops streaming instead of growing the
    /// shared buffer unboundedly while the scope joins.
    stop: AtomicBool,
}

fn run_threaded(
    session: &mut DetectorSession,
    source: &mut dyn EventSource,
    cfg: &ScenarioConfig,
    buffer_size: usize,
    h: usize,
    w: usize,
    sparse_cap: usize,
) -> Result<ScenarioReport> {
    let shared = ThreadShared {
        frame: Mutex::new((vec![0f32; h * w], 0)),
        events: Mutex::new(Vec::new()),
        prepare_ns: std::sync::atomic::AtomicU64::new(0),
        done: AtomicBool::new(false),
        stop: AtomicBool::new(false),
    };
    let dense = cfg.transfer == TransferMode::Dense;
    let t_start = Instant::now();

    let report = std::thread::scope(|scope| -> Result<ScenarioReport> {
        // ---------------------------------------------------- producer
        let shared_ref = &shared;
        let producer = scope.spawn(move || {
            let result = (|| -> Result<()> {
                let mut buffer = Vec::with_capacity(buffer_size);
                while let Some(batch) = source.next_batch()? {
                    if shared_ref.stop.load(Ordering::Acquire) {
                        break; // consumer died; stop streaming
                    }
                    for ev in batch {
                        buffer.push(ev);
                        if buffer.len() == buffer_size {
                            flush_buffer(shared_ref, &buffer, dense, w);
                            buffer.clear();
                        }
                        pace(t_start, ev.t, cfg.time_scale);
                    }
                }
                if !buffer.is_empty() {
                    flush_buffer(shared_ref, &buffer, dense, w);
                }
                Ok(())
            })();
            shared_ref.done.store(true, Ordering::Release);
            result
        });

        // ---------------------------------------------------- consumer
        let mut frames = 0u64;
        let mut events = 0u64;
        let mut dropped = 0u64;
        loop {
            let done = shared.done.load(Ordering::Acquire);
            if dense {
                let grabbed = {
                    let mut guard = shared.frame.lock().unwrap();
                    if guard.1 == 0 {
                        None
                    } else {
                        let fresh = (vec![0f32; h * w], 0);
                        Some(std::mem::replace(&mut *guard, fresh))
                    }
                };
                match grabbed {
                    Some((frame, n)) => {
                        let out = match session.step_dense(&frame) {
                            Ok(out) => out,
                            Err(e) => {
                                shared.stop.store(true, Ordering::Release);
                                return Err(e);
                            }
                        };
                        frames += 1;
                        events += n;
                        dropped += out.dropped_events as u64;
                    }
                    None if done => break,
                    // Yield, don't spin: on a single core a spinning
                    // consumer would starve the producer for a full
                    // quantum, unfairly penalizing the threaded design.
                    None => std::thread::yield_now(),
                }
            } else {
                // Grab at most the device's sparse capacity; the rest
                // stays accumulated (backpressure, never silent loss).
                let grabbed = {
                    let mut guard = shared.events.lock().unwrap();
                    if guard.is_empty() {
                        None
                    } else if guard.len() <= sparse_cap {
                        Some(std::mem::take(&mut *guard))
                    } else {
                        Some(guard.drain(..sparse_cap).collect::<Vec<_>>())
                    }
                };
                match grabbed {
                    Some(evs) => {
                        let out = match session.step_sparse(&evs) {
                            Ok(out) => out,
                            Err(e) => {
                                shared.stop.store(true, Ordering::Release);
                                return Err(e);
                            }
                        };
                        frames += 1;
                        events += evs.len() as u64;
                        dropped += out.dropped_events as u64;
                    }
                    None if done => break,
                    None => std::thread::yield_now(),
                }
            }
        }
        producer.join().expect("producer panicked")?;
        Ok(ScenarioReport {
            label: cfg.label(),
            frames,
            events,
            dropped,
            wall: t_start.elapsed(),
            stats: session.stats,
            host_prepare_ns: shared.prepare_ns.load(Ordering::Relaxed),
        })
    })?;
    Ok(report)
}

/// Producer-side flush for the threaded scenarios: bin (dense) or append
/// (sparse) a full buffer into the shared structure, under its lock.
fn flush_buffer(shared: &ThreadShared, buffer: &[Event], dense: bool, w: usize) {
    let t0 = Instant::now();
    if dense {
        let mut guard = shared.frame.lock().unwrap();
        for ev in buffer {
            guard.0[ev.pixel_index(w as u16)] += ev.p.signum();
        }
        guard.1 += buffer.len() as u64;
    } else {
        shared.events.lock().unwrap().extend_from_slice(buffer);
    }
    shared
        .prepare_ns
        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
}

fn run_coro(
    session: &mut DetectorSession,
    source: &mut dyn EventSource,
    cfg: &ScenarioConfig,
    h: usize,
    w: usize,
    sparse_cap: usize,
) -> Result<ScenarioReport> {
    let dense = cfg.transfer == TransferMode::Dense;
    let t_start = Instant::now();

    // Single-threaded cooperative state: no locks anywhere.
    let acc_frame = RefCell::new((vec![0f32; h * w], 0u64));
    let acc_events: RefCell<Vec<Event>> = RefCell::new(Vec::new());
    let producer_done = std::cell::Cell::new(false);
    // Consumer → producer cancellation (device error with a possibly
    // endless source: stop accumulating).
    let consumer_dead = std::cell::Cell::new(false);
    let prepare_ns = std::cell::Cell::new(0u64);
    let source_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let session = RefCell::new(session);
    let result: RefCell<Option<Result<(u64, u64, u64)>>> = RefCell::new(None);

    {
        let ex = LocalExecutor::new();
        // ---------------------------------------------------- producer
        ex.spawn(async {
            'stream: loop {
                if consumer_dead.get() {
                    break 'stream;
                }
                let batch = match source.next_batch() {
                    Ok(Some(batch)) => batch,
                    Ok(None) => break 'stream,
                    Err(e) => {
                        *source_err.borrow_mut() = Some(e);
                        break 'stream;
                    }
                };
                if batch.is_empty() {
                    // Live source idle: hand control to the consumer.
                    yield_now().await;
                    continue;
                }
                for ev in batch {
                    {
                        let t0 = Instant::now();
                        if dense {
                            let mut acc = acc_frame.borrow_mut();
                            acc.0[ev.pixel_index(w as u16)] += ev.p.signum();
                            acc.1 += 1;
                        } else {
                            acc_events.borrow_mut().push(ev);
                        }
                        prepare_ns.set(prepare_ns.get() + t0.elapsed().as_nanos() as u64);
                    }
                    // Cooperative pacing: instead of sleeping (which
                    // would stall the consumer sharing this thread),
                    // yield until the event is due.
                    if cfg.time_scale.is_finite() {
                        let due =
                            Duration::from_nanos((ev.t as f64 * 1000.0 / cfg.time_scale) as u64);
                        while t_start.elapsed() < due {
                            yield_now().await;
                        }
                    }
                }
            }
            producer_done.set(true);
        });
        // ---------------------------------------------------- consumer
        ex.spawn(async {
            let mut frames = 0u64;
            let mut events = 0u64;
            let mut dropped = 0u64;
            let out = loop {
                let step = if dense {
                    let grabbed = {
                        let mut acc = acc_frame.borrow_mut();
                        if acc.1 == 0 {
                            None
                        } else {
                            let fresh = (vec![0f32; h * w], 0);
                            Some(std::mem::replace(&mut *acc, fresh))
                        }
                    };
                    match grabbed {
                        Some((frame, n)) => {
                            Some(session.borrow_mut().step_dense(&frame).map(|o| (n, o)))
                        }
                        None => None,
                    }
                } else {
                    // Capacity-capped grab: remainder stays accumulated.
                    let grabbed = {
                        let mut acc = acc_events.borrow_mut();
                        if acc.is_empty() {
                            None
                        } else if acc.len() <= sparse_cap {
                            Some(std::mem::take(&mut *acc))
                        } else {
                            Some(acc.drain(..sparse_cap).collect::<Vec<_>>())
                        }
                    };
                    grabbed.map(|evs| {
                        let n = evs.len() as u64;
                        session.borrow_mut().step_sparse(&evs).map(|o| (n, o))
                    })
                };
                match step {
                    Some(Ok((n, out))) => {
                        frames += 1;
                        events += n;
                        dropped += out.dropped_events as u64;
                    }
                    Some(Err(e)) => {
                        consumer_dead.set(true);
                        break Err(e);
                    }
                    None if producer_done.get() => break Ok((frames, events, dropped)),
                    None => {}
                }
                yield_now().await;
            };
            *result.borrow_mut() = Some(out);
        });
        ex.run();
    }

    if let Some(e) = source_err.into_inner() {
        return Err(e);
    }
    let (frames, events, dropped) =
        result.into_inner().expect("consumer did not report")?;
    Ok(ScenarioReport {
        label: cfg.label(),
        frames,
        events,
        dropped,
        wall: t_start.elapsed(),
        stats: session.into_inner().stats,
        host_prepare_ns: prepare_ns.get(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        let four = ScenarioConfig::paper_four(1.0);
        let labels: Vec<String> = four.iter().map(|c| c.label()).collect();
        assert_eq!(labels, ["threads+dense", "coro+dense", "threads+sparse", "coro+sparse"]);
    }

    #[test]
    fn pace_infinite_scale_returns_immediately() {
        let t0 = Instant::now();
        pace(t0, 10_000_000, f64::INFINITY);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    // Full scenario runs need built artifacts; covered by
    // rust/tests/scenario_integration.rs and the fig4 benches.
}
