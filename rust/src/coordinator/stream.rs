//! Generic `input → filters → output` streaming — the CLI's Fig. 2(B)
//! free composition, driven **incrementally** over
//! [`crate::stream`]'s `EventSource`/`EventSink` traits.
//!
//! The [`Source`] and [`Sink`] enums are the CLI-facing configuration;
//! [`run_stream`] converts them into trait objects and hands them to
//! the coroutine driver (default) or the `sync` baseline. Unlike the
//! old batch path, the stream is never materialized: a file source
//! decodes in chunks, a UDP source ends after a bounded idle wait, and
//! memory stays O(chunk) for arbitrarily long (or endless) inputs.
//!
//! Geometry note: sinks that record geometry (file headers, frame
//! binning) take it from the source *before* the first batch. File
//! sources read ahead until their header yields it; live sources (UDP)
//! only learn geometry by observation, so frame sinks grow on demand
//! and file sinks spool to a temporary raw file and re-encode at the
//! end with the exact observed bounding box (same geometry as the old
//! batch path, still O(chunk) memory).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use crate::aer::{Event, Resolution};
use crate::camera::CameraConfig;
use crate::formats::Format;
use crate::pipeline::Pipeline;
use crate::stream::{
    self, CameraSource, EventSink, EventSource, FileSink, FileSource, FrameSink, MemorySource,
    NullSink, StdoutSink, UdpSink, UdpSource, ViewSink,
};

pub use crate::stream::{StreamConfig, StreamDriver, StreamReport};

/// Where events come from.
pub enum Source {
    /// Stream an event file in chunks (format auto-detected).
    File(PathBuf),
    /// Listen for SPIF datagrams until `idle_timeout` passes with no
    /// data (each poll is a cheap bounded wait, not a spin).
    Udp { bind: String, idle_timeout: Duration },
    /// Synthesize from the camera simulator for `duration_us`.
    Synthetic { config: CameraConfig, duration_us: u64 },
    /// In-memory events (tests, benches).
    Memory(Vec<Event>, Resolution),
}

impl Source {
    /// Open the source as a streaming trait object.
    pub fn into_source(self, chunk_size: usize) -> Result<Box<dyn EventSource>> {
        Ok(match self {
            Source::File(path) => Box::new(FileSource::open(&path, chunk_size)?),
            Source::Udp { bind, idle_timeout } => {
                Box::new(UdpSource::bind(&bind, idle_timeout)?)
            }
            Source::Synthetic { config, duration_us } => {
                Box::new(CameraSource::new(config, duration_us))
            }
            Source::Memory(events, res) => Box::new(MemorySource::new(events, res, chunk_size)),
        })
    }
}

/// Where events go.
pub enum Sink {
    /// Write an event file in the given format, batch by batch.
    File(PathBuf, Format),
    /// Send SPIF datagrams to an address.
    Udp(String),
    /// Print `x,y,p,t` lines.
    Stdout,
    /// Count only (benchmarks, dry runs).
    Null,
    /// Bin into frames and report frame statistics (the "GPU" direction
    /// without a device; the full device path lives in `scenarios`).
    Frames { window_us: u64 },
    /// Render frames as terminal density art (visual inspection).
    View { window_us: u64, max_frames: usize },
}

impl Sink {
    /// Open the sink as a streaming trait object for geometry `res`.
    /// `geometry_known` is the source's claim about `res`: when false
    /// (live sources), geometry-recording file sinks spool and stamp
    /// the exact observed bounding box at finish instead.
    pub fn into_sink(self, res: Resolution, geometry_known: bool) -> Result<Box<dyn EventSink>> {
        Ok(match self {
            Sink::File(path, format) if !geometry_known => {
                Box::new(FileSink::create_observing(&path, format)?)
            }
            Sink::File(path, format) => Box::new(FileSink::create(&path, format, res)?),
            Sink::Udp(addr) => Box::new(UdpSink::connect(&addr)?),
            Sink::Stdout => Box::new(StdoutSink::new()),
            Sink::Null => Box::new(NullSink::default()),
            Sink::Frames { window_us } => Box::new(FrameSink::new(res, window_us)),
            Sink::View { window_us, max_frames } => {
                Box::new(ViewSink::new(res, window_us, max_frames))
            }
        })
    }
}

/// Drive a source through a pipeline into a sink with the default
/// streaming configuration (coroutine driver, rendezvous channel,
/// 4096-event chunks).
pub fn run_stream(source: Source, pipeline: Pipeline, sink: Sink) -> Result<StreamReport> {
    run_stream_with(source, pipeline, sink, StreamConfig::default())
}

/// [`run_stream`] with explicit chunking/driver configuration.
pub fn run_stream_with(
    source: Source,
    mut pipeline: Pipeline,
    sink: Sink,
    config: StreamConfig,
) -> Result<StreamReport> {
    let mut source = source.into_source(config.chunk_size)?;
    let mut sink = sink.into_sink(source.resolution(), source.geometry_known())?;
    stream::run(source.as_mut(), &mut pipeline, sink.as_mut(), config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::synthetic_events;

    #[test]
    fn memory_to_null_counts() {
        let events = synthetic_events(500, 64, 64);
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, 500);
    }

    #[test]
    fn filter_reduces_output_not_input() {
        let events = synthetic_events(500, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new().then(PolarityFilter::keep(Polarity::On)),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, on);
    }

    #[test]
    fn file_roundtrip_through_stream() {
        let dir = std::env::temp_dir().join(format!("aestream-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.aedat");
        let events = synthetic_events(300, 128, 128);
        run_stream(
            Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new(),
            Sink::File(path.clone(), Format::Aedat),
        )
        .unwrap();
        let report =
            run_stream(Source::File(path), Pipeline::new(), Sink::Null).unwrap();
        assert_eq!(report.events_in, 300);
        assert_eq!(report.resolution, Resolution::DVS_128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_to_frames() {
        let report = run_stream(
            Source::Synthetic { config: CameraConfig::default(), duration_us: 20_000 },
            Pipeline::new(),
            Sink::Frames { window_us: 1000 },
        )
        .unwrap();
        assert!(report.frames > 0);
        assert!(report.events_in > 0);
    }

    #[test]
    fn sync_driver_counts_like_coroutine_driver() {
        let events = synthetic_events(4000, 64, 64);
        let coro = run_stream_with(
            Source::Memory(events.clone(), Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::default(),
        )
        .unwrap();
        let sync = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::sync(),
        )
        .unwrap();
        assert_eq!(coro.events_in, sync.events_in);
        assert_eq!(coro.events_out, sync.events_out);
        assert_eq!(coro.batches, sync.batches);
    }

    #[test]
    fn chunking_bounds_in_flight_events() {
        let events = synthetic_events(50_000, 64, 64);
        let config = StreamConfig { chunk_size: 1024, ..Default::default() };
        let report = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            config,
        )
        .unwrap();
        assert!(report.peak_in_flight <= 1024, "peak {}", report.peak_in_flight);
        assert_eq!(report.batches, 50_000 / 1024 + 1);
    }
}
