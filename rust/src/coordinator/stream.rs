//! Generic `input → filters → output` streaming — the CLI's Fig. 2(B)
//! free composition, driven **incrementally** over
//! [`crate::stream`]'s `EventSource`/`EventSink` traits.
//!
//! The [`Source`]/[`Input`] and [`Sink`] enums are the CLI-facing
//! configuration. [`lower_to_graph`] is the one lowering: it opens the
//! inputs, builds a [`crate::stream::GraphSpec`] (sources → merge →
//! shared `filters` chain → `split` router → per-branch chains →
//! sinks) whose stage nodes compile for the *opened* canvas geometry
//! (stateful filters are built from what the sources actually report,
//! not from parse-time assumptions), and the graph's `compile()` runs
//! it on the streaming driver. [`run_graph`] executes multi-branch
//! topologies ([`BranchSpec`] per output, the CLI's `branch` clauses);
//! the historical [`run_topology`] stays as a shim that lowers each
//! sink to a chain-free branch. The single-edge
//! [`run_stream`]/[`run_stream_with`] are thin wrappers over the same
//! driver. Unlike the old batch path, the stream is never
//! materialized: a file source decodes in chunks, a UDP source ends
//! after a bounded idle wait, and memory stays O(chunk) for
//! arbitrarily long (or endless) inputs.
//!
//! Geometry note: sinks that record geometry (file headers, frame
//! binning) take it from the source *before* the first batch. File
//! sources read ahead until their header yields it; live sources (UDP)
//! only learn geometry by observation, so frame sinks grow on demand
//! and file sinks spool to a temporary raw file and re-encode at the
//! end with the exact observed bounding box (same geometry as the old
//! batch path, still O(chunk) memory). Fused topologies need real
//! extents up front for their canvas offsets, so a live UDP source
//! joining one must declare its geometry (`input udp ADDR --geometry
//! WxH`) — and a *headerless recording* may do the same (`input file
//! f.raw --geometry WxH`) instead of being rejected.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::aer::{Event, Resolution};
use crate::camera::CameraConfig;
use crate::formats::Format;
use crate::pipeline::fusion::SourceLayout;
use crate::pipeline::{Pipeline, PipelineSpec};
use crate::serve::{ListenerConfig, ListenerSource, SubscribeSink};
use crate::stream::{
    self, CameraSource, EventSink, EventSource, FileSink, FileSource, FrameSink, GraphConfig,
    GraphSpec, MemorySource, NullSink, ReplaySource, SourceOptions, StageOptions, StdoutSink,
    Topology, UdpSink, UdpSource, ViewSink,
};

pub use crate::stream::{
    AdaptiveConfig, AdaptiveReport, ControllerKind, DiskBufferConfig, FusionLayout,
    ReplaySpeed, ReportTarget, RoutePolicy, StreamConfig, StreamDriver, StreamReport,
    ThreadMode, TopologyConfig,
};

/// Where events come from.
pub enum Source {
    /// Stream an event file in chunks (format auto-detected).
    /// `geometry` declares the extents of a *headerless* recording up
    /// front so it can join fused topologies (a recorded header wins
    /// over the claim when both exist).
    File { path: PathBuf, geometry: Option<Resolution> },
    /// Listen for SPIF datagrams until `idle_timeout` passes with no
    /// data (each poll is a cheap bounded wait, not a spin). `geometry`
    /// declares the sensor extents up front (required for fused
    /// topologies, where canvas offsets need real sizes).
    Udp { bind: String, idle_timeout: Duration, geometry: Option<Resolution> },
    /// Synthesize from the camera simulator for `duration_us`.
    Synthetic { config: CameraConfig, duration_us: u64 },
    /// In-memory events (tests, benches).
    Memory(Vec<Event>, Resolution),
    /// Serve SPIF words over TCP: many concurrent clients attach and
    /// detach while the topology runs, each a dynamic merge lane behind
    /// an AIMD-tuned credit window (`input tcp-listen ADDR --geometry
    /// WxH`). Lowers to a `Listener` graph node.
    TcpListen { bind: String, config: ListenerConfig },
    /// Serve HTTP `POST` ingest of the same words (`input http-listen
    /// ADDR --geometry WxH`).
    HttpListen { bind: String, config: ListenerConfig },
    /// Re-serve a recorded buffer directory (`input replay <dir>
    /// [--from-offset N] [--speed orig|max]`): the journal a
    /// disk-buffered edge wrote, replayed through the normal source
    /// API. Offsets count records from the journal start — the
    /// coordinate `acked.offset` uses, so `--from-offset $(acked)`
    /// resumes an interrupted consumer at-least-once.
    Replay { dir: PathBuf, from_offset: u64, speed: ReplaySpeed },
}

impl Source {
    /// A file source with no declared geometry.
    pub fn file(path: impl Into<PathBuf>) -> Source {
        Source::File { path: path.into(), geometry: None }
    }

    /// Open the source as a streaming trait object.
    pub fn into_source(self, chunk_size: usize) -> Result<Box<dyn EventSource>> {
        Ok(match self {
            Source::File { path, geometry } => {
                let source = FileSource::open(&path, chunk_size)?;
                match geometry {
                    Some(res) => Box::new(source.with_geometry(res)),
                    None => Box::new(source),
                }
            }
            Source::Udp { bind, idle_timeout, geometry } => {
                let source = UdpSource::bind(&bind, idle_timeout)?;
                match geometry {
                    Some(res) => Box::new(source.with_geometry(res)),
                    None => Box::new(source),
                }
            }
            Source::Synthetic { config, duration_us } => {
                Box::new(CameraSource::new(config, duration_us))
            }
            Source::Memory(events, res) => Box::new(MemorySource::new(events, res, chunk_size)),
            Source::TcpListen { bind, config } => {
                Box::new(ListenerSource::bind_tcp(bind.as_str(), config)?)
            }
            Source::HttpListen { bind, config } => {
                Box::new(ListenerSource::bind_http(bind.as_str(), config)?)
            }
            Source::Replay { dir, from_offset, speed } => {
                Box::new(ReplaySource::open(&dir, from_offset, speed))
            }
        })
    }

    /// `true` for serving-plane listeners, which lower to `Listener`
    /// graph nodes (polled inline, never pumped) instead of plain
    /// source nodes.
    fn is_listener(&self) -> bool {
        matches!(self, Source::TcpListen { .. } | Source::HttpListen { .. })
    }
}

/// One topology input: a source plus its optional explicit canvas
/// offset (`--offset X,Y`). Any input with an offset switches the whole
/// topology to the explicit layout (offset-less inputs sit at the
/// origin).
pub struct Input {
    /// The source to open.
    pub source: Source,
    /// Explicit placement on the fused canvas.
    pub offset: Option<(u16, u16)>,
}

impl From<Source> for Input {
    fn from(source: Source) -> Self {
        Input { source, offset: None }
    }
}

/// Where events go.
pub enum Sink {
    /// Write an event file in the given format, batch by batch.
    File(PathBuf, Format),
    /// Send SPIF datagrams to an address.
    Udp(String),
    /// Print `x,y,p,t` lines.
    Stdout,
    /// Count only (benchmarks, dry runs).
    Null,
    /// Bin into frames and report frame statistics (the "GPU" direction
    /// without a device; the full device path lives in `scenarios`).
    Frames { window_us: u64 },
    /// Render frames as terminal density art (visual inspection).
    View { window_us: u64, max_frames: usize },
    /// Serve processed events to dynamically attached TCP subscribers
    /// (`output subscribe ADDR`): each consumer gets every batch as
    /// contiguous SPIF words behind its own bounded queue; slow
    /// consumers are dropped-then-evicted, never backpressuring the
    /// trunk.
    Subscribe { bind: String },
}

impl Sink {
    /// Open the sink as a streaming trait object for geometry `res`.
    /// `geometry_known` is the source's claim about `res`: when false
    /// (live sources), geometry-recording file sinks spool and stamp
    /// the exact observed bounding box at finish instead.
    pub fn into_sink(self, res: Resolution, geometry_known: bool) -> Result<Box<dyn EventSink>> {
        Ok(match self {
            Sink::File(path, format) if !geometry_known => {
                Box::new(FileSink::create_observing(&path, format)?)
            }
            Sink::File(path, format) => Box::new(FileSink::create(&path, format, res)?),
            Sink::Udp(addr) => Box::new(UdpSink::connect(&addr)?),
            Sink::Stdout => Box::new(StdoutSink::new()),
            Sink::Null => Box::new(NullSink::default()),
            Sink::Frames { window_us } => Box::new(FrameSink::new(res, window_us)),
            Sink::View { window_us, max_frames } => {
                Box::new(ViewSink::new(res, window_us, max_frames))
            }
            Sink::Subscribe { bind } => Box::new(SubscribeSink::bind(bind.as_str())?),
        })
    }
}

/// One fan-out branch of a CLI/coordinator topology: its own filter
/// chain (often empty — the legacy shape) ending in one sink. The CLI's
/// `branch filter … output …` clauses parse into these.
pub struct BranchSpec {
    /// The branch's private stage chain (geometry-deferred).
    pub spec: PipelineSpec,
    /// The sink terminating the branch.
    pub sink: Sink,
}

impl From<Sink> for BranchSpec {
    fn from(sink: Sink) -> Self {
        BranchSpec { spec: PipelineSpec::new(), sink }
    }
}

/// Topology-level options layered on the per-edge [`StreamConfig`].
#[derive(Debug, Clone)]
pub struct TopologyOptions {
    /// Chunking and edge-driver selection.
    pub config: StreamConfig,
    /// Pin each source to its own OS thread (fed through the lock-free
    /// SPSC ring) instead of polling them all from the executor thread.
    pub source_threads: bool,
    /// How processed events are distributed across the sinks.
    pub route: RoutePolicy,
    /// How fused inputs are arranged on the canvas. This field is a
    /// *default preference*: when any input declares an explicit
    /// `--offset`, the offsets define the canvas and this field is not
    /// consulted (the lowering passes no layout to the merge node).
    /// Only the CLI — where `--layout` is an explicit request — and
    /// `GraphSpec::validate()` for builder users treat the combination
    /// as a hard error.
    pub layout: FusionLayout,
    /// Shard workers per shardable pipeline stage (1 = serial).
    pub shards: usize,
    /// Pin each shard worker to its own OS thread.
    pub shard_threads: bool,
    /// Pin each sink behind its own OS-thread pump (`--sink-threads`):
    /// a blocking sink backpressures through its bounded ring instead
    /// of stalling the fan-out router inline.
    pub sink_threads: bool,
    /// Adaptive controllers (`--adaptive skew,chunk --epoch N`); `None`
    /// keeps the static runtime.
    pub adaptive: Option<AdaptiveConfig>,
    /// Stream one JSON line per telemetry epoch — plus a final report
    /// line on shutdown — to a file or stdout (`--report-json
    /// <path|->`). `None` keeps reporting end-of-run only.
    pub report_json: Option<ReportTarget>,
    /// Decode worker budget for the shared codec plane
    /// (`--decode-threads N|auto`); `None` keeps packed-format decode
    /// inline on each ingest thread.
    pub decode_threads: Option<usize>,
    /// Make every sink edge durable (`--buffer disk=<dir>[:cap]`):
    /// each `out{j}` sink drains through its own disk journal under
    /// `<dir>/out{j}`. Takes precedence over
    /// [`sink_threads`](Self::sink_threads) — the buffer brings its own
    /// writer/drainer thread pair. `None` (default) keeps pure-memory
    /// edges.
    pub buffer: Option<DiskBufferConfig>,
}

impl Default for TopologyOptions {
    fn default() -> Self {
        TopologyOptions {
            config: StreamConfig::default(),
            source_threads: false,
            route: RoutePolicy::Broadcast,
            layout: FusionLayout::default(),
            shards: 1,
            shard_threads: false,
            sink_threads: false,
            adaptive: None,
            report_json: None,
            decode_threads: None,
            buffer: None,
        }
    }
}

/// Opened sources plus everything derived from their *actual* (primed)
/// geometries: the fused layout and the canvas.
struct OpenedTopology {
    sources: Vec<Box<dyn EventSource>>,
    layout: Option<SourceLayout>,
    canvas: Resolution,
    geometry_known: bool,
}

/// Open every input and build the canvas layout from the opened
/// sources' reported geometries (headers are primed at open; declared
/// geometries claim live/headerless inputs).
fn open_topology(inputs: Vec<Input>, opts: &TopologyOptions) -> Result<OpenedTopology> {
    let chunk = opts.config.chunk_size;
    let mut offsets: Vec<Option<(u16, u16)>> = Vec::with_capacity(inputs.len());
    let mut opened: Vec<Box<dyn EventSource>> = Vec::with_capacity(inputs.len());
    for input in inputs {
        offsets.push(input.offset);
        opened.push(input.source.into_source(chunk)?);
    }
    let explicit = offsets.iter().any(Option::is_some);
    let fused = opened.len() > 1 || explicit;
    let geometry_known = opened.iter().all(|s| s.geometry_known());
    if fused && !geometry_known {
        bail!(
            "fusing requires every input's geometry up front: declare it for \
             live inputs (input udp ADDR --geometry WxH) and for headerless \
             recordings (input file f.raw --geometry WxH); formats with a \
             geometry header need no declaration"
        );
    }
    let layout = if fused {
        let resolutions: Vec<Resolution> = opened.iter().map(|s| s.resolution()).collect();
        // All validated variants share the hard u16 address-space bound
        // a silent saturating layout would otherwise hide.
        Some(if explicit {
            let offsets: Vec<(u16, u16)> =
                offsets.iter().map(|o| o.unwrap_or((0, 0))).collect();
            stream::topology::explicit_layout(&resolutions, &offsets)?
        } else {
            match opts.layout {
                FusionLayout::SideBySide => stream::topology::default_layout(&resolutions)?,
                FusionLayout::Grid => stream::topology::grid_layout(&resolutions)?,
                FusionLayout::Overlay => SourceLayout::overlay(&resolutions),
            }
        })
    } else {
        None
    };
    let canvas = layout.as_ref().map_or_else(|| opened[0].resolution(), |l| l.canvas);
    Ok(OpenedTopology { sources: opened, layout, canvas, geometry_known })
}

/// The stream-layer config an options struct maps to.
fn edge_config(opts: &TopologyOptions) -> TopologyConfig {
    TopologyConfig {
        chunk_size: opts.config.chunk_size,
        driver: opts.config.driver,
        threads: if opts.source_threads {
            ThreadMode::PerSourceThread
        } else {
            ThreadMode::Inline
        },
        route: opts.route,
        adaptive: opts.adaptive.clone(),
        decode_threads: opts.decode_threads,
    }
}

/// Drive an N-source, M-sink topology: sources fan in through the
/// streaming timestamp-ordered merge onto the configured canvas layout,
/// flow through the stage graph compiled from `spec` (each stage a
/// topology node, shardable stages spread over `opts.shards` workers),
/// and fan out per `opts.route`. Stateful filters are built from the
/// *opened* sources' geometry, never from parse-time assumptions.
///
/// **Legacy shim**: this is now sugar over the graph layer — each sink
/// becomes a chain-free [`BranchSpec`] and the whole call lowers
/// through [`lower_to_graph`]. Prefer [`run_graph`] (or
/// [`Topology::builder`] directly) for new code; per-branch filter
/// chains are only expressible there.
pub fn run_topology(
    inputs: Vec<Input>,
    spec: PipelineSpec,
    sinks: Vec<Sink>,
    opts: TopologyOptions,
) -> Result<StreamReport> {
    run_graph(inputs, spec, sinks.into_iter().map(Into::into).collect(), opts)
}

/// Drive a declarative multi-branch topology: inputs fan in through the
/// merge, flow through the shared `spec` chain, and split per
/// `opts.route` into branches that each run their *own* filter chain
/// into their own sink — the CLI's `branch` clauses, or any
/// [`BranchSpec`] list assembled in code.
pub fn run_graph(
    inputs: Vec<Input>,
    spec: PipelineSpec,
    branches: Vec<BranchSpec>,
    opts: TopologyOptions,
) -> Result<StreamReport> {
    let config = GraphConfig {
        chunk_size: opts.config.chunk_size,
        driver: opts.config.driver,
        adaptive: opts.adaptive.clone(),
        report_json: opts.report_json.clone(),
        decode_threads: opts.decode_threads,
    };
    lower_to_graph(inputs, spec, branches, &opts)?.run(config)
}

/// Lower CLI-shaped configuration onto a [`GraphSpec`]: one source node
/// per input (`in0`, `in1`, …, pump-threaded per
/// [`TopologyOptions::source_threads`]), a `fuse` merge when fusing, a
/// `filters` node for the shared chain, a `split` router whenever the
/// fan-out needs one, then per-branch `branch{j}` chains into `out{j}`
/// sinks (pump-threaded per [`TopologyOptions::sink_threads`]). The
/// clause syntax is sugar; the graph is the real program — the golden
/// test asserts the CLI and hand-built builder summaries agree.
pub fn lower_to_graph(
    inputs: Vec<Input>,
    spec: PipelineSpec,
    branches: Vec<BranchSpec>,
    opts: &TopologyOptions,
) -> Result<GraphSpec<'static>> {
    if inputs.is_empty() {
        bail!("topology needs at least one input");
    }
    if branches.is_empty() {
        bail!("topology needs at least one output");
    }
    let chunk = opts.config.chunk_size;
    let stage_opts =
        StageOptions { shards: opts.shards.max(1), shard_threads: opts.shard_threads };
    let any_offset = inputs.iter().any(|input| input.offset.is_some());
    let fused = inputs.len() > 1 || any_offset;

    let mut builder = Topology::builder();
    let mut source_names = Vec::with_capacity(inputs.len());
    for (i, input) in inputs.into_iter().enumerate() {
        let name = format!("in{i}");
        let listener = input.source.is_listener();
        if listener && input.offset.is_some() {
            bail!(
                "listener inputs cannot take --offset: clients land on the \
                 listener's declared canvas, which joins the fused layout whole"
            );
        }
        let source = input.source.into_source(chunk)?;
        builder = if listener {
            // Listeners are graph roots polled inline (never pumped):
            // their client plane must reach the merge driver so clients
            // admitted at runtime become dynamic lanes.
            builder.listen(&name, source)
        } else {
            builder.source_with(
                &name,
                source,
                SourceOptions { offset: input.offset, threaded: opts.source_threads },
            )
        };
        source_names.push(name);
    }
    if fused {
        let refs: Vec<&str> = source_names.iter().map(String::as_str).collect();
        // Explicit offsets define the canvas themselves; only pass the
        // layout policy when it actually applies (a declared policy
        // *plus* offsets is the conflict `validate()` rejects).
        builder = if any_offset {
            builder.merge("fuse", &refs)
        } else {
            builder.merge_with_layout("fuse", &refs, opts.layout)
        };
    }
    if !spec.is_empty() {
        builder = builder.stages_with("filters", spec, stage_opts);
    }
    // A router is also inserted for a *single* branch with its own
    // chain, so the chain compiles as a branch node (prefixed
    // `branch0/…` reports) instead of silently folding into the trunk
    // (where the adaptive epoch loop would re-cut it).
    let fan = branches.len() > 1
        || opts.route != RoutePolicy::Broadcast
        || branches.iter().any(|b| !b.spec.is_empty());
    if fan {
        builder = builder.route("split", opts.route);
    }
    // Geometry-recording sinks need the fused canvas before they open.
    let (canvas, geometry_known) = builder.planned_geometry()?;
    for (j, branch) in branches.into_iter().enumerate() {
        if fan {
            builder = builder.after("split");
        }
        if !branch.spec.is_empty() {
            builder = builder.stages_with(&format!("branch{j}"), branch.spec, stage_opts);
        }
        let sink = branch.sink.into_sink(canvas, geometry_known)?;
        let name = format!("out{j}");
        builder = if let Some(buffer) = &opts.buffer {
            // Durable edge: each sink gets its own journal under the
            // shared base dir, keyed by edge name so replays address
            // exactly one edge. The buffer's writer/drainer pair
            // already decouples the sink from the router, so it
            // supersedes the plain pump.
            let mut config = buffer.clone();
            config.dir = config.dir.join(&name);
            builder.sink_buffered(&name, sink, config)
        } else if opts.sink_threads {
            // Mirror of per-source threads: each sink's blocking I/O
            // moves onto its own pump, fed through a bounded ring.
            builder.sink_threaded(&name, sink)
        } else {
            builder.sink(&name, sink)
        };
    }
    Ok(builder.build())
}

/// Drive a source through a pipeline into a sink with the default
/// streaming configuration (coroutine driver, rendezvous channel,
/// 4096-event chunks).
pub fn run_stream(source: Source, pipeline: Pipeline, sink: Sink) -> Result<StreamReport> {
    run_stream_with(source, pipeline, sink, StreamConfig::default())
}

/// [`run_stream`] with explicit chunking/driver configuration — the
/// single-edge serial path, sharing [`run_topology`]'s open/build
/// machinery but running the caller's ready-made [`Pipeline`].
pub fn run_stream_with(
    source: Source,
    mut pipeline: Pipeline,
    sink: Sink,
    config: StreamConfig,
) -> Result<StreamReport> {
    let opts = TopologyOptions { config, ..Default::default() };
    let opened = open_topology(vec![source.into()], &opts)?;
    let sink = sink.into_sink(opened.canvas, opened.geometry_known)?;
    stream::run_topology(
        opened.sources,
        &mut pipeline,
        vec![sink],
        opened.layout,
        &edge_config(&opts),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::{synthetic_events, synthetic_events_seeded};

    #[test]
    fn memory_to_null_counts() {
        let events = synthetic_events(500, 64, 64);
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, 500);
    }

    #[test]
    fn filter_reduces_output_not_input() {
        let events = synthetic_events(500, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new().then(PolarityFilter::keep(Polarity::On)),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, on);
    }

    #[test]
    fn file_roundtrip_through_stream() {
        let dir = std::env::temp_dir().join(format!("aestream-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.aedat");
        let events = synthetic_events(300, 128, 128);
        run_stream(
            Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new(),
            Sink::File(path.clone(), Format::Aedat),
        )
        .unwrap();
        let report = run_stream(Source::file(path), Pipeline::new(), Sink::Null).unwrap();
        assert_eq!(report.events_in, 300);
        assert_eq!(report.resolution, Resolution::DVS_128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_to_frames() {
        let report = run_stream(
            Source::Synthetic { config: CameraConfig::default(), duration_us: 20_000 },
            Pipeline::new(),
            Sink::Frames { window_us: 1000 },
        )
        .unwrap();
        assert!(report.frames > 0);
        assert!(report.events_in > 0);
    }

    #[test]
    fn sync_driver_counts_like_coroutine_driver() {
        let events = synthetic_events(4000, 64, 64);
        let coro = run_stream_with(
            Source::Memory(events.clone(), Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::default(),
        )
        .unwrap();
        let sync = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::sync(),
        )
        .unwrap();
        assert_eq!(coro.events_in, sync.events_in);
        assert_eq!(coro.events_out, sync.events_out);
        assert_eq!(coro.batches, sync.batches);
    }

    #[test]
    fn chunking_bounds_in_flight_events() {
        let events = synthetic_events(50_000, 64, 64);
        let config = StreamConfig { chunk_size: 1024, ..Default::default() };
        let report = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            config,
        )
        .unwrap();
        assert!(report.peak_in_flight <= 1024, "peak {}", report.peak_in_flight);
        assert_eq!(report.batches, 50_000 / 1024 + 1);
    }

    #[test]
    fn fused_memory_sources_share_a_side_by_side_canvas() {
        let a = synthetic_events_seeded(400, 64, 64, 1);
        let b = synthetic_events_seeded(600, 64, 64, 2);
        let report = run_topology(
            vec![
                Source::Memory(a, Resolution::new(64, 64)).into(),
                Source::Memory(b, Resolution::new(64, 64)).into(),
            ],
            PipelineSpec::new(),
            vec![Sink::Null, Sink::Null],
            TopologyOptions::default(),
        )
        .unwrap();
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.resolution, Resolution::new(128, 64));
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.sinks.len(), 2);
        for sink in &report.sinks {
            assert_eq!(sink.events, 1000, "broadcast");
        }
    }

    #[test]
    fn fusing_live_sources_without_geometry_is_rejected() {
        let err = run_topology(
            vec![
                Source::Udp {
                    bind: "127.0.0.1:0".into(),
                    idle_timeout: Duration::from_millis(10),
                    geometry: None,
                }
                .into(),
                Source::Memory(Vec::new(), Resolution::new(8, 8)).into(),
            ],
            PipelineSpec::new(),
            vec![Sink::Null],
            TopologyOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("--geometry"));
    }

    #[test]
    fn grid_and_overlay_layouts_shape_the_canvas() {
        let events = |seed| synthetic_events_seeded(200, 64, 64, seed);
        let res = Resolution::new(64, 64);
        let inputs = |n: u64| -> Vec<Input> {
            (0..n).map(|i| Source::Memory(events(i), res).into()).collect()
        };
        let grid = run_topology(
            inputs(3),
            PipelineSpec::new(),
            vec![Sink::Null],
            TopologyOptions { layout: FusionLayout::Grid, ..Default::default() },
        )
        .unwrap();
        // 3 sources → 2×2 grid of 64×64 cells.
        assert_eq!(grid.resolution, Resolution::new(128, 128));
        assert_eq!(grid.events_in, 600);

        let overlay = run_topology(
            inputs(3),
            PipelineSpec::new(),
            vec![Sink::Null],
            TopologyOptions { layout: FusionLayout::Overlay, ..Default::default() },
        )
        .unwrap();
        assert_eq!(overlay.resolution, res, "overlay shares one plane");
        assert_eq!(overlay.events_in, 600);
    }

    #[test]
    fn explicit_offsets_override_the_layout_choice() {
        let res = Resolution::new(32, 32);
        let a = synthetic_events_seeded(150, 32, 32, 5);
        let b = synthetic_events_seeded(150, 32, 32, 6);
        let report = run_topology(
            vec![
                Input { source: Source::Memory(a, res), offset: Some((0, 0)) },
                Input { source: Source::Memory(b, res), offset: Some((100, 40)) },
            ],
            PipelineSpec::new(),
            vec![Sink::Null],
            // The layout choice is ignored once offsets are explicit.
            TopologyOptions { layout: FusionLayout::Grid, ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.resolution, Resolution::new(132, 72));
        assert_eq!(report.events_in, 300);
        assert_eq!(report.merge_dropped, 0);
    }

    #[test]
    fn sink_threads_deliver_identically_to_inline_sinks() {
        let events = synthetic_events(2000, 64, 64);
        let report = run_topology(
            vec![Source::Memory(events, Resolution::new(64, 64)).into()],
            PipelineSpec::new(),
            vec![Sink::Null, Sink::Null],
            TopologyOptions { sink_threads: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.events_in, 2000);
        assert_eq!(report.sinks.len(), 2);
        for sink in &report.sinks {
            assert_eq!(sink.events, 2000, "broadcast through the pump");
            assert!(sink.name.starts_with("thread("), "got {:?}", sink.name);
        }
    }

    #[test]
    fn adaptive_options_flow_through_and_report_history() {
        let events = synthetic_events(20_000, 64, 64);
        let report = run_topology(
            vec![Source::Memory(events, Resolution::new(64, 64)).into()],
            PipelineSpec::new(),
            vec![Sink::Null],
            TopologyOptions {
                config: StreamConfig { chunk_size: 512, ..Default::default() },
                adaptive: Some(
                    AdaptiveConfig::new(vec![ControllerKind::Chunk]).with_epoch(4),
                ),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.events_in, 20_000);
        let adaptive = report.adaptive.expect("adaptive runs must report history");
        assert!(adaptive.epochs >= 1, "~39 batches over epochs of 4");
        assert!(
            !adaptive.chunk_changes.is_empty(),
            "the AIMD tuner always moves off an unclamped start"
        );
        assert_eq!(
            adaptive.final_chunk,
            adaptive.chunk_changes.last().unwrap().to,
            "history and final state agree"
        );
        // Static runs keep reporting no history.
        let untouched = run_stream(
            Source::Memory(synthetic_events(100, 8, 8), Resolution::new(8, 8)),
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert!(untouched.adaptive.is_none());
    }

    #[test]
    fn disk_buffered_edge_matches_memory_edge_and_replays() {
        let dir = std::env::temp_dir()
            .join(format!("aestream-coord-buf-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let events = synthetic_events(3000, 64, 64);
        let res = Resolution::new(64, 64);
        let mem_out = dir.join("mem.aedat");
        let buf_out = dir.join("buf.aedat");
        std::fs::create_dir_all(&dir).unwrap();
        run_topology(
            vec![Source::Memory(events.clone(), res).into()],
            PipelineSpec::new(),
            vec![Sink::File(mem_out.clone(), Format::Aedat)],
            TopologyOptions::default(),
        )
        .unwrap();
        let mut config = DiskBufferConfig::new(dir.join("journal"), 64 * 1024 * 1024);
        config.fsync_per_batch = false;
        let report = run_topology(
            vec![Source::Memory(events.clone(), res).into()],
            PipelineSpec::new(),
            vec![Sink::File(buf_out.clone(), Format::Aedat)],
            TopologyOptions { buffer: Some(config), ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.events_in, 3000);
        assert_eq!(
            std::fs::read(&mem_out).unwrap(),
            std::fs::read(&buf_out).unwrap(),
            "disk-buffered edge must be byte-identical to the memory edge"
        );
        assert!(!report.buffer_spill_active, "journal must drain by stream end");
        assert!(
            report.buffer_bytes_on_disk > 0,
            "retained journal keeps its bytes for replay"
        );
        assert!(report.sinks.iter().any(|s| s.name.starts_with("diskbuf(")));

        // The retained journal re-serves the same events, from 0 and
        // from a mid-stream offset.
        let journal = dir.join("journal").join("out0");
        assert_eq!(crate::stream::read_acked_offset(&journal), 3000);
        let full = run_stream(
            Source::Replay { dir: journal.clone(), from_offset: 0, speed: ReplaySpeed::Max },
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(full.events_in, 3000);
        let tail = run_stream(
            Source::Replay { dir: journal, from_offset: 1000, speed: ReplaySpeed::Max },
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(tail.events_in, 2000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_range_offset_is_a_hard_error() {
        let res = Resolution::new(64, 64);
        let err = run_topology(
            vec![Input {
                source: Source::Memory(Vec::new(), res),
                offset: Some((u16::MAX - 10, 0)),
            }],
            PipelineSpec::new(),
            vec![Sink::Null],
            TopologyOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("address space"));
    }
}
