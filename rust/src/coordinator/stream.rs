//! Generic `input → filters → output` streaming — the CLI's Fig. 2(B)
//! free composition, driven **incrementally** over
//! [`crate::stream`]'s `EventSource`/`EventSink` traits.
//!
//! The [`Source`] and [`Sink`] enums are the CLI-facing configuration;
//! [`run_topology`] converts them into trait objects and hands them to
//! [`crate::stream::run_topology`], which fans N sources in through a
//! streaming timestamp-ordered merge (optionally one OS thread per
//! source) and fans out to M sinks by [`RoutePolicy`]. The single-edge
//! [`run_stream`]/[`run_stream_with`] are thin wrappers over the same
//! path. Unlike the old batch path, the stream is never materialized:
//! a file source decodes in chunks, a UDP source ends after a bounded
//! idle wait, and memory stays O(chunk) for arbitrarily long (or
//! endless) inputs.
//!
//! Geometry note: sinks that record geometry (file headers, frame
//! binning) take it from the source *before* the first batch. File
//! sources read ahead until their header yields it; live sources (UDP)
//! only learn geometry by observation, so frame sinks grow on demand
//! and file sinks spool to a temporary raw file and re-encode at the
//! end with the exact observed bounding box (same geometry as the old
//! batch path, still O(chunk) memory). Fused topologies need real
//! extents up front for their canvas offsets, so a UDP source joining
//! one must declare its geometry (`input udp ADDR --geometry WxH`).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::aer::{Event, Resolution};
use crate::camera::CameraConfig;
use crate::formats::Format;
use crate::pipeline::Pipeline;
use crate::stream::{
    self, CameraSource, EventSink, EventSource, FileSink, FileSource, FrameSink, MemorySource,
    NullSink, StdoutSink, UdpSink, UdpSource, ViewSink,
};

pub use crate::stream::{
    RoutePolicy, StreamConfig, StreamDriver, StreamReport, ThreadMode, TopologyConfig,
};

/// Where events come from.
pub enum Source {
    /// Stream an event file in chunks (format auto-detected).
    File(PathBuf),
    /// Listen for SPIF datagrams until `idle_timeout` passes with no
    /// data (each poll is a cheap bounded wait, not a spin). `geometry`
    /// declares the sensor extents up front (required for fused
    /// topologies, where canvas offsets need real sizes).
    Udp { bind: String, idle_timeout: Duration, geometry: Option<Resolution> },
    /// Synthesize from the camera simulator for `duration_us`.
    Synthetic { config: CameraConfig, duration_us: u64 },
    /// In-memory events (tests, benches).
    Memory(Vec<Event>, Resolution),
}

impl Source {
    /// Open the source as a streaming trait object.
    pub fn into_source(self, chunk_size: usize) -> Result<Box<dyn EventSource>> {
        Ok(match self {
            Source::File(path) => Box::new(FileSource::open(&path, chunk_size)?),
            Source::Udp { bind, idle_timeout, geometry } => {
                let source = UdpSource::bind(&bind, idle_timeout)?;
                match geometry {
                    Some(res) => Box::new(source.with_geometry(res)),
                    None => Box::new(source),
                }
            }
            Source::Synthetic { config, duration_us } => {
                Box::new(CameraSource::new(config, duration_us))
            }
            Source::Memory(events, res) => Box::new(MemorySource::new(events, res, chunk_size)),
        })
    }
}

/// Where events go.
pub enum Sink {
    /// Write an event file in the given format, batch by batch.
    File(PathBuf, Format),
    /// Send SPIF datagrams to an address.
    Udp(String),
    /// Print `x,y,p,t` lines.
    Stdout,
    /// Count only (benchmarks, dry runs).
    Null,
    /// Bin into frames and report frame statistics (the "GPU" direction
    /// without a device; the full device path lives in `scenarios`).
    Frames { window_us: u64 },
    /// Render frames as terminal density art (visual inspection).
    View { window_us: u64, max_frames: usize },
}

impl Sink {
    /// Open the sink as a streaming trait object for geometry `res`.
    /// `geometry_known` is the source's claim about `res`: when false
    /// (live sources), geometry-recording file sinks spool and stamp
    /// the exact observed bounding box at finish instead.
    pub fn into_sink(self, res: Resolution, geometry_known: bool) -> Result<Box<dyn EventSink>> {
        Ok(match self {
            Sink::File(path, format) if !geometry_known => {
                Box::new(FileSink::create_observing(&path, format)?)
            }
            Sink::File(path, format) => Box::new(FileSink::create(&path, format, res)?),
            Sink::Udp(addr) => Box::new(UdpSink::connect(&addr)?),
            Sink::Stdout => Box::new(StdoutSink::new()),
            Sink::Null => Box::new(NullSink::default()),
            Sink::Frames { window_us } => Box::new(FrameSink::new(res, window_us)),
            Sink::View { window_us, max_frames } => {
                Box::new(ViewSink::new(res, window_us, max_frames))
            }
        })
    }
}

/// Topology-level options layered on the per-edge [`StreamConfig`].
#[derive(Debug, Clone, Default)]
pub struct TopologyOptions {
    /// Chunking and edge-driver selection.
    pub config: StreamConfig,
    /// Pin each source to its own OS thread (fed through the lock-free
    /// SPSC ring) instead of polling them all from the executor thread.
    pub source_threads: bool,
    /// How processed events are distributed across the sinks.
    pub route: RoutePolicy,
}

/// Drive an N-source, M-sink topology: sources fan in through the
/// streaming timestamp-ordered merge onto a side-by-side canvas, flow
/// through `pipeline` once, and fan out per `opts.route`.
pub fn run_topology(
    sources: Vec<Source>,
    mut pipeline: Pipeline,
    sinks: Vec<Sink>,
    opts: TopologyOptions,
) -> Result<StreamReport> {
    if sources.is_empty() {
        bail!("topology needs at least one input");
    }
    if sinks.is_empty() {
        bail!("topology needs at least one output");
    }
    let chunk = opts.config.chunk_size;
    let opened: Vec<Box<dyn EventSource>> = sources
        .into_iter()
        .map(|s| s.into_source(chunk))
        .collect::<Result<_>>()?;
    let fused = opened.len() > 1;
    let geometry_known = opened.iter().all(|s| s.geometry_known());
    if fused && !geometry_known {
        bail!(
            "fusing requires every input's geometry up front: declare it for \
             live inputs (input udp ADDR --geometry WxH) and use formats with \
             a geometry header for file inputs (headerless recordings such as \
             .txt only learn their extent by observation)"
        );
    }
    let layout = if fused {
        // Shared with the library-level default-layout path, including
        // its hard u16 canvas-width bound.
        let resolutions: Vec<Resolution> =
            opened.iter().map(|s| s.resolution()).collect();
        Some(stream::topology::default_layout(&resolutions)?)
    } else {
        None
    };
    let canvas = layout.as_ref().map_or_else(|| opened[0].resolution(), |l| l.canvas);
    let sinks: Vec<Box<dyn EventSink>> = sinks
        .into_iter()
        .map(|k| k.into_sink(canvas, geometry_known))
        .collect::<Result<_>>()?;
    let config = TopologyConfig {
        chunk_size: chunk,
        driver: opts.config.driver,
        threads: if opts.source_threads {
            ThreadMode::PerSourceThread
        } else {
            ThreadMode::Inline
        },
        route: opts.route,
    };
    stream::run_topology(opened, &mut pipeline, sinks, layout, &config)
}

/// Drive a source through a pipeline into a sink with the default
/// streaming configuration (coroutine driver, rendezvous channel,
/// 4096-event chunks).
pub fn run_stream(source: Source, pipeline: Pipeline, sink: Sink) -> Result<StreamReport> {
    run_stream_with(source, pipeline, sink, StreamConfig::default())
}

/// [`run_stream`] with explicit chunking/driver configuration — the
/// single-edge wrapper over [`run_topology`].
pub fn run_stream_with(
    source: Source,
    pipeline: Pipeline,
    sink: Sink,
    config: StreamConfig,
) -> Result<StreamReport> {
    run_topology(
        vec![source],
        pipeline,
        vec![sink],
        TopologyOptions { config, ..Default::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::{synthetic_events, synthetic_events_seeded};

    #[test]
    fn memory_to_null_counts() {
        let events = synthetic_events(500, 64, 64);
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, 500);
    }

    #[test]
    fn filter_reduces_output_not_input() {
        let events = synthetic_events(500, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new().then(PolarityFilter::keep(Polarity::On)),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, on);
    }

    #[test]
    fn file_roundtrip_through_stream() {
        let dir = std::env::temp_dir().join(format!("aestream-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.aedat");
        let events = synthetic_events(300, 128, 128);
        run_stream(
            Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new(),
            Sink::File(path.clone(), Format::Aedat),
        )
        .unwrap();
        let report =
            run_stream(Source::File(path), Pipeline::new(), Sink::Null).unwrap();
        assert_eq!(report.events_in, 300);
        assert_eq!(report.resolution, Resolution::DVS_128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_to_frames() {
        let report = run_stream(
            Source::Synthetic { config: CameraConfig::default(), duration_us: 20_000 },
            Pipeline::new(),
            Sink::Frames { window_us: 1000 },
        )
        .unwrap();
        assert!(report.frames > 0);
        assert!(report.events_in > 0);
    }

    #[test]
    fn sync_driver_counts_like_coroutine_driver() {
        let events = synthetic_events(4000, 64, 64);
        let coro = run_stream_with(
            Source::Memory(events.clone(), Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::default(),
        )
        .unwrap();
        let sync = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            StreamConfig::sync(),
        )
        .unwrap();
        assert_eq!(coro.events_in, sync.events_in);
        assert_eq!(coro.events_out, sync.events_out);
        assert_eq!(coro.batches, sync.batches);
    }

    #[test]
    fn chunking_bounds_in_flight_events() {
        let events = synthetic_events(50_000, 64, 64);
        let config = StreamConfig { chunk_size: 1024, ..Default::default() };
        let report = run_stream_with(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
            config,
        )
        .unwrap();
        assert!(report.peak_in_flight <= 1024, "peak {}", report.peak_in_flight);
        assert_eq!(report.batches, 50_000 / 1024 + 1);
    }

    #[test]
    fn fused_memory_sources_share_a_side_by_side_canvas() {
        let a = synthetic_events_seeded(400, 64, 64, 1);
        let b = synthetic_events_seeded(600, 64, 64, 2);
        let report = run_topology(
            vec![
                Source::Memory(a, Resolution::new(64, 64)),
                Source::Memory(b, Resolution::new(64, 64)),
            ],
            Pipeline::new(),
            vec![Sink::Null, Sink::Null],
            TopologyOptions::default(),
        )
        .unwrap();
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.resolution, Resolution::new(128, 64));
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.sinks.len(), 2);
        for sink in &report.sinks {
            assert_eq!(sink.events, 1000, "broadcast");
        }
    }

    #[test]
    fn fusing_live_sources_without_geometry_is_rejected() {
        let err = run_topology(
            vec![
                Source::Udp {
                    bind: "127.0.0.1:0".into(),
                    idle_timeout: Duration::from_millis(10),
                    geometry: None,
                },
                Source::Memory(Vec::new(), Resolution::new(8, 8)),
            ],
            Pipeline::new(),
            vec![Sink::Null],
            TopologyOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("--geometry"));
    }
}
