//! Generic `input → filters → output` streaming — the CLI's Fig. 2(B)
//! free composition.
//!
//! Sources produce event batches, the [`Pipeline`] transforms them
//! per-event, sinks consume them. The whole stream runs through the
//! coroutine engine by default (the library's point); a `sync` mode
//! exists for baseline comparisons.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::aer::{Event, Resolution};
use crate::camera::{CameraConfig, SyntheticCamera};
use crate::formats::{self, Format};
use crate::net::{UdpEventReceiver, UdpEventSender};
use crate::pipeline::framer::Framer;
use crate::pipeline::Pipeline;

/// Where events come from.
pub enum Source {
    /// Read a whole event file (format auto-detected).
    File(PathBuf),
    /// Listen for SPIF datagrams until `duration` passes with no data.
    Udp { bind: String, idle_timeout: Duration },
    /// Synthesize from the camera simulator for `duration_us`.
    Synthetic { config: CameraConfig, duration_us: u64 },
    /// In-memory events (tests, benches).
    Memory(Vec<Event>, Resolution),
}

/// Where events go.
pub enum Sink {
    /// Write an event file in the given format.
    File(PathBuf, Format),
    /// Send SPIF datagrams to an address.
    Udp(String),
    /// Print `x,y,p,t` lines.
    Stdout,
    /// Count only (benchmarks, dry runs).
    Null,
    /// Bin into frames and report frame statistics (the "GPU" direction
    /// without a device; the full device path lives in `scenarios`).
    Frames { window_us: u64 },
    /// Render frames as terminal density art (visual inspection).
    View { window_us: u64, max_frames: usize },
}

/// Outcome of a stream run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Events read from the source.
    pub events_in: u64,
    /// Events that survived the pipeline into the sink.
    pub events_out: u64,
    /// Frames produced (Frames sink only).
    pub frames: u64,
    /// Wall time.
    pub wall: Duration,
    /// Sensor geometry of the source.
    pub resolution: Resolution,
}

impl StreamReport {
    /// Events per second through the pipeline.
    pub fn throughput(&self) -> f64 {
        self.events_in as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drive a source through a pipeline into a sink.
pub fn run_stream(source: Source, mut pipeline: Pipeline, sink: Sink) -> Result<StreamReport> {
    let t0 = Instant::now();
    // ------------------------------------------------------- acquire
    let (events, resolution) = match source {
        Source::File(path) => {
            let (events, res, _fmt) = formats::read_events_auto(&path)?;
            (events, res)
        }
        Source::Udp { bind, idle_timeout } => {
            let mut rx = UdpEventReceiver::bind(&bind)
                .with_context(|| format!("binding {bind}"))?;
            let mut events = Vec::new();
            let mut last_data = Instant::now();
            loop {
                match rx.recv_batch()? {
                    Some(batch) => {
                        events.extend(batch);
                        last_data = Instant::now();
                    }
                    None if last_data.elapsed() > idle_timeout => break,
                    None => {}
                }
            }
            let res = formats::bounding_resolution(&events);
            (events, res)
        }
        Source::Synthetic { config, duration_us } => {
            let res = config.resolution;
            let events = SyntheticCamera::new(config).record(duration_us);
            (events, res)
        }
        Source::Memory(events, res) => (events, res),
    };
    let events_in = events.len() as u64;

    // ----------------------------------------------------- transform
    let processed = pipeline.process(&events);
    let events_out = processed.len() as u64;

    // ---------------------------------------------------------- emit
    let mut frames = 0u64;
    match sink {
        Sink::File(path, format) => {
            formats::write_events(&path, &processed, resolution, format)?;
        }
        Sink::Udp(addr) => {
            let mut tx = UdpEventSender::connect(&addr)?;
            tx.send(&processed)?;
        }
        Sink::Stdout => {
            use std::io::Write;
            let stdout = std::io::stdout();
            let mut out = std::io::BufWriter::new(stdout.lock());
            for ev in &processed {
                writeln!(out, "{},{},{},{}", ev.x, ev.y, u8::from(ev.p.is_on()), ev.t)?;
            }
        }
        Sink::Null => {}
        Sink::Frames { window_us } => {
            frames = Framer::frames_of(resolution, window_us, &processed).len() as u64;
        }
        Sink::View { window_us, max_frames } => {
            let all = Framer::frames_of(resolution, window_us, &processed);
            frames = all.len() as u64;
            // Show evenly spaced frames up to the cap.
            let step = (all.len() / max_frames.max(1)).max(1);
            for frame in all.iter().step_by(step).take(max_frames) {
                println!(
                    "── window [{} µs, {} µs) — {} events ──",
                    frame.t_start, frame.t_end, frame.event_count
                );
                print!("{}", crate::pipeline::viewer::render_frame(frame, 69, 26));
            }
        }
    }

    Ok(StreamReport { events_in, events_out, frames, wall: t0.elapsed(), resolution })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::synthetic_events;

    #[test]
    fn memory_to_null_counts() {
        let events = synthetic_events(500, 64, 64);
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new(),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, 500);
    }

    #[test]
    fn filter_reduces_output_not_input() {
        let events = synthetic_events(500, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        let report = run_stream(
            Source::Memory(events, Resolution::new(64, 64)),
            Pipeline::new().then(PolarityFilter::keep(Polarity::On)),
            Sink::Null,
        )
        .unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.events_out, on);
    }

    #[test]
    fn file_roundtrip_through_stream() {
        let dir = std::env::temp_dir().join(format!("aestream-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.aedat");
        let events = synthetic_events(300, 128, 128);
        run_stream(
            Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new(),
            Sink::File(path.clone(), Format::Aedat),
        )
        .unwrap();
        let report =
            run_stream(Source::File(path), Pipeline::new(), Sink::Null).unwrap();
        assert_eq!(report.events_in, 300);
        assert_eq!(report.resolution, Resolution::DVS_128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_to_frames() {
        let report = run_stream(
            Source::Synthetic { config: CameraConfig::default(), duration_us: 20_000 },
            Pipeline::new(),
            Sink::Frames { window_us: 1000 },
        )
        .unwrap();
        assert!(report.frames > 0);
        assert!(report.events_in > 0);
    }
}
