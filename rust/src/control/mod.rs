//! Closed-loop neuromorphic control — the paper's §6 headline future
//! work: "we aim to stream events *back* to an actuator to create a
//! closed-loop, fully neuromorphic control system in real-time."
//!
//! The loop implemented here (exercised end-to-end by the
//! `closed_loop` example):
//!
//! ```text
//!   scene (moving target) ──▶ synthetic camera ──▶ events
//!        ▲                                            │
//!        │                                     edge detector
//!   pan actuator ◀── controller ◀── activity centroid ┘
//! ```
//!
//! * [`centroid`] extracts the activity centroid of an edge/spike map —
//!   the "where is the object" readout of the SNN;
//! * [`PController`] is a proportional tracker commanding pan velocity;
//! * [`PanActuator`] is the simulated plant: a first-order pan axis
//!   with slew-rate limiting, the stand-in for real motor hardware
//!   (DESIGN.md §Substitutions).

use crate::aer::Resolution;

/// Activity centroid of a row-major map. `None` if the map is silent.
/// Uses |activity| so ON/OFF edge polarity doesn't cancel the target.
pub fn centroid(map: &[f32], res: Resolution) -> Option<(f32, f32)> {
    let w = res.width as usize;
    let mut mass = 0.0f64;
    let (mut mx, mut my) = (0.0f64, 0.0f64);
    for (i, &v) in map.iter().enumerate() {
        let a = v.abs() as f64;
        if a > 0.0 {
            mass += a;
            mx += a * (i % w) as f64;
            my += a * (i / w) as f64;
        }
    }
    if mass == 0.0 {
        None
    } else {
        Some(((mx / mass) as f32, (my / mass) as f32))
    }
}

/// Proportional controller: drives the horizontal tracking error (px)
/// to zero by commanding pan velocity (px/s).
#[derive(Debug, Clone)]
pub struct PController {
    /// Proportional gain (1/s): velocity per pixel of error.
    pub gain: f32,
    /// Output saturation (px/s).
    pub max_velocity: f32,
}

impl PController {
    /// New controller.
    pub fn new(gain: f32, max_velocity: f32) -> Self {
        PController { gain, max_velocity }
    }

    /// Velocity command for a horizontal error (target − crosshair).
    pub fn command(&self, error_px: f32) -> f32 {
        (self.gain * error_px).clamp(-self.max_velocity, self.max_velocity)
    }
}

/// Simulated pan axis: integrates commanded velocity with slew limiting.
#[derive(Debug, Clone)]
pub struct PanActuator {
    /// Current pan position (px in scene coordinates).
    pub position: f32,
    /// Hard slew-rate limit of the axis (px/s).
    pub slew_limit: f32,
    /// Commands applied so far.
    pub commands: u64,
}

impl PanActuator {
    /// New actuator at position 0.
    pub fn new(slew_limit: f32) -> Self {
        PanActuator { position: 0.0, slew_limit, commands: 0 }
    }

    /// Apply a velocity command for `dt_us` microseconds.
    pub fn apply(&mut self, velocity_px_s: f32, dt_us: u64) {
        let v = velocity_px_s.clamp(-self.slew_limit, self.slew_limit);
        self.position += v * dt_us as f32 / 1e6;
        self.commands += 1;
    }
}

/// One closed-loop step: map → centroid → error → command → actuate.
/// Returns the tracking error (px) if the map had activity.
pub fn track_step(
    map: &[f32],
    res: Resolution,
    controller: &PController,
    actuator: &mut PanActuator,
    dt_us: u64,
) -> Option<f32> {
    let (cx, _cy) = centroid(map, res)?;
    // Error of the target relative to the sensor crosshair; the actuator
    // pans the *camera*, so positive error ⇒ pan right.
    let error = cx - res.width as f32 / 2.0;
    let cmd = controller.command(error);
    actuator.apply(cmd, dt_us);
    Some(error)
}

#[cfg(test)]
mod tests {
    use super::*;

    const RES: Resolution = Resolution::new(16, 8);

    #[test]
    fn centroid_of_point_mass() {
        let mut map = vec![0.0; RES.pixels()];
        map[3 * 16 + 10] = 2.0;
        let (cx, cy) = centroid(&map, RES).unwrap();
        assert_eq!((cx, cy), (10.0, 3.0));
    }

    #[test]
    fn centroid_uses_magnitude_not_sign() {
        let mut map = vec![0.0; RES.pixels()];
        map[5] = 1.0;
        map[11] = -1.0; // opposite polarity must not cancel
        let (cx, _) = centroid(&map, RES).unwrap();
        assert_eq!(cx, 8.0);
    }

    #[test]
    fn centroid_of_silence_is_none() {
        assert!(centroid(&vec![0.0; RES.pixels()], RES).is_none());
    }

    #[test]
    fn controller_saturates() {
        let c = PController::new(10.0, 50.0);
        assert_eq!(c.command(1.0), 10.0);
        assert_eq!(c.command(100.0), 50.0);
        assert_eq!(c.command(-100.0), -50.0);
    }

    #[test]
    fn actuator_integrates_with_slew_limit() {
        let mut a = PanActuator::new(100.0);
        a.apply(50.0, 1_000_000); // 1 s at 50 px/s
        assert!((a.position - 50.0).abs() < 1e-4);
        a.apply(1000.0, 1_000_000); // clamped to 100 px/s
        assert!((a.position - 150.0).abs() < 1e-3);
        assert_eq!(a.commands, 2);
    }

    #[test]
    fn loop_converges_on_static_target() {
        // Target fixed at x=12; crosshair at 8. The loop should pan the
        // camera until the (simulated) error is driven toward zero.
        let controller = PController::new(5.0, 200.0);
        let mut actuator = PanActuator::new(200.0);
        let mut target_in_sensor = 12.0f32;
        let mut last_err = f32::INFINITY;
        for _ in 0..50 {
            let mut map = vec![0.0; RES.pixels()];
            let xi = (target_in_sensor.round() as usize).min(15);
            map[4 * 16 + xi] = 1.0;
            let err = track_step(&map, RES, &controller, &mut actuator, 10_000)
                .expect("target visible");
            // Panning the camera shifts the target's apparent position
            // opposite to the pan motion.
            target_in_sensor = 12.0 - actuator.position;
            last_err = err;
        }
        assert!(last_err.abs() < 1.0, "loop did not converge: err {last_err}");
    }
}
