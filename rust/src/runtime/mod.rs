//! XLA/PJRT device runtime — the paper's "GPU" boundary.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them on the PJRT CPU client, and executes them from the L3
//! hot path with **explicit, instrumented host→device transfers**:
//! every input literal crosses the boundary through
//! [`Device::to_device`], which counts operations, bytes and
//! nanoseconds into [`TransferStats`] — the measurement behind the
//! Fig. 4(B) reproduction.
//!
//! Python never runs here: artifacts are plain text files on disk.
//!
//! * [`json`] — minimal JSON parser (no serde offline);
//! * [`manifest`] — the artifacts contract;
//! * [`device`] — client, module cache, transfer accounting;
//! * [`detector`] — state-carrying edge-detector sessions (dense/sparse).

pub mod detector;
pub mod device;
pub mod json;
pub mod manifest;

pub use detector::{DetectorSession, StepOutput, TransferMode};
pub use device::{Device, Module, TransferStats};
pub use manifest::{default_artifacts_dir, Manifest};
