//! State-carrying edge-detector sessions on the device.
//!
//! A [`DetectorSession`] owns the LIF state (`v`, `r`) across frames and
//! runs either the **dense** module (host-built frame in) or the
//! **sparse** module (padded event list in, frame built on-device by
//! the Pallas scatter kernel) — the two transfer strategies of the
//! paper's Fig. 4.
//!
//! Per frame:
//! 1. host encodes the input literal(s) — dense `H·W·4` bytes vs sparse
//!    `MAX_EVENTS·12 + 4` bytes;
//! 2. inputs + state cross the boundary via instrumented
//!    [`Device::to_device`] calls (state re-upload is identical in both
//!    modes, so the Fig. 4(B) asymmetry is attributable to the input);
//! 3. the module executes; the output tuple `(edges, spikes, v', r')`
//!    is read back; `v'`/`r'` become the next frame's state.

use anyhow::{bail, Result};

use crate::aer::Event;

use super::device::{events_literal_into, frame_literal, literal_to_f32, Device, Module, TransferStats};

/// Which transfer strategy a session uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferMode {
    /// Host builds the dense frame; full-tensor copy (scenarios 1–2).
    Dense,
    /// Host ships the sparse event list; on-device scatter (3–4).
    Sparse,
}

impl TransferMode {
    /// The export name this mode executes.
    pub fn module_name(&self, free_running: bool) -> &'static str {
        match (self, free_running) {
            (TransferMode::Dense, false) => "dense_step",
            (TransferMode::Sparse, false) => "sparse_step",
            // Free-running variants consume edges on-device and return
            // only a scalar activity readout + recycled state, sparing
            // the per-frame H·W·8-byte device→host haul (§Perf).
            (TransferMode::Dense, true) => "dense_step_free",
            (TransferMode::Sparse, true) => "sparse_step_free",
        }
    }
}

/// Output of one detector step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Edge map, row-major `H×W` (empty in free-running sessions).
    pub edges: Vec<f32>,
    /// Spike map, row-major `H×W` (empty in free-running sessions).
    pub spikes: Vec<f32>,
    /// Σ|edges| computed on-device (free-running sessions only).
    pub edge_activity: f32,
    /// Events that exceeded the sparse capacity and were dropped (always
    /// 0 in dense mode).
    pub dropped_events: usize,
}

/// A device-resident edge-detector with persistent LIF state.
pub struct DetectorSession<'d> {
    device: &'d Device,
    module: Module,
    mode: TransferMode,
    height: usize,
    width: usize,
    max_events: usize,
    /// LIF state literals, fed back each frame.
    v: xla::Literal,
    r: xla::Literal,
    /// Accumulated transfer statistics.
    pub stats: TransferStats,
    /// `false` = free-running: edges consumed on-device (scalar
    /// activity readout), matching the paper's loop that leaves frames
    /// on the GPU; `true` = full edge/spike maps fetched each step.
    fetch_outputs: bool,
    /// Reused row arena for sparse-event literal encoding (avoids a
    /// 48 KB allocation per frame; §Perf L3 — measured <5 %, kept for
    /// allocation hygiene on embedded-style deployments).
    row_arena: Vec<i32>,
}

impl<'d> DetectorSession<'d> {
    /// Open a verification session (full outputs fetched each step).
    pub fn new(device: &'d Device, mode: TransferMode) -> Result<Self> {
        Self::with_outputs(device, mode, true)
    }

    /// Open a session choosing the output regime (see `fetch_outputs`).
    pub fn with_outputs(
        device: &'d Device,
        mode: TransferMode,
        fetch_outputs: bool,
    ) -> Result<Self> {
        let m = device.manifest();
        let (height, width, max_events) = (m.height, m.width, m.max_events);
        let module = device.load(mode.module_name(!fetch_outputs))?;
        let zeros = vec![0f32; height * width];
        Ok(DetectorSession {
            device,
            module,
            mode,
            height,
            width,
            max_events,
            v: frame_literal(&zeros, height, width)?,
            r: frame_literal(&zeros, height, width)?,
            stats: TransferStats::new(),
            fetch_outputs,
            row_arena: Vec::new(),
        })
    }

    /// Session mode.
    pub fn mode(&self) -> TransferMode {
        self.mode
    }

    /// Frame geometry `(height, width)`.
    pub fn geometry(&self) -> (usize, usize) {
        (self.height, self.width)
    }

    /// Sparse capacity per frame.
    pub fn max_events(&self) -> usize {
        self.max_events
    }

    /// Reset LIF state to zero.
    pub fn reset(&mut self) -> Result<()> {
        let zeros = vec![0f32; self.height * self.width];
        self.v = frame_literal(&zeros, self.height, self.width)?;
        self.r = frame_literal(&zeros, self.height, self.width)?;
        Ok(())
    }

    /// Dense step: `frame` is a row-major `H×W` signed event-count frame.
    pub fn step_dense(&mut self, frame: &[f32]) -> Result<StepOutput> {
        if self.mode != TransferMode::Dense {
            bail!("step_dense on a sparse session");
        }
        let input = frame_literal(frame, self.height, self.width)?;
        self.run(&[input], 0)
    }

    /// Sparse step: raw events of one window (coordinates must fit the
    /// sensor; events beyond capacity are dropped and counted).
    pub fn step_sparse(&mut self, events: &[Event]) -> Result<StepOutput> {
        if self.mode != TransferMode::Sparse {
            bail!("step_sparse on a dense session");
        }
        let (ev, dropped) =
            events_literal_into(events, self.max_events, &mut self.row_arena)?;
        self.run(&[ev], dropped)
    }

    /// Common path: upload inputs + state, execute, fetch, re-state.
    fn run(&mut self, inputs: &[xla::Literal], dropped: usize) -> Result<StepOutput> {
        let stats = &mut self.stats;
        let mut bufs = Vec::with_capacity(inputs.len() + 2);
        for lit in inputs {
            bufs.push(self.device.to_device(lit, stats)?);
        }
        bufs.push(self.device.to_device_state(&self.v, stats)?);
        bufs.push(self.device.to_device_state(&self.r, stats)?);
        let arg_refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = self.device.execute(&self.module, &arg_refs, stats)?;
        let mut parts = self.device.from_device(&out, stats)?;
        if self.fetch_outputs {
            // (edges, spikes, v', r')
            if parts.len() != 4 {
                bail!("module {} returned {} outputs, expected 4", self.module.name, parts.len());
            }
            let r = parts.pop().unwrap();
            let v = parts.pop().unwrap();
            let spikes_lit = parts.pop().unwrap();
            let edges_lit = parts.pop().unwrap();
            self.v = v;
            self.r = r;
            Ok(StepOutput {
                edges: literal_to_f32(&edges_lit)?,
                spikes: literal_to_f32(&spikes_lit)?,
                edge_activity: 0.0,
                dropped_events: dropped,
            })
        } else {
            // (activity, v', r')
            if parts.len() != 3 {
                bail!("module {} returned {} outputs, expected 3", self.module.name, parts.len());
            }
            let r = parts.pop().unwrap();
            let v = parts.pop().unwrap();
            let activity = parts.pop().unwrap().to_vec::<f32>()?[0];
            self.v = v;
            self.r = r;
            Ok(StepOutput {
                edges: Vec::new(),
                spikes: Vec::new(),
                edge_activity: activity,
                dropped_events: dropped,
            })
        }
    }
}

// Integration tests (needing built artifacts + a PJRT client) live in
// rust/tests/runtime_integration.rs.
