//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! exported HLO module (file, input shapes/dtypes, content hash). The
//! runtime refuses to run against a manifest whose geometry disagrees
//! with what the coordinator expects — catching stale artifacts at load
//! time instead of as garbage numerics.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Input tensor spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Dtype name as jax spells it (`"float32"`, `"int32"`).
    pub dtype: String,
}

impl InputSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes per element.
    pub fn element_size(&self) -> Result<usize> {
        Ok(match self.dtype.as_str() {
            "float32" | "int32" | "uint32" => 4,
            "float64" | "int64" | "uint64" => 8,
            "float16" | "bfloat16" | "int16" | "uint16" => 2,
            "int8" | "uint8" | "bool" => 1,
            other => bail!("unknown dtype {other}"),
        })
    }

    /// Total byte size of one tensor of this spec.
    pub fn byte_size(&self) -> Result<usize> {
        Ok(self.elements() * self.element_size()?)
    }
}

/// One exported HLO module.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    /// File name within the artifacts dir.
    pub file: String,
    /// Input specs, in call order.
    pub inputs: Vec<InputSpec>,
    /// SHA-256 of the HLO text (as hex), for staleness errors.
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Frame height (paper: 260).
    pub height: usize,
    /// Frame width (paper: 346).
    pub width: usize,
    /// Sparse event capacity per frame (paper config: 4096).
    pub max_events: usize,
    /// Modules by export name.
    pub modules: BTreeMap<String, ModuleSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest: invalid json")?;
        let get_dim = |k: &str| -> Result<usize> {
            Ok(root
                .get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("manifest: missing {k}"))? as usize)
        };
        let mut modules = BTreeMap::new();
        let mods = root
            .get("modules")
            .and_then(Json::as_obj)
            .context("manifest: missing modules")?;
        for (name, m) in mods {
            let file = m
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest: module {name} missing file"))?
                .to_string();
            let mut inputs = Vec::new();
            for inp in m
                .get("inputs")
                .and_then(Json::as_arr)
                .with_context(|| format!("manifest: module {name} missing inputs"))?
            {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("manifest: input missing shape")?
                    .iter()
                    .map(|d| d.as_u64().context("bad dim").map(|d| d as usize))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .context("manifest: input missing dtype")?
                    .to_string();
                inputs.push(InputSpec { shape, dtype });
            }
            let sha256 = m
                .get("sha256")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string();
            modules.insert(name.clone(), ModuleSpec { file, inputs, sha256 });
        }
        Ok(Manifest {
            height: get_dim("height")?,
            width: get_dim("width")?,
            max_events: get_dim("max_events")?,
            modules,
            dir: dir.to_path_buf(),
        })
    }

    /// Spec for a module, or a helpful error.
    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules.get(name).with_context(|| {
            format!(
                "module {name} not in manifest (have: {:?}); run `make artifacts`",
                self.modules.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of a module's HLO file.
    pub fn module_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.module(name)?.file))
    }
}

/// Default artifacts directory: `$AESTREAM_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("AESTREAM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "height": 260, "width": 346, "max_events": 4096,
        "modules": {
            "dense_step": {
                "file": "dense_step.hlo.txt",
                "inputs": [
                    {"shape": [260, 346], "dtype": "float32"},
                    {"shape": [260, 346], "dtype": "float32"},
                    {"shape": [260, 346], "dtype": "float32"}
                ],
                "sha256": "deadbeef", "bytes": 1
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!((m.height, m.width, m.max_events), (260, 346, 4096));
        let spec = m.module("dense_step").unwrap();
        assert_eq!(spec.inputs.len(), 3);
        assert_eq!(spec.inputs[0].byte_size().unwrap(), 260 * 346 * 4);
        assert_eq!(
            m.module_path("dense_step").unwrap(),
            Path::new("/tmp/a/dense_step.hlo.txt")
        );
    }

    #[test]
    fn missing_module_is_helpful() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let err = m.module("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"height": 1}"#, Path::new(".")).is_err());
    }

    #[test]
    fn input_spec_sizes() {
        let s = InputSpec { shape: vec![4096, 3], dtype: "int32".into() };
        assert_eq!(s.byte_size().unwrap(), 49152);
        let bad = InputSpec { shape: vec![1], dtype: "complex64".into() };
        assert!(bad.byte_size().is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!((m.height, m.width), (260, 346));
            for name in ["dense_step", "sparse_step", "scatter_only", "lif_only"] {
                assert!(m.module_path(name).unwrap().exists(), "missing {name}");
            }
        }
    }
}
