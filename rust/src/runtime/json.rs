//! Minimal JSON parser for the artifacts manifest.
//!
//! The offline build has no `serde_json`; this covers the JSON subset
//! the AOT manifest uses (objects, arrays, strings, integers, floats,
//! booleans, null) with proper escape handling and precise error
//! offsets. ~150 lines, fully tested — not a general-purpose parser.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Unwrap a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Unwrap a number as u64 (rejects negatives/fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Unwrap an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Unwrap an object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: &'static str,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &'static str) -> JsonError {
        JsonError { offset: self.pos, message }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &'static str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar (multibyte-safe).
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "height": 260, "width": 346, "max_events": 4096,
            "modules": {
                "dense_step": {
                    "file": "dense_step.hlo.txt",
                    "inputs": [{"shape": [260, 346], "dtype": "float32"}],
                    "sha256": "abc", "bytes": 10557
                }
            }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("height").unwrap().as_u64(), Some(260));
        let m = v.get("modules").unwrap().get("dense_step").unwrap();
        assert_eq!(m.get("file").unwrap().as_str(), Some("dense_step.hlo.txt"));
        let inputs = m.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse(r#"[1, [2, {"a": 3}]]"#).unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ é ü""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ é ü"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("2.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }
}
