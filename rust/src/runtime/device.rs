//! PJRT device wrapper with host↔device transfer accounting.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::aer::Event;

use super::manifest::Manifest;

/// Counters for traffic across the host/device boundary.
///
/// The paper's Fig. 4(B) reports "time spent copying memory from host to
/// device (HtoD) as a percentage of the total runtime"; these counters
/// are the measured equivalents. Device→host reads (fetching edge maps
/// back) are tracked separately — the paper's benchmark leaves results
/// on the GPU, ours verifies them, so DtoH must not pollute HtoD.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TransferStats {
    /// Host→device copy operations for *model inputs* (frames / event
    /// lists) — the quantity the paper's Fig. 4(B) varies.
    pub htod_ops: u64,
    /// Host→device input bytes.
    pub htod_bytes: u64,
    /// Nanoseconds spent in host→device input copies.
    pub htod_ns: u64,
    /// Host→device copies of recycled LIF state (v, r). On the paper's
    /// GPU, Norse keeps state resident; our PJRT tuple-output API forces
    /// a symmetric round-trip, so it is accounted separately to keep the
    /// input-transfer asymmetry measurable (DESIGN.md §Substitutions).
    pub state_ops: u64,
    /// Host→device state bytes.
    pub state_bytes: u64,
    /// Nanoseconds spent in state re-uploads.
    pub state_ns: u64,
    /// Device→host copy operations.
    pub dtoh_ops: u64,
    /// Device→host bytes.
    pub dtoh_bytes: u64,
    /// Nanoseconds spent in device→host copies.
    pub dtoh_ns: u64,
    /// Nanoseconds spent executing compiled modules.
    pub exec_ns: u64,
    /// Number of module executions.
    pub executions: u64,
}

impl TransferStats {
    /// Fresh zeroed stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Merge another stats block into this one.
    pub fn merge(&mut self, o: &TransferStats) {
        self.htod_ops += o.htod_ops;
        self.htod_bytes += o.htod_bytes;
        self.htod_ns += o.htod_ns;
        self.state_ops += o.state_ops;
        self.state_bytes += o.state_bytes;
        self.state_ns += o.state_ns;
        self.dtoh_ops += o.dtoh_ops;
        self.dtoh_bytes += o.dtoh_bytes;
        self.dtoh_ns += o.dtoh_ns;
        self.exec_ns += o.exec_ns;
        self.executions += o.executions;
    }

    /// HtoD time as a fraction of `total_ns`.
    pub fn htod_fraction(&self, total_ns: u64) -> f64 {
        if total_ns == 0 {
            0.0
        } else {
            self.htod_ns as f64 / total_ns as f64
        }
    }
}

/// The PJRT device plus the artifacts manifest.
pub struct Device {
    client: xla::PjRtClient,
    manifest: Manifest,
}

/// A compiled module ready to execute.
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    /// Export name (for errors/labels).
    pub name: String,
    /// Number of inputs the module expects.
    pub arity: usize,
}

impl Device {
    /// Open the CPU PJRT client and load the manifest from `dir`.
    pub fn open(dir: &Path) -> Result<Device> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Device { client, manifest })
    }

    /// Open with the default artifacts directory.
    pub fn open_default() -> Result<Device> {
        Self::open(&super::default_artifacts_dir())
    }

    /// The manifest (geometry, module specs).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one exported module.
    pub fn load(&self, name: &str) -> Result<Module> {
        let spec = self.manifest.module(name)?;
        let path = self.manifest.module_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling module {name}"))?;
        Ok(Module { exe, name: name.to_string(), arity: spec.inputs.len() })
    }

    /// Copy an *input* literal to the device, accounting the transfer.
    pub fn to_device(
        &self,
        lit: &xla::Literal,
        stats: &mut TransferStats,
    ) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self
            .client
            .buffer_from_host_literal(None, lit)
            .context("host→device transfer")?;
        stats.htod_ns += t0.elapsed().as_nanos() as u64;
        stats.htod_ops += 1;
        stats.htod_bytes += lit.size_bytes() as u64;
        Ok(buf)
    }

    /// Copy a recycled *state* literal to the device (accounted apart
    /// from inputs; see [`TransferStats::state_ops`]).
    pub fn to_device_state(
        &self,
        lit: &xla::Literal,
        stats: &mut TransferStats,
    ) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self
            .client
            .buffer_from_host_literal(None, lit)
            .context("host→device state transfer")?;
        stats.state_ns += t0.elapsed().as_nanos() as u64;
        stats.state_ops += 1;
        stats.state_bytes += lit.size_bytes() as u64;
        Ok(buf)
    }

    /// Execute a module on device buffers; returns the raw output buffer
    /// (a tuple for our exports) and accounts execution time.
    pub fn execute(
        &self,
        module: &Module,
        args: &[&xla::PjRtBuffer],
        stats: &mut TransferStats,
    ) -> Result<xla::PjRtBuffer> {
        if args.len() != module.arity {
            bail!("module {} expects {} inputs, got {}", module.name, module.arity, args.len());
        }
        let t0 = Instant::now();
        let mut out = module.exe.execute_b(args).with_context(|| format!("executing {}", module.name))?;
        stats.exec_ns += t0.elapsed().as_nanos() as u64;
        stats.executions += 1;
        let replica = out.pop().context("no execution output")?;
        replica.into_iter().next().context("no output buffer")
    }

    /// Read a device buffer back to host literals (decomposing the
    /// result tuple), accounting the transfer.
    pub fn from_device(
        &self,
        buf: &xla::PjRtBuffer,
        stats: &mut TransferStats,
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let mut lit = buf.to_literal_sync().context("device→host transfer")?;
        stats.dtoh_ns += t0.elapsed().as_nanos() as u64;
        stats.dtoh_ops += 1;
        let parts = lit.decompose_tuple().context("decomposing result tuple")?;
        // NB: size_bytes() on the *tuple* literal aborts inside XLA
        // (ByteSizeOf(TUPLE) needs a pointer size); sum the leaves.
        stats.dtoh_bytes += parts.iter().map(|p| p.size_bytes() as u64).sum::<u64>();
        Ok(parts)
    }
}

// ---------------------------------------------------------------------
// Literal builders (host-side encode of model inputs)
// ---------------------------------------------------------------------

/// Build an `f32[h, w]` literal from a row-major frame.
///
/// Single-copy construction: `vec1(..).reshape(..)` would copy the
/// 360 KB frame twice per step (EXPERIMENTS.md §Perf, L3 entry).
pub fn frame_literal(frame: &[f32], h: usize, w: usize) -> Result<xla::Literal> {
    if frame.len() != h * w {
        bail!("frame has {} elements, expected {}", frame.len(), h * w);
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(frame.as_ptr() as *const u8, frame.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[h, w],
        bytes,
    )?)
}

/// Build the sparse input: `i32[max_events, 3]` event rows, padded with
/// sentinel rows (`p = -1`) that the on-device scatter kernel masks out.
/// A single literal ⇒ a single HtoD operation per frame. Events beyond
/// `max_events` are dropped (counted in the return value).
pub fn events_literal(events: &[Event], max_events: usize) -> Result<(xla::Literal, usize)> {
    let mut arena = Vec::new();
    events_literal_into(events, max_events, &mut arena)
}

/// Arena-reusing variant of [`events_literal`]: `arena` is resized and
/// overwritten, avoiding a per-frame allocation on the hot path.
pub fn events_literal_into(
    events: &[Event],
    max_events: usize,
    arena: &mut Vec<i32>,
) -> Result<(xla::Literal, usize)> {
    let n = events.len().min(max_events);
    let dropped = events.len() - n;
    arena.clear();
    arena.resize(max_events * 3, 0);
    for (i, ev) in events[..n].iter().enumerate() {
        arena[i * 3] = ev.x as i32;
        arena[i * 3 + 1] = ev.y as i32;
        arena[i * 3 + 2] = ev.p.is_on() as i32;
    }
    for i in n..max_events {
        arena[i * 3 + 2] = -1; // sentinel: void row
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(arena.as_ptr() as *const u8, arena.len() * 4) };
    let ev_lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[max_events, 3],
        bytes,
    )?;
    Ok((ev_lit, dropped))
}

/// Read an `f32` literal into a Vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Event;

    #[test]
    fn events_literal_pads_and_truncates() {
        let events = vec![Event::on(1, 2, 0), Event::off(3, 4, 1)];
        let (ev, dropped) = events_literal(&events, 4).unwrap();
        assert_eq!(dropped, 0);
        let rows = ev.to_vec::<i32>().unwrap();
        assert_eq!(&rows[..6], &[1, 2, 1, 3, 4, 0]);
        // Sentinel padding rows: p = -1.
        assert_eq!(&rows[6..], &[0, 0, -1, 0, 0, -1]);

        let (_, dropped) = events_literal(&events, 1).unwrap();
        assert_eq!(dropped, 1);
    }

    #[test]
    fn frame_literal_validates_size() {
        assert!(frame_literal(&[0.0; 6], 2, 3).is_ok());
        assert!(frame_literal(&[0.0; 5], 2, 3).is_err());
    }

    #[test]
    fn stats_merge_and_fraction() {
        let mut a = TransferStats { htod_ns: 30, htod_ops: 1, ..Default::default() };
        let b = TransferStats { htod_ns: 70, htod_ops: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.htod_ops, 3);
        assert!((a.htod_fraction(1000) - 0.1).abs() < 1e-9);
        assert_eq!(TransferStats::new().htod_fraction(0), 0.0);
    }

    // Device-dependent tests live in rust/tests/runtime_integration.rs
    // (they need built artifacts).
}
