//! Leaky integrate-and-fire layer with refractory period.
//!
//! This function *is* the specification the JAX/Pallas `lif_step` kernel
//! must reproduce (operation order matters for float equality; keep in
//! sync with `python/compile/kernels/ref.py`).

/// Neuron parameters (shared across all pixels).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LifParams {
    /// Membrane decay per step (`v ← v·decay`), in (0, 1].
    pub decay: f32,
    /// Spike threshold.
    pub threshold: f32,
    /// Post-spike reset voltage.
    pub v_reset: f32,
    /// Refractory duration in steps.
    pub refrac_steps: u32,
}

impl Default for LifParams {
    fn default() -> Self {
        // Chosen to match the paper's qualitative behaviour: integrate a
        // few frames of event input, spike on sustained edges, stay quiet
        // for a few frames afterwards (noise suppression).
        LifParams { decay: 0.9, threshold: 1.0, v_reset: 0.0, refrac_steps: 3 }
    }
}

/// Per-pixel state.
#[derive(Debug, Clone, PartialEq)]
pub struct LifState {
    /// Membrane voltages.
    pub v: Vec<f32>,
    /// Remaining refractory steps (0 = integrating).
    pub r: Vec<u32>,
}

impl LifState {
    /// Zeroed state for `n` neurons.
    pub fn zeroed(n: usize) -> Self {
        LifState { v: vec![0.0; n], r: vec![0; n] }
    }
}

/// One LIF step over an input frame. Returns the spike map (0.0 / 1.0).
///
/// Refractory pixels leak but do not integrate input — matching Norse's
/// `LIFRefrac` semantics that the paper uses.
pub fn lif_step(params: &LifParams, state: &mut LifState, input: &[f32]) -> Vec<f32> {
    assert_eq!(state.v.len(), input.len());
    let mut spikes = vec![0.0f32; input.len()];
    for i in 0..input.len() {
        let integrating = state.r[i] == 0;
        let mut v = state.v[i] * params.decay;
        if integrating {
            v += input[i];
        }
        let spike = integrating && v >= params.threshold;
        if spike {
            spikes[i] = 1.0;
            v = params.v_reset;
            state.r[i] = params.refrac_steps;
        } else if state.r[i] > 0 {
            state.r[i] -= 1;
        }
        state.v[i] = v;
    }
    spikes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spike_resets_voltage_and_sets_refractory() {
        let p = LifParams::default();
        let mut s = LifState::zeroed(1);
        let spikes = lif_step(&p, &mut s, &[1.5]);
        assert_eq!(spikes, vec![1.0]);
        assert_eq!(s.v[0], 0.0);
        assert_eq!(s.r[0], 3);
    }

    #[test]
    fn refractory_counts_down() {
        let p = LifParams::default();
        let mut s = LifState::zeroed(1);
        lif_step(&p, &mut s, &[1.5]);
        for expected_r in [2, 1, 0] {
            lif_step(&p, &mut s, &[0.0]);
            assert_eq!(s.r[0], expected_r);
        }
    }

    #[test]
    fn refractory_blocks_input_but_leaks() {
        let p = LifParams { refrac_steps: 2, ..Default::default() };
        let mut s = LifState::zeroed(1);
        lif_step(&p, &mut s, &[1.5]); // spike, v=0, r=2
        let spikes = lif_step(&p, &mut s, &[100.0]); // blocked
        assert_eq!(spikes, vec![0.0]);
        assert_eq!(s.v[0], 0.0, "input must not integrate during refractory");
    }

    #[test]
    fn exact_threshold_spikes() {
        let p = LifParams::default();
        let mut s = LifState::zeroed(1);
        let spikes = lif_step(&p, &mut s, &[1.0]);
        assert_eq!(spikes, vec![1.0], "v ≥ threshold is inclusive");
    }

    #[test]
    fn decay_is_geometric() {
        let p = LifParams { threshold: 10.0, ..Default::default() };
        let mut s = LifState::zeroed(1);
        lif_step(&p, &mut s, &[1.0]);
        assert!((s.v[0] - 1.0).abs() < 1e-6);
        lif_step(&p, &mut s, &[0.0]);
        assert!((s.v[0] - 0.9).abs() < 1e-6);
        lif_step(&p, &mut s, &[0.0]);
        assert!((s.v[0] - 0.81).abs() < 1e-6);
    }
}
