//! 3×3 "same" convolution with zero padding — the edge-extraction stage.

/// Discrete Laplacian: responds to spatial discontinuities (edges) in the
/// spike map and cancels on uniform regions.
pub const LAPLACIAN_3X3: [f32; 9] = [0.0, -1.0, 0.0, -1.0, 4.0, -1.0, 0.0, -1.0, 0.0];

/// 3×3 convolution over a row-major `height × width` image with zero
/// padding ("same" output size). Kernel is row-major and applied in
/// cross-correlation orientation (matching `jax.lax.conv`).
pub fn conv2d_3x3(input: &[f32], width: usize, height: usize, kernel: &[f32; 9]) -> Vec<f32> {
    assert_eq!(input.len(), width * height, "image size mismatch");
    let mut out = vec![0.0f32; width * height];
    if width == 0 || height == 0 {
        return out;
    }
    for y in 0..height {
        for x in 0..width {
            let mut acc = 0.0f32;
            for ky in 0..3usize {
                let iy = y as isize + ky as isize - 1;
                if iy < 0 || iy >= height as isize {
                    continue;
                }
                for kx in 0..3usize {
                    let ix = x as isize + kx as isize - 1;
                    if ix < 0 || ix >= width as isize {
                        continue;
                    }
                    acc += input[iy as usize * width + ix as usize] * kernel[ky * 3 + kx];
                }
            }
            out[y * width + x] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_is_identity() {
        let mut k = [0.0; 9];
        k[4] = 1.0;
        let img: Vec<f32> = (0..20).map(|i| i as f32).collect();
        assert_eq!(conv2d_3x3(&img, 5, 4, &k), img);
    }

    #[test]
    fn laplacian_of_uniform_interior_is_zero() {
        let img = vec![3.0; 6 * 6];
        let out = conv2d_3x3(&img, 6, 6, &LAPLACIAN_3X3);
        // Interior pixels cancel exactly.
        for y in 1..5 {
            for x in 1..5 {
                assert_eq!(out[y * 6 + x], 0.0);
            }
        }
        // Border pixels see missing neighbours (zero padding).
        assert_eq!(out[0], 3.0 * 4.0 - 3.0 - 3.0);
    }

    #[test]
    fn laplacian_highlights_step_edge() {
        // Left half 1, right half 0: response concentrates at the edge.
        let w = 8;
        let mut img = vec![0.0; w * 4];
        for y in 0..4 {
            for x in 0..4 {
                img[y * w + x] = 1.0;
            }
        }
        let out = conv2d_3x3(&img, w, 4, &LAPLACIAN_3X3);
        // Interior row: positive on the bright side of the edge,
        // negative on the dark side.
        assert!(out[w + 3] > 0.0);
        assert!(out[w + 4] < 0.0);
        assert_eq!(out[w + 1], 0.0); // uniform region
    }

    #[test]
    fn offset_kernel_shifts() {
        // Kernel with 1 at top-left: out(y,x) = in(y-1, x-1).
        let mut k = [0.0; 9];
        k[0] = 1.0;
        let mut img = vec![0.0; 16];
        img[5] = 7.0; // (y=1, x=1)
        let out = conv2d_3x3(&img, 4, 4, &k);
        assert_eq!(out[10], 7.0); // (y=2, x=2)
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn size_mismatch_panics() {
        conv2d_3x3(&[0.0; 5], 2, 2, &LAPLACIAN_3X3);
    }
}
