//! Pure-Rust spiking edge detector: the reference oracle.
//!
//! The paper's use case (§5) runs "a leaky integrate-and-fire (LIF)
//! neuron layer (with an added refractory term to reduce noise) and a
//! regular convolution" on the GPU via Norse. This module is the
//! bit-level specification of that network, shared by:
//!
//! * the **JAX model** (`python/compile/model.py`) — must match this
//!   implementation to float tolerance (checked by integration tests
//!   through the compiled HLO);
//! * the **CPU-baseline scenario** of the Fig. 4 coordinator;
//! * unit tests of the L1 Pallas kernels (via golden frames).
//!
//! Semantics of one step over an input frame `x` (per pixel):
//!
//! ```text
//! integrating = (r == 0)
//! v ← v·decay + x·[integrating]
//! spike = integrating ∧ (v ≥ threshold)
//! v ← v_reset where spike
//! r ← refrac_steps where spike, else max(r−1, 0)
//! edges = conv2d_3×3(spike, LAPLACIAN)   (zero padding)
//! ```

pub mod conv;
pub mod lif;

use crate::aer::Resolution;
use crate::pipeline::framer::Frame;

pub use conv::{conv2d_3x3, LAPLACIAN_3X3};
pub use lif::{LifParams, LifState};

/// Full edge-detector: LIF layer + Laplacian convolution.
#[derive(Debug, Clone)]
pub struct EdgeDetector {
    /// Neuron parameters.
    pub params: LifParams,
    /// Membrane/refractory state.
    pub state: LifState,
    resolution: Resolution,
    /// 3×3 convolution kernel (row-major).
    pub kernel: [f32; 9],
}

impl EdgeDetector {
    /// New detector with default parameters for a sensor geometry.
    pub fn new(resolution: Resolution) -> Self {
        EdgeDetector {
            params: LifParams::default(),
            state: LifState::zeroed(resolution.pixels()),
            resolution,
            kernel: LAPLACIAN_3X3,
        }
    }

    /// Sensor geometry.
    pub fn resolution(&self) -> Resolution {
        self.resolution
    }

    /// Process one dense input frame; returns the edge map (row-major
    /// `H×W`). The spike map is an intermediate; expose it for tests via
    /// [`step_full`](Self::step_full).
    pub fn step(&mut self, frame: &[f32]) -> Vec<f32> {
        self.step_full(frame).1
    }

    /// Process one frame, returning `(spikes, edges)`.
    pub fn step_full(&mut self, frame: &[f32]) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(
            frame.len(),
            self.resolution.pixels(),
            "frame size does not match detector geometry"
        );
        let spikes = lif::lif_step(&self.params, &mut self.state, frame);
        let edges = conv2d_3x3(
            &spikes,
            self.resolution.width as usize,
            self.resolution.height as usize,
            &self.kernel,
        );
        (spikes, edges)
    }

    /// Convenience: run over a [`Frame`] from the framer.
    pub fn step_frame(&mut self, frame: &Frame) -> Vec<f32> {
        self.step(&frame.data)
    }

    /// Reset neuron state (new stream).
    pub fn reset(&mut self) {
        self.state = LifState::zeroed(self.resolution.pixels());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RES: Resolution = Resolution::new(16, 12);

    fn impulse_frame(x: usize, y: usize, v: f32) -> Vec<f32> {
        let mut f = vec![0.0; RES.pixels()];
        f[y * 16 + x] = v;
        f
    }

    #[test]
    fn single_strong_input_spikes_and_makes_edges() {
        let mut det = EdgeDetector::new(RES);
        let (spikes, edges) = det.step_full(&impulse_frame(8, 6, 2.0));
        assert_eq!(spikes[6 * 16 + 8], 1.0);
        assert_eq!(spikes.iter().filter(|&&s| s != 0.0).count(), 1);
        // Laplacian of a single spike: +4 at centre, -1 at 4-neighbours.
        assert_eq!(edges[6 * 16 + 8], 4.0);
        assert_eq!(edges[6 * 16 + 7], -1.0);
        assert_eq!(edges[5 * 16 + 8], -1.0);
    }

    #[test]
    fn refractory_blocks_immediate_re_spike() {
        let mut det = EdgeDetector::new(RES);
        let frame = impulse_frame(2, 2, 2.0);
        let (s1, _) = det.step_full(&frame);
        assert_eq!(s1[2 * 16 + 2], 1.0);
        // Next frames: pixel is refractory (default 3 steps) despite input.
        for step in 0..det.params.refrac_steps {
            let (s, _) = det.step_full(&frame);
            assert_eq!(s[2 * 16 + 2], 0.0, "should be refractory at step {step}");
        }
        // Refractory over: spikes again.
        let (s, _) = det.step_full(&frame);
        assert_eq!(s[2 * 16 + 2], 1.0);
    }

    #[test]
    fn subthreshold_input_integrates_across_steps() {
        let mut det = EdgeDetector::new(RES);
        let frame = impulse_frame(1, 1, 0.6);
        let (s1, _) = det.step_full(&frame);
        assert_eq!(s1[17], 0.0, "0.6 < threshold: no spike");
        // v = 0.6·decay + 0.6 ≥ 1.0 for decay 0.9 → spike on step 2.
        let (s2, _) = det.step_full(&frame);
        assert_eq!(s2[17], 1.0);
    }

    #[test]
    fn leak_decays_voltage_to_zero() {
        let mut det = EdgeDetector::new(RES);
        det.step(&impulse_frame(1, 1, 0.9));
        let v_after_1 = det.state.v[17];
        assert!(v_after_1 > 0.0);
        let zero = vec![0.0; RES.pixels()];
        for _ in 0..100 {
            det.step(&zero);
        }
        assert!(det.state.v[17] < 1e-4);
    }

    #[test]
    fn reset_clears_state() {
        let mut det = EdgeDetector::new(RES);
        det.step(&impulse_frame(3, 3, 5.0));
        det.reset();
        assert!(det.state.v.iter().all(|&v| v == 0.0));
        assert!(det.state.r.iter().all(|&r| r == 0));
    }

    #[test]
    #[should_panic(expected = "frame size")]
    fn wrong_frame_size_panics() {
        EdgeDetector::new(RES).step(&[0.0; 3]);
    }
}
