//! Benchmark harness (criterion is unavailable offline).
//!
//! Small, deterministic measurement core used by every target in
//! `benches/`: warmup, fixed sample counts, robust summary statistics,
//! and aligned table rendering. Benches are plain binaries
//! (`harness = false`), so `cargo bench` runs them directly.

use std::time::{Duration, Instant};

/// Summary statistics over a set of duration samples.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Number of samples.
    pub n: usize,
    /// Mean, seconds.
    pub mean_s: f64,
    /// Sample standard deviation, seconds.
    pub std_s: f64,
    /// Minimum, seconds.
    pub min_s: f64,
    /// Median, seconds.
    pub median_s: f64,
    /// Maximum, seconds.
    pub max_s: f64,
}

impl Stats {
    /// Compute from raw samples. Panics on empty input.
    pub fn from_samples(samples: &[Duration]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            secs.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Stats {
            n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: secs[0],
            median_s: secs[n / 2],
            max_s: secs[n - 1],
        }
    }

    /// Mean expressed as items/second for `items` per run.
    pub fn throughput(&self, items: u64) -> f64 {
        items as f64 / self.mean_s.max(1e-12)
    }

    /// Human-readable mean ± std.
    pub fn display_mean(&self) -> String {
        format!("{} ± {}", fmt_duration(self.mean_s), fmt_duration(self.std_s))
    }
}

/// Run `f` with `warmup` unmeasured and `samples` measured iterations.
pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed());
    }
    Stats::from_samples(&out)
}

/// Format seconds with an adaptive unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Format a rate with an adaptive SI prefix (e.g. `"12.3 Mev/s"`).
pub fn fmt_rate(rate: f64, unit: &str) -> String {
    if rate >= 1e9 {
        format!("{:.2} G{unit}", rate / 1e9)
    } else if rate >= 1e6 {
        format!("{:.2} M{unit}", rate / 1e6)
    } else if rate >= 1e3 {
        format!("{:.2} k{unit}", rate / 1e3)
    } else {
        format!("{rate:.2} {unit}")
    }
}

/// Aligned plain-text table builder for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row/header mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let samples: Vec<Duration> =
            [1, 2, 3, 4, 5].iter().map(|&ms| Duration::from_millis(ms)).collect();
        let s = Stats::from_samples(&samples);
        assert_eq!(s.n, 5);
        assert!((s.mean_s - 0.003).abs() < 1e-9);
        assert!((s.min_s - 0.001).abs() < 1e-9);
        assert!((s.max_s - 0.005).abs() < 1e-9);
        assert!((s.median_s - 0.003).abs() < 1e-9);
        assert!(s.std_s > 0.0);
    }

    #[test]
    fn throughput_of_known_rate() {
        let s = Stats::from_samples(&[Duration::from_secs(1)]);
        assert!((s.throughput(1_000_000) - 1e6).abs() < 1.0);
    }

    #[test]
    fn measure_runs_expected_times() {
        let mut calls = 0;
        let s = measure(3, 7, || calls += 1);
        assert_eq!(calls, 10);
        assert_eq!(s.n, 7);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000s");
        assert_eq!(fmt_duration(0.002), "2.000ms");
        assert_eq!(fmt_duration(0.000002), "2.000µs");
        assert_eq!(fmt_duration(2e-9), "2ns");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(2_500_000.0, "ev/s"), "2.50 Mev/s");
        assert_eq!(fmt_rate(12.0, "fps"), "12.00 fps");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("name"));
        assert!(r.lines().count() == 4);
    }
}
