//! Chunk buffer pooling: sole-owner reclaim of batch allocations.
//!
//! The zero-copy chunk currency ([`super::chunk::EventChunk`]) removed
//! per-hop *copies*; what remained was a fresh `Arc<Vec<Event>>`
//! **allocation** per batch at every producer (sources, the merge's
//! owned-output path, stateful stage outputs). At camera rates that is
//! tens of thousands of heap round-trips per second for buffers with
//! identical lifetimes and sizes. [`ChunkPool`] closes the loop:
//!
//! * producers call [`get`](ChunkPool::get) for a cleared `Vec<Event>`
//!   with capacity already paid for;
//! * consumers return buffers either directly
//!   ([`recycle_vec`](ChunkPool::recycle_vec), for buffers they own) or
//!   by parking a refcounted handle
//!   ([`recycle`](ChunkPool::recycle)/[`recycle_arc`](ChunkPool::recycle_arc))
//!   that the pool reclaims **only once it is the sole owner**
//!   (`Arc::try_unwrap`) — a buffer still aliased by a live
//!   [`EventChunk`] view downstream is never handed out again, so the
//!   immutability guarantee of emitted chunks survives recycling.
//!
//! Hit/miss counters run at two scopes, mirroring the copy accounting
//! in [`super::chunk`]: per-pool (surfaced through
//! [`crate::metrics::LiveNode`] → `StreamReport` → `--report-json`)
//! and process-wide ([`pool_counters`]) for the sequential bench suite.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::aer::Event;
use crate::metrics::LiveNode;

use super::chunk::EventChunk;

/// Bound on parked (still-aliased) buffers awaiting sole ownership.
/// Beyond it the oldest handle is dropped — the buffer frees normally
/// when its last view goes, it just isn't recycled.
const MAX_PENDING: usize = 32;

/// Bound on reclaimed free buffers held for reuse.
const MAX_FREE: usize = 16;

/// Process-wide pool hits (buffer served from the free list).
static POOL_HITS: AtomicU64 = AtomicU64::new(0);
/// Process-wide pool misses (fresh allocation).
static POOL_MISSES: AtomicU64 = AtomicU64::new(0);

/// Snapshot of pool hit/miss counters (per-pool or process-wide).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolCounters {
    /// Buffers served from the free list (no allocation).
    pub hits: u64,
    /// Buffers freshly allocated because the free list was empty.
    pub misses: u64,
}

impl PoolCounters {
    /// Counters accumulated since an earlier snapshot.
    pub fn delta(&self, since: &PoolCounters) -> PoolCounters {
        PoolCounters { hits: self.hits - since.hits, misses: self.misses - since.misses }
    }
}

/// Read the process-wide pool counters. Exact only when nothing else
/// streams concurrently (the bench suite's situation); parallel tests
/// must assert on per-pool [`ChunkPool::counters`] or the per-run
/// totals in [`crate::stream::StreamReport`].
pub fn pool_counters() -> PoolCounters {
    PoolCounters {
        hits: POOL_HITS.load(Ordering::Relaxed),
        misses: POOL_MISSES.load(Ordering::Relaxed),
    }
}

struct PoolInner {
    /// Cleared buffers ready to hand out.
    free: Vec<Vec<Event>>,
    /// Buffers still aliased by live views, awaiting sole ownership.
    pending: VecDeque<Arc<Vec<Event>>>,
}

/// A shared recycling pool of `Vec<Event>` batch buffers.
///
/// Thread-safe (one `Mutex` around the free/pending lists — the lock
/// is held for pointer shuffling only, never while copying events);
/// shared as `Arc<ChunkPool>` between a topology's sources, merge, and
/// stages.
pub struct ChunkPool {
    inner: Mutex<PoolInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ChunkPool {
    /// An empty pool.
    pub fn new() -> ChunkPool {
        ChunkPool {
            inner: Mutex::new(PoolInner { free: Vec::new(), pending: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Get a cleared buffer with at least `cap` capacity: recycled when
    /// one is available (hit), freshly allocated otherwise (miss).
    pub fn get(&self, cap: usize) -> Vec<Event> {
        self.get_inner(cap).0
    }

    /// [`get`](Self::get), additionally mirroring the hit/miss into a
    /// node's live telemetry (the per-node `pool_hits`/`pool_misses`
    /// report columns).
    pub fn get_counted(&self, cap: usize, node: &LiveNode) -> Vec<Event> {
        let (buf, hit) = self.get_inner(cap);
        if hit {
            node.add_pool_hit();
        } else {
            node.add_pool_miss();
        }
        buf
    }

    fn get_inner(&self, cap: usize) -> (Vec<Event>, bool) {
        let reclaimed = {
            let mut inner = self.inner.lock().expect("pool lock");
            Self::reclaim_locked(&mut inner);
            inner.free.pop()
        };
        match reclaimed {
            Some(mut buf) => {
                debug_assert!(buf.is_empty());
                if buf.capacity() < cap {
                    buf.reserve(cap);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                POOL_HITS.fetch_add(1, Ordering::Relaxed);
                (buf, true)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                POOL_MISSES.fetch_add(1, Ordering::Relaxed);
                (Vec::with_capacity(cap), false)
            }
        }
    }

    /// Park a chunk's backing buffer for reclaim once every view of it
    /// has been dropped. Safe to call while views are live — that is
    /// the point.
    pub fn recycle(&self, chunk: &EventChunk) {
        self.recycle_arc(Arc::clone(chunk.shared_buf()));
    }

    /// Park a shared buffer handle (the merge's drained-segment path).
    pub fn recycle_arc(&self, buf: Arc<Vec<Event>>) {
        if buf.capacity() == 0 {
            // Nothing worth recycling (e.g. the shared empty chunk).
            return;
        }
        let mut inner = self.inner.lock().expect("pool lock");
        inner.pending.push_back(buf);
        while inner.pending.len() > MAX_PENDING {
            inner.pending.pop_front();
        }
    }

    /// Return an owned buffer directly to the free list (cleared).
    pub fn recycle_vec(&self, mut buf: Vec<Event>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut inner = self.inner.lock().expect("pool lock");
        if inner.free.len() < MAX_FREE {
            inner.free.push(buf);
        }
    }

    /// Move every pending buffer whose views have all dropped onto the
    /// free list. `strong_count == 1` means the pool's handle is the
    /// last one, so no other thread can clone it concurrently —
    /// `try_unwrap` then cannot race.
    fn reclaim_locked(inner: &mut PoolInner) {
        let mut i = 0;
        while i < inner.pending.len() {
            if Arc::strong_count(&inner.pending[i]) == 1 {
                let arc = inner.pending.remove(i).expect("index in bounds");
                match Arc::try_unwrap(arc) {
                    Ok(mut buf) => {
                        buf.clear();
                        if inner.free.len() < MAX_FREE {
                            inner.free.push(buf);
                        }
                    }
                    Err(arc) => {
                        // Lost a race we argued can't happen; put it
                        // back rather than leak correctness on it.
                        inner.pending.insert(i, arc);
                        i += 1;
                    }
                }
            } else {
                i += 1;
            }
        }
    }

    /// This pool's hit/miss counters.
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

impl Default for ChunkPool {
    fn default() -> Self {
        ChunkPool::new()
    }
}

struct BytePoolInner {
    free: Vec<Vec<u8>>,
    /// Byte buffers still referenced by in-flight decode pieces.
    pending: VecDeque<Arc<Vec<u8>>>,
}

/// The raw-bytes sibling of [`ChunkPool`]: recycles the `Vec<u8>` read
/// buffers that ingest threads fill and hand to the codec worker plane
/// as `Arc<Vec<u8>>` piece ranges. Identical sole-owner discipline —
/// a buffer is reclaimed only once every piece range over it has been
/// decoded and dropped — and identical hit/miss accounting (folded into
/// the same process-wide [`pool_counters`]).
pub struct BytePool {
    inner: Mutex<BytePoolInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BytePool {
    /// An empty pool.
    pub fn new() -> BytePool {
        BytePool {
            inner: Mutex::new(BytePoolInner { free: Vec::new(), pending: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Get a cleared byte buffer with at least `cap` capacity.
    pub fn get(&self, cap: usize) -> Vec<u8> {
        let reclaimed = {
            let mut inner = self.inner.lock().expect("byte pool lock");
            Self::reclaim_locked(&mut inner);
            inner.free.pop()
        };
        match reclaimed {
            Some(mut buf) => {
                debug_assert!(buf.is_empty());
                if buf.capacity() < cap {
                    buf.reserve(cap);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                POOL_HITS.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                POOL_MISSES.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(cap)
            }
        }
    }

    /// Park a shared byte buffer for reclaim once the last decode piece
    /// over it drops.
    pub fn recycle_arc(&self, buf: Arc<Vec<u8>>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut inner = self.inner.lock().expect("byte pool lock");
        inner.pending.push_back(buf);
        while inner.pending.len() > MAX_PENDING {
            inner.pending.pop_front();
        }
    }

    /// Return an owned byte buffer directly to the free list (cleared).
    pub fn recycle_vec(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut inner = self.inner.lock().expect("byte pool lock");
        if inner.free.len() < MAX_FREE {
            inner.free.push(buf);
        }
    }

    fn reclaim_locked(inner: &mut BytePoolInner) {
        let mut i = 0;
        while i < inner.pending.len() {
            if Arc::strong_count(&inner.pending[i]) == 1 {
                let arc = inner.pending.remove(i).expect("index in bounds");
                match Arc::try_unwrap(arc) {
                    Ok(mut buf) => {
                        buf.clear();
                        if inner.free.len() < MAX_FREE {
                            inner.free.push(buf);
                        }
                    }
                    Err(arc) => {
                        inner.pending.insert(i, arc);
                        i += 1;
                    }
                }
            } else {
                i += 1;
            }
        }
    }

    /// This pool's hit/miss counters.
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

impl Default for BytePool {
    fn default() -> Self {
        BytePool::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn live_views_gate_reclaim() {
        let pool = ChunkPool::new();
        let chunk = EventChunk::from_vec(synthetic_events(100, 64, 64));
        let base = chunk.as_slice().as_ptr() as usize;
        pool.recycle(&chunk);
        // The chunk is still alive: the pool must allocate fresh.
        let b1 = pool.get(100);
        assert_ne!(b1.as_ptr() as usize, base, "aliased buffer must not be handed out");
        assert_eq!(pool.counters(), PoolCounters { hits: 0, misses: 1 });
        drop(chunk);
        // Sole owner now: the original allocation comes back cleared.
        let b2 = pool.get(100);
        assert_eq!(b2.as_ptr() as usize, base, "sole-owner buffer must be reclaimed");
        assert!(b2.is_empty());
        assert!(b2.capacity() >= 100);
        assert_eq!(pool.counters(), PoolCounters { hits: 1, misses: 1 });
    }

    #[test]
    fn owned_buffers_recycle_directly() {
        let pool = ChunkPool::new();
        let mut buf = pool.get(64);
        assert_eq!(pool.counters().misses, 1);
        buf.extend_from_slice(&synthetic_events(64, 32, 32));
        let base = buf.as_ptr() as usize;
        pool.recycle_vec(buf);
        let again = pool.get(64);
        assert_eq!(again.as_ptr() as usize, base);
        assert!(again.is_empty(), "recycled buffers come back cleared");
        assert_eq!(pool.counters().hits, 1);
    }

    #[test]
    fn empty_buffers_are_not_pooled() {
        let pool = ChunkPool::new();
        pool.recycle(&EventChunk::empty());
        pool.recycle_vec(Vec::new());
        let got = pool.get(8);
        assert_eq!(pool.counters(), PoolCounters { hits: 0, misses: 1 });
        assert!(got.capacity() >= 8);
    }

    #[test]
    fn byte_pool_mirrors_the_event_pool_discipline() {
        let pool = BytePool::new();
        let mut buf = pool.get(4096);
        assert_eq!(pool.counters(), PoolCounters { hits: 0, misses: 1 });
        buf.extend_from_slice(&[7u8; 128]);
        let base = buf.as_ptr() as usize;
        let shared = Arc::new(buf);
        let piece = Arc::clone(&shared); // an in-flight decode piece
        pool.recycle_arc(shared);
        let fresh = pool.get(4096);
        assert_ne!(fresh.as_ptr() as usize, base, "aliased buffer must not be handed out");
        drop(piece);
        let back = pool.get(4096);
        assert_eq!(back.as_ptr() as usize, base, "sole-owner buffer reclaimed");
        assert!(back.is_empty());
        assert_eq!(pool.counters(), PoolCounters { hits: 1, misses: 2 });
        pool.recycle_vec(back);
        assert_eq!(pool.get(1).as_ptr() as usize, base);
    }

    #[test]
    fn pending_ring_is_bounded() {
        let pool = ChunkPool::new();
        let chunks: Vec<EventChunk> =
            (0..2 * MAX_PENDING).map(|_| EventChunk::from_vec(synthetic_events(4, 8, 8))).collect();
        for c in &chunks {
            pool.recycle(c);
        }
        assert!(pool.inner.lock().unwrap().pending.len() <= MAX_PENDING);
        drop(chunks);
        // Reclaim everything that survived the bound; the free list is
        // bounded too.
        let _ = pool.get(1);
        assert!(pool.inner.lock().unwrap().free.len() <= MAX_FREE);
    }
}
