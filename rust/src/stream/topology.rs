//! Stream topology: multi-threaded fan-in/fan-out graphs over the
//! streaming layer.
//!
//! The paper's §6 names multi-sensor fusion as the natural extension of
//! coroutine streaming ("sending multiple inputs to a single
//! neuromorphic compute platform would … be trivial"). This module
//! generalizes the single `source → pipeline → sink` edge into a graph:
//!
//! * **Fan-in** — [`FusedSource`] lifts [`crate::pipeline::fusion`]'s
//!   batch-only k-way merge to a *streaming*, timestamp-ordered merge:
//!   per-source carry buffers hold at most one batch each (O(chunk ×
//!   sources) memory), and an optional [`SourceLayout`] offsets each
//!   source onto a shared canvas as events flow.
//! * **Threads** — with [`ThreadMode::PerSourceThread`], every source is
//!   pinned to its own OS thread and feeds the cooperative executor
//!   through [`crate::rt::sync_channel`] (the wait-free SPSC ring in
//!   [`crate::sync::spsc`]); the merge and the pipeline stay on the
//!   executor thread, so there is still no per-event lock anywhere.
//! * **Fan-out** — M sinks each run as their own coroutine behind a
//!   bounded channel; a router task applies the shared stage chain once
//!   and distributes batches by [`RoutePolicy`] (broadcast, polarity
//!   split, or vertical region stripes).
//!
//! [`run_topology`] drives the whole graph; the single-edge
//! [`super::run`] is a thin wrapper over it (one source, one sink,
//! inline threading). Merge correctness requires each individual source
//! to be time-ordered (the same precondition as
//! [`crate::pipeline::fusion::merge_streams`]); the streaming merge
//! only emits an event once every live source has data buffered. An
//! idle live source therefore stalls the merge — but only for a
//! *bounded* time: once its [`IdleBackoff`] escalation runs out the
//! source is treated as heartbeating (its lane stops blocking the
//! merge) until data returns, so one quiet UDP sensor cannot freeze
//! its siblings. Events that arrive behind the merge frontier after a
//! heartbeat are still delivered — with their timestamps clamped up to
//! the frontier (watermark semantics), so the merge's output stays
//! globally monotonic for frame binners — and counted in
//! [`StreamReport::merge_late_events`]. Inline live sources poll with
//! *blocking* slices, so even after a heartbeat each merge round can
//! spend one poll slice on the quiet lane — fuse live sources with
//! [`ThreadMode::PerSourceThread`] to keep their polls off the merge
//! thread entirely.
//!
//! Between fan-in and fan-out the edge runs any
//! [`super::BatchProcessor`]: the serial [`crate::pipeline::Pipeline`], or a compiled
//! [`super::StageGraph`] whose stages execute as sharded topology
//! nodes.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::aer::{Event, Resolution};
use crate::metrics::{LiveNode, NodeReport};
use crate::pipeline::fusion::SourceLayout;
use crate::rt::channel::TrySendError;
use crate::rt::{
    block_on, channel, sync_channel, yield_now, LocalExecutor, Sender, SyncReceiver, SyncSender,
};

use super::adapt::{Adaptor, AdaptiveConfig, AdaptiveRuntime, DEFAULT_EPOCH_BATCHES};
use super::chunk::{self, EventChunk, EVENT_BYTES};
use super::codec_plane::{CodecPlane, CodecPlaneConfig};
use super::merge::MergeCore;
use super::pool::{ChunkPool, PoolCounters};
use super::report::{ReportEmitter, ReportTarget};
use super::sources::grow_resolution;
use super::stage::{stripe_cut, stripe_index, BatchProcessor, StageGraph};
use super::{ClientPlane, EventSink, EventSource, StreamConfig, StreamDriver, StreamReport};

/// Batches buffered per source-thread channel (in addition to the batch
/// being assembled on either side): small, so per-source memory stays
/// O(chunk) while still decoupling the reader from momentary merge
/// stalls.
const PUMP_QUEUE_BATCHES: usize = 2;

/// How processed batches are distributed across a topology's sinks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Every sink receives every event.
    #[default]
    Broadcast,
    /// Sink 0 receives ON events, sink 1 receives OFF events
    /// (requires exactly two sinks).
    Polarity,
    /// The canvas is cut into M vertical stripes; sink i receives the
    /// events of stripe i.
    Stripes,
}

/// Where each source of a topology runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ThreadMode {
    /// All sources are pulled from the executor thread (cooperative
    /// scheduling only — the paper's Fig. 1(B) shape).
    #[default]
    Inline,
    /// Each source is pinned to its own OS thread and hands batches to
    /// the executor through the lock-free SPSC ring: a true
    /// multi-threaded driver with no per-event locks.
    PerSourceThread,
}

/// Parameters for [`run_topology`].
#[derive(Debug, Clone)]
pub struct TopologyConfig {
    /// Target events per batch (and the per-hop memory unit). An
    /// adaptive `chunk` controller may re-tune this mid-run.
    pub chunk_size: usize,
    /// Edge scheduling strategy (shared with the single-edge driver).
    pub driver: StreamDriver,
    /// Source threading.
    pub threads: ThreadMode,
    /// Sink routing.
    pub route: RoutePolicy,
    /// Adaptive controllers to run at epoch barriers (`None` = the
    /// static runtime). See [`super::adapt`].
    pub adaptive: Option<AdaptiveConfig>,
    /// Decode worker budget for the shared codec plane
    /// (`--decode-threads`). `None` keeps packed-format decode inline
    /// on each ingest thread; `Some(w)` spawns a plane of `w` workers
    /// and hands it to every source (see
    /// [`super::codec_plane`]).
    pub decode_threads: Option<usize>,
}

impl From<StreamConfig> for TopologyConfig {
    fn from(config: StreamConfig) -> Self {
        TopologyConfig {
            chunk_size: config.chunk_size,
            driver: config.driver,
            threads: ThreadMode::Inline,
            route: RoutePolicy::Broadcast,
            adaptive: None,
            decode_threads: None,
        }
    }
}

impl Default for TopologyConfig {
    fn default() -> Self {
        StreamConfig::default().into()
    }
}

/// Escalating bounded wait for idle live sources: a few scheduler
/// yields first (cheap when data is imminent), then exponentially
/// growing sleeps capped at 1 ms — an idle UDP topology wakes ≤ 1000
/// times a second instead of burning a core.
#[derive(Debug, Default)]
pub(crate) struct IdleBackoff {
    streak: u32,
}

impl IdleBackoff {
    /// Yields before the first sleep.
    const YIELDS: u32 = 8;
    /// Sleep cap in microseconds.
    const MAX_SLEEP_US: u64 = 1000;

    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Data arrived: restart the escalation from cheap yields.
    pub(crate) fn reset(&mut self) {
        self.streak = 0;
    }

    /// One bounded wait step, escalating with the idle streak
    /// (50 µs → 100 → 200 → … capped at [`Self::MAX_SLEEP_US`]).
    pub(crate) fn wait(&mut self) {
        self.streak = self.streak.saturating_add(1);
        if self.streak <= Self::YIELDS {
            std::thread::yield_now();
        } else {
            let exp = u64::from((self.streak - Self::YIELDS - 1).min(5));
            let us = (50u64 << exp).min(Self::MAX_SLEEP_US);
            std::thread::sleep(Duration::from_micros(us));
        }
    }
}

// ---------------------------------------------------------------- fan-in

/// Empty refills a live source may report before the merge declares it
/// heartbeating (non-blocking). Matched to the [`IdleBackoff`]
/// escalation: by this many idle polls the driver's waits have reached
/// the backoff's sleep cap, i.e. the source had its full bounded grace.
/// Non-blocking lanes (pump-thread rings) hit this in a few ms.
pub(crate) const HEARTBEAT_POLLS: u32 = IdleBackoff::YIELDS + 6;

/// Wall-clock grace for lanes whose polls *block* (an inline
/// [`UdpSource`](super::UdpSource) waits its poll slice — up to tens of
/// ms — per empty refill, so it would exhaust via its idle timeout
/// before ever accumulating [`HEARTBEAT_POLLS`]). Whichever bound trips
/// first breaks the stall.
pub(crate) const HEARTBEAT_GRACE: Duration = Duration::from_millis(10);

/// Per-source bookkeeping beside the merge lane.
struct FusedInput<S: EventSource> {
    source: S,
    /// Live counter cell (events/batches pulled), shared with the
    /// telemetry plane.
    node: Arc<LiveNode>,
    /// Consecutive empty refills (live source with nothing pending).
    idle_polls: u32,
    /// When the current idle streak started (live sources only).
    idle_since: Option<Instant>,
    /// `true` once the source's idle grace ran out: its empty lane no
    /// longer blocks the merge.
    heartbeat: bool,
}

/// Outcome of one bounded pull on an input.
enum Poll {
    /// New events landed in the lane.
    Data,
    /// The source ended (lane exhausted).
    End,
    /// Live source, nothing pending right now.
    Idle,
}

/// One bounded pull on a merge input, with all heartbeat bookkeeping —
/// a free function (not a `FusedSource` method) so static inputs
/// (`FusedInput<S>`) and dynamic client lanes
/// (`FusedInput<Box<dyn EventSource>>`) share it without forcing the
/// whole merge behind a trait object.
fn poll_one<T: EventSource>(
    input: &mut FusedInput<T>,
    core: &mut MergeCore<Event>,
    lane: usize,
    stalls_broken: &mut u64,
) -> Result<Poll> {
    debug_assert_eq!(core.lane_len(lane), 0);
    match input.source.next_batch()? {
        None => {
            core.exhaust(lane);
            Ok(Poll::End)
        }
        Some(batch) if batch.is_empty() => {
            // Only *live* sources may heartbeat: a finite source's
            // empty batch is momentary starvation (e.g. a slow pump
            // thread), and breaking its stall would trade exact
            // order for nothing.
            if input.source.is_live() {
                input.idle_polls = input.idle_polls.saturating_add(1);
                let since = *input.idle_since.get_or_insert_with(Instant::now);
                if !input.heartbeat
                    && (input.idle_polls >= HEARTBEAT_POLLS || since.elapsed() >= HEARTBEAT_GRACE)
                {
                    // Grace expired (poll-count bound for cheap
                    // non-blocking lanes, wall-clock bound for
                    // lanes with blocking polls): stop letting
                    // this quiet source stall its siblings.
                    input.heartbeat = true;
                    core.set_blocking(lane, false);
                    *stalls_broken += 1;
                }
            }
            Ok(Poll::Idle)
        }
        Some(batch) => {
            input.node.add_events(batch.len() as u64);
            input.node.add_batch();
            input.idle_polls = 0;
            input.idle_since = None;
            if input.heartbeat {
                input.heartbeat = false;
                core.set_blocking(lane, true);
            }
            // The whole batch becomes one shared carry segment: runs
            // emitted from it are views, and the buffer flows back to
            // the pool once drained.
            core.push_vec(lane, batch);
            Ok(Poll::Data)
        }
    }
}

/// Streaming, timestamp-ordered k-way merge of N [`EventSource`]s — the
/// incremental lift of [`crate::pipeline::fusion::merge_streams`] /
/// [`fuse`](crate::pipeline::fusion::fuse), built on the shared
/// [`MergeCore`].
///
/// Each input keeps a carry buffer of at most one batch; an event is
/// emitted only when every live *blocking* input has data buffered, so
/// the output is globally time-ordered whenever each input is. A live
/// input that stays idle past its bounded grace starts heartbeating:
/// its empty lane stops blocking (the stall is counted), and any events
/// it later delivers behind the merge frontier are still emitted —
/// timestamps clamped to the frontier so the output stays monotonic —
/// and counted late. With a [`SourceLayout`], events are offset onto the
/// shared canvas as they are merged (out-of-bounds events are counted,
/// not emitted). A single input with no layout passes batches through
/// untouched, which is what makes the single-edge [`super::run`] a
/// zero-cost wrapper.
pub struct FusedSource<S: EventSource> {
    inputs: Vec<FusedInput<S>>,
    /// Dynamic lanes adopted from serving planes while the merge runs
    /// (network clients attaching mid-stream). They occupy core lanes
    /// `inputs.len()..` and live until their client disconnects.
    clients: Vec<FusedInput<Box<dyn EventSource>>>,
    /// Serving planes discovered on the inputs ([`EventSource::client_plane`]):
    /// polled for freshly admitted clients at every merge round.
    planes: Vec<Arc<dyn ClientPlane>>,
    core: MergeCore<Event>,
    /// Batch buffer pool shared with every input ([`EventSource::
    /// set_buffer_pool`]): carry segments drained by the merge are
    /// reclaimed here once downstream drops its views, and the merge's
    /// own owned-output batches draw from it too.
    pool: Arc<ChunkPool>,
    layout: Option<SourceLayout>,
    chunk: usize,
    /// Events rejected by the layout (outside their source's geometry).
    dropped: u64,
    /// Highest timestamp emitted so far (the merge frontier).
    frontier: u64,
    /// Times an idle live source's lane stopped blocking the merge.
    stalls_broken: u64,
    /// Events that arrived behind the frontier after a heartbeat
    /// override (emitted with clamped timestamps).
    late_events: u64,
}

impl<S: EventSource> FusedSource<S> {
    /// Merge `sources` (each individually time-ordered) into one stream
    /// of at most `chunk`-event batches. `layout` offsets each source
    /// onto a shared canvas; `None` leaves coordinates untouched (the
    /// canvas is then the union bounding box of the source geometries).
    pub fn new(sources: Vec<S>, layout: Option<SourceLayout>, chunk: usize) -> Self {
        assert!(!sources.is_empty(), "FusedSource needs at least one source");
        if let Some(layout) = &layout {
            assert_eq!(
                layout.placements.len(),
                sources.len(),
                "layout placements must match source count"
            );
        }
        let n = sources.len();
        let pool = Arc::new(ChunkPool::new());
        let inputs: Vec<FusedInput<S>> = sources
            .into_iter()
            .map(|mut source| {
                source.set_buffer_pool(Arc::clone(&pool));
                let node = Arc::new(LiveNode::new(source.describe()));
                source.set_live_node(Arc::clone(&node));
                FusedInput {
                    source,
                    node,
                    idle_polls: 0,
                    idle_since: None,
                    heartbeat: false,
                }
            })
            .collect();
        let planes = inputs.iter().filter_map(|input| input.source.client_plane()).collect();
        let mut core = MergeCore::new(n);
        // Drained carry buffers park for recycling instead of dropping:
        // the sources above draw their next batches from the same pool.
        core.set_keep_drained(true);
        FusedSource {
            inputs,
            clients: Vec::new(),
            planes,
            core,
            pool,
            layout,
            chunk: chunk.max(1),
            dropped: 0,
            frontier: 0,
            stalls_broken: 0,
            late_events: 0,
        }
    }

    /// Peak events buffered across carry buffers (the merge's memory
    /// high-water mark; 0 for pass-through single-source use).
    pub fn peak_buffered(&self) -> usize {
        self.core.peak_buffered()
    }

    /// Events dropped for violating their source's layout geometry
    /// (layout rejections only; the [`EventSource::dropped`] impl also
    /// sums what the inputs discarded themselves).
    pub fn layout_dropped(&self) -> u64 {
        self.dropped
    }

    /// Times an idle live source's bounded grace expired and its lane
    /// stopped blocking the merge (fan-in stalls broken).
    pub fn stalls_broken(&self) -> u64 {
        self.stalls_broken
    }

    /// Hit/miss counters of the buffer pool shared between this merge
    /// and its sources (rolled into [`StreamReport::pool_hits`] /
    /// [`StreamReport::pool_misses`]).
    pub fn pool_counters(&self) -> PoolCounters {
        self.pool.counters()
    }

    /// Events that arrived behind the merge frontier after a heartbeat
    /// override and were clamped to it (the order cost of not
    /// stalling).
    pub fn late_events(&self) -> u64 {
        self.late_events
    }

    /// Per-source counters for [`StreamReport::sources`]: a final
    /// sample of each input's live cell, plus the source's own discard
    /// count. Static inputs first (in declaration order), then every
    /// dynamic client lane adopted during the run.
    pub fn node_reports(&self) -> Vec<NodeReport> {
        self.inputs
            .iter()
            .map(|input| (input.node.sample(), input.source.dropped()))
            .chain(
                self.clients
                    .iter()
                    .map(|client| (client.node.sample(), client.source.dropped())),
            )
            .map(|(mut report, dropped)| {
                report.dropped = dropped;
                report
            })
            .collect()
    }

    /// The serving planes discovered on the inputs (empty for ordinary
    /// topologies) — handed to the adaptive runtime so per-client
    /// windows can be sampled and retargeted.
    pub(crate) fn client_planes(&self) -> Vec<Arc<dyn ClientPlane>> {
        self.planes.clone()
    }

    /// Retarget the merged batch size (adaptive chunk controller): the
    /// merge emits at most `chunk` events per batch from now on, and
    /// every input receives the advisory
    /// [`EventSource::set_chunk_hint`].
    pub fn set_chunk(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
        for input in &mut self.inputs {
            input.source.set_chunk_hint(self.chunk);
        }
        for client in &mut self.clients {
            client.source.set_chunk_hint(self.chunk);
        }
    }

    /// Single input, no layout: forward batches untouched.
    fn next_single(&mut self) -> Result<Option<Vec<Event>>> {
        let input = &mut self.inputs[0];
        match input.source.next_batch()? {
            None => Ok(None),
            Some(batch) => {
                if !batch.is_empty() {
                    input.node.add_events(batch.len() as u64);
                    input.node.add_batch();
                }
                Ok(Some(batch))
            }
        }
    }

    /// One bounded pull on the lane `lane` (static input or dynamic
    /// client), with all heartbeat bookkeeping.
    fn poll_lane(&mut self, lane: usize) -> Result<Poll> {
        let n = self.inputs.len();
        if lane < n {
            poll_one(&mut self.inputs[lane], &mut self.core, lane, &mut self.stalls_broken)
        } else {
            poll_one(
                &mut self.clients[lane - n],
                &mut self.core,
                lane,
                &mut self.stalls_broken,
            )
        }
    }

    /// Whether `lane` is currently heartbeating (its emptiness does not
    /// block the merge).
    fn lane_heartbeat(&self, lane: usize) -> bool {
        let n = self.inputs.len();
        if lane < n {
            self.inputs[lane].heartbeat
        } else {
            self.clients[lane - n].heartbeat
        }
    }

    /// Adopt every client admitted on a serving plane since the last
    /// merge round. This is the safe point dynamic attach happens at:
    /// between pops, with nothing half-emitted. A fresh client joins
    /// with `heartbeat: true` over a non-blocking core lane, so an
    /// admitted-but-quiet connection can never stall the frontier; the
    /// first delivered batch flips it to an ordinary blocking lane.
    fn attach_clients(&mut self) {
        for p in 0..self.planes.len() {
            for client in self.planes[p].take_lanes() {
                let lane = self.core.add_lane(false);
                debug_assert_eq!(lane, self.inputs.len() + self.clients.len());
                let mut source = client.source;
                source.set_chunk_hint(self.chunk);
                source.set_buffer_pool(Arc::clone(&self.pool));
                source.set_live_node(Arc::clone(&client.node));
                self.clients.push(FusedInput {
                    source,
                    node: client.node,
                    idle_polls: 0,
                    idle_since: None,
                    heartbeat: true,
                });
            }
        }
    }

    fn next_merged(&mut self) -> Result<Option<EventChunk>> {
        self.attach_clients();
        // Refill every empty lane — one pull per input per call, so
        // each call does bounded work even over slow live sources.
        for lane in 0..self.core.lanes() {
            if !self.core.is_exhausted(lane) && self.core.lane_len(lane) == 0 {
                self.poll_lane(lane)?;
            }
        }
        if self.core.all_done() {
            return Ok(None);
        }
        if self.core.stalled() {
            // A live, still-blocking input has nothing buffered:
            // emitting now could violate global timestamp order (its
            // next event may be earlier than every buffered one).
            // Report idle upward; the driver waits a bounded amount.
            return Ok(Some(EventChunk::empty()));
        }
        self.core.note_peak();
        // The round emits whole *runs* (loser-tree winner galloped to
        // the runner-up's key). `zero` holds the round's first run
        // while it can still go out as a zero-copy view of its
        // producer's buffer; the moment a second run — or any
        // per-event transform (layout placement, frontier clamping) —
        // joins the batch, it spills into the pooled accumulator
        // `out`.
        let mut zero: Option<EventChunk> = None;
        let mut out: Vec<Event> = Vec::new();
        loop {
            let have = zero.as_ref().map_or(0, EventChunk::len) + out.len();
            if have >= self.chunk {
                break;
            }
            // Ties break to the lowest source id inside the core,
            // matching `fusion::merge_streams` determinism — run-wise
            // exactly as the per-event pop applied it.
            let Some(run) = self.core.pop_run(self.chunk - have, |ev: &Event| ev.t) else {
                break;
            };
            let i = run.lane();
            let (first_t, last_t) = {
                let events = run.as_slice();
                (events[0].t, events[events.len() - 1].t)
            };
            let needs_layout = self.layout.is_some() && i < self.inputs.len();
            if !needs_layout && first_t >= self.frontier {
                // In-order, un-transformed run: within a run the
                // producer's key order makes timestamps non-decreasing,
                // so the frontier advances straight to the run's end
                // and no event needs touching at all.
                self.frontier = last_t;
                if zero.is_none() && out.is_empty() {
                    zero = Some(run.into_chunk());
                } else {
                    if out.capacity() == 0 {
                        out = self.pool.get(self.chunk);
                    }
                    if let Some(z) = zero.take() {
                        out.extend_from_slice(z.as_slice());
                    }
                    out.extend_from_slice(run.as_slice());
                }
            } else {
                // Per-event path: layout placement for static inputs,
                // and/or frontier clamping after a heartbeat override.
                if out.capacity() == 0 {
                    out = self.pool.get(self.chunk);
                }
                if let Some(z) = zero.take() {
                    out.extend_from_slice(z.as_slice());
                }
                for &ev in run.as_slice() {
                    let mut ev = ev;
                    if ev.t < self.frontier {
                        // Possible only after a heartbeat override let
                        // the merge run ahead of this source. Clamp the
                        // straggler to the frontier (watermark
                        // semantics): downstream consumers — frame
                        // binners above all — rely on the merge's
                        // globally monotonic timestamps, so late data
                        // joins the *current* window instead of
                        // reopening an already-emitted one. Counted per
                        // event.
                        self.late_events += 1;
                        ev.t = self.frontier;
                    } else {
                        self.frontier = ev.t;
                    }
                    match &self.layout {
                        // Layout placements cover the static inputs
                        // only; a dynamic client lane already conforms
                        // to the serving plane's declared geometry (the
                        // hub filters and counts out-of-bounds events
                        // at ingest), so its events pass through
                        // unplaced.
                        Some(layout) if i < self.inputs.len() => match layout.place(i, &ev) {
                            Some(placed) => out.push(placed),
                            None => self.dropped += 1,
                        },
                        _ => out.push(ev),
                    }
                }
            }
            if self.core.lane_len(i) == 0 && !self.core.is_exhausted(i) {
                match self.poll_lane(i)? {
                    Poll::Data => self.core.note_peak(),
                    Poll::End => {}
                    Poll::Idle => {
                        if !self.lane_heartbeat(i) {
                            // Live source momentarily dry within its
                            // grace: its future timestamps are unknown,
                            // so this merge round must stop here.
                            break;
                        }
                    }
                }
            }
        }
        // Hand the carry buffers fully drained this round back to the
        // pool; they free up for reuse once downstream drops the last
        // chunk view into them (sole-owner reclaim).
        for buf in self.core.take_drained() {
            self.pool.recycle_arc(buf);
        }
        let chunk = match zero {
            Some(z) => z,
            None if out.is_empty() => EventChunk::empty(),
            None => {
                let chunk = EventChunk::from_vec(out);
                // Park the emitted buffer too: the next owned round
                // reuses it after downstream lets go.
                self.pool.recycle(&chunk);
                chunk
            }
        };
        Ok(Some(chunk))
    }

    /// Pull the next merged batch as a refcounted chunk — the
    /// zero-copy entry point the topology drivers use. Single-source
    /// pass-through wraps the batch without copying; merged rounds
    /// emit either a zero-copy run view or a pooled owned buffer.
    pub fn next_chunk(&mut self) -> Result<Option<EventChunk>> {
        // The pass-through fast path is only sound when no serving
        // plane can attach dynamic lanes behind the single input.
        if self.inputs.len() == 1 && self.layout.is_none() && self.planes.is_empty() {
            Ok(self.next_single()?.map(|batch| {
                if batch.is_empty() {
                    EventChunk::empty()
                } else {
                    EventChunk::from_vec(batch)
                }
            }))
        } else {
            self.next_merged()
        }
    }
}

impl<S: EventSource> EventSource for FusedSource<S> {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        // Legacy batch entry: the chunk either extracts for free (sole
        // owner) or pays one counted copy. Drivers use
        // [`Self::next_chunk`] directly.
        Ok(self.next_chunk()?.map(EventChunk::into_vec))
    }

    fn resolution(&self) -> Resolution {
        match &self.layout {
            Some(layout) => layout.canvas,
            None => {
                let mut res = self.inputs[0].source.resolution();
                for input in &self.inputs[1..] {
                    let r = input.source.resolution();
                    res.width = res.width.max(r.width);
                    res.height = res.height.max(r.height);
                }
                res
            }
        }
    }

    fn geometry_known(&self) -> bool {
        self.inputs.iter().all(|i| i.source.geometry_known())
    }

    fn dropped(&self) -> u64 {
        // Layout rejections plus whatever the inputs discarded
        // themselves ([`Self::layout_dropped`] reports layout-only).
        self.dropped
            + self.inputs.iter().map(|i| i.source.dropped()).sum::<u64>()
            + self.clients.iter().map(|c| c.source.dropped()).sum::<u64>()
    }

    fn set_chunk_hint(&mut self, chunk: usize) {
        self.set_chunk(chunk);
    }

    fn set_buffer_pool(&mut self, pool: Arc<ChunkPool>) {
        // Adopt the caller's pool (nested fusion) and re-distribute it
        // to every input so the whole tree recycles from one place.
        self.pool = Arc::clone(&pool);
        for input in &mut self.inputs {
            input.source.set_buffer_pool(Arc::clone(&pool));
        }
        for client in &mut self.clients {
            client.source.set_buffer_pool(Arc::clone(&pool));
        }
    }

    fn describe(&self) -> String {
        if self.inputs.len() == 1 {
            self.inputs[0].source.describe()
        } else {
            format!("fused({} sources)", self.inputs.len())
        }
    }
}

// ------------------------------------------------------------- threading

/// Executor-side end of a pinned source thread: a non-blocking
/// [`EventSource`] over the SPSC ring. An empty channel reads as a live
/// source with nothing pending; a closed channel as end of stream —
/// unless the pump recorded an error, which is surfaced *now* so a
/// failed sensor aborts the whole topology instead of looking like a
/// clean end-of-stream while its siblings keep it running forever.
struct ChannelSource<'e> {
    rx: SyncReceiver<Vec<Event>>,
    err: &'e Mutex<Option<anyhow::Error>>,
    res: Resolution,
    known: bool,
    /// Liveness of the pumped source: only live lanes may heartbeat
    /// (an empty ring for a finite source is starvation, not quiet).
    live: bool,
    name: String,
}

impl EventSource for ChannelSource<'_> {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if let Some(batch) = self.rx.try_recv() {
            grow_resolution(&mut self.res, &batch);
            return Ok(Some(batch));
        }
        if self.rx.is_closed() {
            // Drain-then-close: one more pop after observing the close.
            if let Some(batch) = self.rx.try_recv() {
                grow_resolution(&mut self.res, &batch);
                return Ok(Some(batch));
            }
            // The pump stores its error before dropping the sender, so
            // after observing the close any failure is visible here.
            if let Some(e) = self.err.lock().unwrap().take() {
                return Err(e);
            }
            return Ok(None);
        }
        Ok(Some(Vec::new()))
    }

    fn resolution(&self) -> Resolution {
        self.res
    }

    fn geometry_known(&self) -> bool {
        self.known
    }

    fn is_live(&self) -> bool {
        self.live
    }

    fn describe(&self) -> String {
        format!("thread({})", self.name)
    }
}

/// Source-thread body: pull batches and push them through the ring,
/// counting full-ring suspensions as backpressure. Exits when the
/// source ends or errors, or when the executor side hangs up.
fn pump<S: EventSource>(
    mut source: S,
    mut tx: SyncSender<Vec<Event>>,
    err: &Mutex<Option<anyhow::Error>>,
    waits: &AtomicU64,
    drops: &AtomicU64,
) {
    let mut idle = IdleBackoff::new();
    loop {
        match source.next_batch() {
            Ok(Some(batch)) => {
                if batch.is_empty() {
                    idle.wait();
                    continue;
                }
                idle.reset();
                match tx.try_send(batch) {
                    Ok(()) => {}
                    Err(batch) => {
                        waits.fetch_add(1, Ordering::Relaxed);
                        if block_on(tx.send(batch)).is_err() {
                            break; // merge side hung up
                        }
                    }
                }
            }
            Ok(None) => break,
            Err(e) => {
                *err.lock().unwrap() = Some(e);
                break;
            }
        }
    }
    // Publish the source's own discard count (the executor side only
    // sees the ring, not the source) before the sender drops.
    drops.store(source.dropped(), Ordering::Relaxed);
}

// --------------------------------------------------------------- fan-out

/// Split one processed chunk into per-sink chunks.
///
/// Broadcast is **copy-free**: every sink receives a refcount clone of
/// the same chunk. The selection policies (polarity, stripes) are
/// single-pass-counted: one scan over the chunk computes every part's
/// size, then exact-capacity parts are filled — each surviving event is
/// written once *total* (counted as `bytes_moved`), never once per
/// sink, and no part ever reallocates.
fn partition(
    processed: EventChunk,
    route: &RoutePolicy,
    canvas: Resolution,
    m: usize,
) -> Vec<EventChunk> {
    match route {
        RoutePolicy::Broadcast => vec![processed; m],
        RoutePolicy::Polarity => {
            let events = processed.as_slice();
            let on_n = events.iter().filter(|ev| ev.p.is_on()).count();
            let mut on = Vec::with_capacity(on_n);
            let mut off = Vec::with_capacity(events.len() - on_n);
            for &ev in events {
                if ev.p.is_on() {
                    on.push(ev);
                } else {
                    off.push(ev);
                }
            }
            chunk::note_events_moved(events.len());
            vec![EventChunk::from_vec(on), EventChunk::from_vec(off)]
        }
        RoutePolicy::Stripes => {
            // Same cut as the sharded stage nodes, so "stripe i" means
            // the same pixel columns at every layer.
            let events = processed.as_slice();
            let stripe = stripe_cut(canvas.width, m);
            let mut counts = vec![0usize; m];
            for ev in events {
                counts[stripe_index(ev.x, stripe, m)] += 1;
            }
            let mut parts: Vec<Vec<Event>> =
                counts.into_iter().map(Vec::with_capacity).collect();
            for &ev in events {
                parts[stripe_index(ev.x, stripe, m)].push(ev);
            }
            chunk::note_events_moved(events.len());
            parts.into_iter().map(EventChunk::from_vec).collect()
        }
    }
}

/// Attribute one partition's selection-copy traffic to the destination
/// sink nodes (broadcast moves nothing — the parts are refcount views).
fn note_partition_traffic(route: &RoutePolicy, parts: &[EventChunk], nodes: &[Arc<LiveNode>]) {
    if matches!(route, RoutePolicy::Broadcast) {
        return;
    }
    for (part, node) in parts.iter().zip(nodes) {
        if !part.is_empty() {
            node.add_bytes_moved((part.len() * EVENT_BYTES) as u64);
        }
    }
}

// ---------------------------------------------------------------- driver

/// Build the default fused layout for `resolutions`: side by side, with
/// the hard errors a silent saturating layout would otherwise hide.
/// Shared by the library driver and the coordinator (which needs the
/// canvas before the run to size its sinks).
pub fn default_layout(resolutions: &[Resolution]) -> Result<SourceLayout> {
    let layout = SourceLayout::side_by_side(resolutions);
    validate_layout(&layout)?;
    Ok(layout)
}

/// Hard-error check for a saturating layout: every placement must fit
/// its canvas in true (u32) arithmetic. The `SourceLayout` constructors
/// saturate at the u16 address space, so any clamped offset or canvas
/// shows up here as a placement spilling past the canvas — the check is
/// against the layout the merge will actually use, so validator and
/// layout math can never drift apart.
fn validate_layout(layout: &SourceLayout) -> Result<()> {
    for (i, p) in layout.placements.iter().enumerate() {
        if u32::from(p.x_offset) + u32::from(p.resolution.width)
            > u32::from(layout.canvas.width)
            || u32::from(p.y_offset) + u32::from(p.resolution.height)
                > u32::from(layout.canvas.height)
        {
            bail!(
                "source {i} at offset {},{} with geometry {}x{} exceeds the \
                 u16 address space (canvas {}x{})",
                p.x_offset,
                p.y_offset,
                p.resolution.width,
                p.resolution.height,
                layout.canvas.width,
                layout.canvas.height
            );
        }
    }
    Ok(())
}

/// Build a validated near-square grid layout (row-major cells sized to
/// the largest source).
pub fn grid_layout(resolutions: &[Resolution]) -> Result<SourceLayout> {
    let layout = SourceLayout::grid(resolutions);
    validate_layout(&layout)?;
    Ok(layout)
}

/// Build a validated layout from explicit per-source canvas offsets
/// (sources without a declared offset sit at the origin).
pub fn explicit_layout(
    resolutions: &[Resolution],
    offsets: &[(u16, u16)],
) -> Result<SourceLayout> {
    let layout = SourceLayout::at_offsets(resolutions, offsets);
    validate_layout(&layout)?;
    Ok(layout)
}

/// Counters produced by one edge drive, merged into [`StreamReport`].
/// Per-sink counters live on the telemetry plane (one
/// [`LiveNode`] per sink), not here.
struct DriveOutcome {
    events_in: u64,
    events_out: u64,
    batches: u64,
    peak_in_flight: usize,
    backpressure_waits: u64,
}

/// One fan-out branch of a topology: an optional per-branch stage
/// chain (compiled with prefixed report names by [`super::graph`]) and
/// the sink that terminates it. Legacy shapes use `graph: None` — the
/// router's partition goes straight to the sink, exactly as before the
/// graph layer existed.
pub(crate) struct BranchRun<K> {
    pub(crate) graph: Option<StageGraph>,
    pub(crate) sink: K,
    /// Branch name for error contexts (defaults to the sink description).
    pub(crate) label: String,
}

impl<K: EventSink> BranchRun<K> {
    /// Run one routed part through the branch chain (if any) and into
    /// the sink, counting delivered events on the branch's sink node.
    /// `consume_empty` preserves the single-sink drivers' historical
    /// behavior of consuming empty batches; the fan drivers skip them.
    ///
    /// Chain-free (and identity-chain) branches hand the routed chunk to
    /// the sink as-is — a borrow or refcount bump, never a copy. A real
    /// branch chain materializes its output once (counted as the node's
    /// `bytes_moved`), which is the transform's own buffer, not a
    /// routing copy.
    fn deliver(&mut self, part: EventChunk, node: &LiveNode, consume_empty: bool) -> Result<()> {
        let out = match &mut self.graph {
            Some(graph) if !graph.is_identity() && !part.is_empty() => {
                let processed = graph
                    .process_batch(part.as_slice())
                    .with_context(|| format!("branch {:?} stage", self.label))?;
                node.add_bytes_moved((processed.len() * EVENT_BYTES) as u64);
                EventChunk::from_vec(processed)
            }
            _ => part,
        };
        if !out.is_empty() {
            node.add_events(out.len() as u64);
            node.add_batch();
        } else if !consume_empty {
            return Ok(());
        }
        self.sink.consume_chunk(&out).context("stream sink")
    }
}

/// Apply the shared stage chain to one merged chunk. The identity chain
/// (no stages) passes the chunk through untouched — the refcount path
/// that keeps stateless topologies copy-free end to end; a real chain
/// materializes its output buffer once, which every branch then shares.
fn process_shared<P: BatchProcessor + ?Sized>(
    shared: &mut P,
    batch: EventChunk,
) -> Result<EventChunk> {
    if shared.is_identity() {
        Ok(batch)
    } else {
        Ok(EventChunk::from_vec(shared.process_batch(batch.as_slice())?))
    }
}

/// One fan-in lane of [`run_nodes`]: a source pulled inline on the
/// driving thread, or the executor-side tap of a source pinned to its
/// own pump thread. Threading is a per-lane decision (the graph layer
/// places it per source node); the legacy [`ThreadMode`] flag maps to
/// all-or-nothing.
enum Lane<'e, S: EventSource> {
    Direct(S),
    Pumped(ChannelSource<'e>),
}

impl<S: EventSource> EventSource for Lane<'_, S> {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        match self {
            Lane::Direct(s) => s.next_batch(),
            Lane::Pumped(s) => s.next_batch(),
        }
    }
    fn resolution(&self) -> Resolution {
        match self {
            Lane::Direct(s) => s.resolution(),
            Lane::Pumped(s) => s.resolution(),
        }
    }
    fn geometry_known(&self) -> bool {
        match self {
            Lane::Direct(s) => s.geometry_known(),
            Lane::Pumped(s) => s.geometry_known(),
        }
    }
    fn is_live(&self) -> bool {
        match self {
            Lane::Direct(s) => s.is_live(),
            Lane::Pumped(s) => s.is_live(),
        }
    }
    fn dropped(&self) -> u64 {
        match self {
            Lane::Direct(s) => s.dropped(),
            Lane::Pumped(s) => s.dropped(),
        }
    }
    fn set_chunk_hint(&mut self, chunk: usize) {
        match self {
            Lane::Direct(s) => s.set_chunk_hint(chunk),
            Lane::Pumped(s) => s.set_chunk_hint(chunk),
        }
    }
    fn set_buffer_pool(&mut self, pool: Arc<ChunkPool>) {
        match self {
            Lane::Direct(s) => s.set_buffer_pool(pool),
            // A pumped lane's batches are materialized on the pump
            // thread and cross the ring by move; recycling them from
            // the merge thread would bounce the buffers (and their
            // cache lines) back across cores, so pumped sources opt
            // out of the pool.
            Lane::Pumped(_) => {}
        }
    }
    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        match self {
            Lane::Direct(s) => s.set_live_node(node),
            // A pumped lane's real source lives on the pump thread; its
            // counters are tracked by the pump's ProducerGauges instead.
            Lane::Pumped(_) => {}
        }
    }
    fn describe(&self) -> String {
        match self {
            Lane::Direct(s) => s.describe(),
            Lane::Pumped(s) => s.describe(),
        }
    }
    fn client_plane(&self) -> Option<Arc<dyn ClientPlane>> {
        match self {
            Lane::Direct(s) => s.client_plane(),
            // A pumped lane only sees the ring; listener nodes always
            // compile inline, so their plane is never behind a pump.
            Lane::Pumped(_) => None,
        }
    }
}

/// The generalized driver under both [`run_topology`] (the legacy
/// fixed shape) and [`super::graph`] (compiled graphs): N sources —
/// each optionally pinned to its own pump thread — fan in through the
/// timestamp-ordered merge, flow through the shared processor, and fan
/// out per `route` into branches, each optionally running its own stage
/// chain before its sink.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_nodes<S, P, K>(
    sources: Vec<(S, bool)>,
    shared: &mut P,
    branches: Vec<BranchRun<K>>,
    layout: Option<SourceLayout>,
    route: RoutePolicy,
    chunk_size: usize,
    driver: StreamDriver,
    adaptive: Option<AdaptiveRuntime>,
    report_json: Option<ReportTarget>,
    decode_threads: Option<usize>,
) -> Result<StreamReport>
where
    S: EventSource,
    P: BatchProcessor + ?Sized,
    K: EventSink,
{
    if sources.is_empty() {
        bail!("topology needs at least one source");
    }
    if branches.is_empty() {
        bail!("topology needs at least one sink");
    }
    if route == RoutePolicy::Polarity && branches.len() != 2 {
        bail!("polarity routing requires exactly 2 sinks, got {}", branches.len());
    }
    let emitter = match &report_json {
        Some(target) => Some(Arc::new(ReportEmitter::open(target)?)),
        None => None,
    };
    // `--report-json` without `--adaptive`: synthesize an empty
    // controller list so the epoch clock still ticks and per-epoch
    // lines flow (nothing is retuned).
    let adaptive = match (adaptive, emitter.is_some()) {
        (None, true) => Some(AdaptiveRuntime {
            epoch_batches: DEFAULT_EPOCH_BATCHES,
            controllers: Vec::new(),
        }),
        (adaptive, _) => adaptive,
    };
    // The shared codec plane, when a decode-thread budget is set: one
    // bounded worker pool handed to every source before its lane is
    // wrapped (file pumps restart their read through it; serving-plane
    // listeners store it in their hub for client reader loops).
    let plane = decode_threads.map(|w| CodecPlane::new(CodecPlaneConfig::with_workers(w)));
    let t0 = Instant::now();
    let n = sources.len();
    let pump_errs: Vec<Mutex<Option<anyhow::Error>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let pump_waits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let pump_drops: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let mut pumped = vec![false; n];
    let result = std::thread::scope(|scope| {
        let pumped = &mut pumped;
        let mut lanes: Vec<Lane<S>> = Vec::with_capacity(n);
        for (i, (source, threaded)) in sources.into_iter().enumerate() {
            let mut source = source;
            if let Some(plane) = &plane {
                source.set_codec_plane(Arc::clone(plane));
            }
            if threaded {
                pumped[i] = true;
                let res = source.resolution();
                let known = source.geometry_known();
                let live = source.is_live();
                let name = source.describe();
                let (tx, rx) = sync_channel::<Vec<Event>>(PUMP_QUEUE_BATCHES);
                let (err, waits, drops) = (&pump_errs[i], &pump_waits[i], &pump_drops[i]);
                std::thread::Builder::new()
                    .name(format!("src:{i}"))
                    .spawn_scoped(scope, move || pump(source, tx, err, waits, drops))
                    .expect("spawn source pump thread");
                lanes.push(Lane::Pumped(ChannelSource { rx, err, res, known, live, name }));
            } else {
                lanes.push(Lane::Direct(source));
            }
        }
        let mut merged = FusedSource::new(lanes, layout, chunk_size);
        drive_and_report(
            &mut merged,
            shared,
            branches,
            route,
            driver,
            chunk_size,
            adaptive,
            emitter,
            t0,
            plane.as_deref(),
        )
        // `merged` (and with it every ring receiver) drops here, so any
        // pump still parked in a full-ring send unblocks before the
        // scope joins the threads.
    });
    // The run is over: join the decode workers before reading their
    // counters, so peaks are final and no `codec:` thread outlives the
    // topology.
    let decode = plane.map(|plane| {
        plane.shutdown();
        plane.counters()
    });
    let mut report = result?;
    if let Some(counters) = decode {
        report.decode_workers = counters.workers;
        report.decode_jobs = counters.jobs;
        report.decode_queue_depth = counters.queue_depth;
        report.decode_worker_busy = counters.worker_busy;
        report.decode_reassembly_lag = counters.reassembly_lag;
    }
    for (i, err) in pump_errs.into_iter().enumerate() {
        if let Some(e) = err.into_inner().unwrap() {
            return Err(e.context(format!("stream source {i} (thread)")));
        }
    }
    // Only the first `n` source reports are static lanes (dynamic
    // client lanes append theirs after, and are never pumped).
    for (i, node) in report.sources.iter_mut().enumerate().take(n) {
        if pumped[i] {
            node.backpressure_waits = pump_waits[i].load(Ordering::Relaxed);
            node.dropped = pump_drops[i].load(Ordering::Relaxed);
        }
    }
    Ok(report)
}

/// Drive an N-source, M-sink topology to completion.
///
/// Sources fan in through the streaming timestamp-ordered merge
/// (`layout` defaults to [`SourceLayout::side_by_side`] when several
/// sources are given), flow through the shared stage processor once —
/// a serial [`crate::pipeline::Pipeline`] or a sharded
/// [`super::StageGraph`] — and fan out per `config.route`. Memory
/// stays O(chunk × (sources + shards + sinks)).
///
/// This is the engine entry for the one fixed shape
/// `fan-in → shared chain → fan-out`; richer graphs (per-branch stage
/// chains, per-node thread placement) are described with
/// [`super::graph::Topology::builder`] and compiled onto the same
/// driver.
pub fn run_topology<S: EventSource, P: BatchProcessor, K: EventSink>(
    sources: Vec<S>,
    pipeline: &mut P,
    sinks: Vec<K>,
    layout: Option<SourceLayout>,
    config: &TopologyConfig,
) -> Result<StreamReport> {
    let adaptive = match &config.adaptive {
        Some(cfg) => Some(cfg.build().context("assembling adaptive controllers")?),
        None => None,
    };
    run_topology_with_adaptive(sources, pipeline, sinks, layout, config, adaptive)
}

/// [`run_topology`] with explicitly assembled adaptive controllers —
/// the hook for custom [`Controller`](super::Controller)
/// implementations (tests force re-cuts this way); [`run_topology`]
/// itself builds the runtime from
/// [`TopologyConfig::adaptive`].
pub fn run_topology_with_adaptive<S: EventSource, P: BatchProcessor, K: EventSink>(
    sources: Vec<S>,
    pipeline: &mut P,
    sinks: Vec<K>,
    layout: Option<SourceLayout>,
    config: &TopologyConfig,
    adaptive: Option<AdaptiveRuntime>,
) -> Result<StreamReport> {
    if sources.is_empty() {
        bail!("topology needs at least one source");
    }
    if sinks.is_empty() {
        bail!("topology needs at least one sink");
    }
    if config.route == RoutePolicy::Polarity && sinks.len() != 2 {
        bail!("polarity routing requires exactly 2 sinks, got {}", sinks.len());
    }
    if config.route == RoutePolicy::Stripes && !sources.iter().all(|s| s.geometry_known()) {
        // Stripe boundaries are cut from the canvas before the run; a
        // geometry that is only observed (1×1 at start) would degenerate
        // every stripe to the last sink.
        bail!("stripes routing requires known source geometry (declare --geometry)");
    }
    let layout = match layout {
        Some(layout) => {
            if layout.placements.len() != sources.len() {
                bail!(
                    "layout has {} placements for {} sources",
                    layout.placements.len(),
                    sources.len()
                );
            }
            Some(layout)
        }
        None if sources.len() > 1 => {
            // The default layout is fabricated from the sources' claimed
            // resolutions; a live source still reporting its observed
            // placeholder (1×1) would get a placement that rejects
            // nearly every event. Refuse rather than silently drop.
            if !sources.iter().all(|s| s.geometry_known()) {
                bail!(
                    "fusing a source with unknown geometry needs an explicit \
                     layout (or a declared source geometry)"
                );
            }
            let resolutions: Vec<Resolution> =
                sources.iter().map(|s| s.resolution()).collect();
            Some(default_layout(&resolutions)?)
        }
        None => None,
    };
    let threaded = config.threads == ThreadMode::PerSourceThread;
    let sources: Vec<(S, bool)> = sources.into_iter().map(|s| (s, threaded)).collect();
    let branches: Vec<BranchRun<K>> = sinks
        .into_iter()
        .map(|sink| {
            let label = sink.describe();
            BranchRun { graph: None, sink, label }
        })
        .collect();
    run_nodes(
        sources,
        pipeline,
        branches,
        layout,
        config.route,
        config.chunk_size,
        config.driver,
        adaptive,
        None,
        config.decode_threads,
    )
}

/// Drive the merged edge with the configured driver, then flush
/// branches and assemble the report — every per-node section
/// reconstructed from a final sample of the telemetry plane. Branch
/// stage chains contribute their (prefix-named) node reports after the
/// shared chain's.
#[allow(clippy::too_many_arguments)]
fn drive_and_report<S, P, K>(
    merged: &mut FusedSource<S>,
    shared: &mut P,
    mut branches: Vec<BranchRun<K>>,
    route: RoutePolicy,
    driver: StreamDriver,
    chunk_size: usize,
    adaptive: Option<AdaptiveRuntime>,
    emitter: Option<Arc<ReportEmitter>>,
    t0: Instant,
    plane: Option<&CodecPlane>,
) -> Result<StreamReport>
where
    S: EventSource,
    P: BatchProcessor + ?Sized,
    K: EventSink,
{
    let canvas = merged.resolution();
    let sink_nodes: Vec<Arc<LiveNode>> =
        branches.iter().map(|b| Arc::new(LiveNode::new(b.sink.describe()))).collect();
    // Sinks with internal machinery (disk-buffered edges) publish their
    // gauges straight onto the node the driver samples.
    for (branch, node) in branches.iter_mut().zip(&sink_nodes) {
        branch.sink.set_live_node(Arc::clone(node));
    }
    // Only the coroutine drivers have a bounded edge channel whose
    // full-queue suspensions mean anything; the sync loop's zero is
    // "no gauge", and backpressure-keyed controllers must know that.
    let gauged = matches!(driver, StreamDriver::Coroutine { .. });
    let mut adaptor = adaptive.map(|rt| Adaptor::new(rt, chunk_size, gauged));
    if let Some(adaptor) = adaptor.as_mut() {
        adaptor.set_planes(merged.client_planes());
        if let Some(emitter) = &emitter {
            adaptor.set_emitter(emitter.clone());
        }
    }
    let outcome = match driver {
        StreamDriver::Sync => {
            drive_sync(merged, shared, &mut branches, &route, canvas, &sink_nodes, &mut adaptor)?
        }
        StreamDriver::Coroutine { channel_capacity } => {
            let cap = channel_capacity.max(1);
            if branches.len() == 1 {
                let node = &sink_nodes[0];
                drive_coro_single(merged, shared, &mut branches[0], cap, node, &mut adaptor)?
            } else {
                drive_coro_fan(
                    merged,
                    shared,
                    &mut branches,
                    &route,
                    canvas,
                    cap,
                    &sink_nodes,
                    &mut adaptor,
                )?
            }
        }
    };
    // Join any shard workers before reading their counters — the shared
    // chain's first, then every branch chain's.
    shared.finish_stages().context("stage shutdown")?;
    let mut stages = shared.stage_reports();
    for branch in &mut branches {
        if let Some(graph) = &mut branch.graph {
            graph
                .finish_stages()
                .with_context(|| format!("branch {:?} stage shutdown", branch.label))?;
            stages.extend(graph.stage_reports());
        }
    }
    let final_res = merged.resolution();
    for branch in branches.iter_mut() {
        branch.sink.observe_geometry(final_res);
    }
    let mut frames = 0u64;
    let mut sink_reports = Vec::with_capacity(branches.len());
    for (i, branch) in branches.iter_mut().enumerate() {
        let summary = branch.sink.finish().context("stream sink finish")?;
        frames += summary.frames;
        let mut report = sink_nodes[i].sample();
        report.frames = summary.frames;
        // A ThreadedSink wrapper counts the full-ring suspensions its
        // feeder hit on the pump ring (invisible to this driver's own
        // queue accounting); fold them into the node view, along with
        // whatever the sink itself discarded (device sessions drop
        // out-of-plane events).
        report.backpressure_waits += summary.backpressure_waits;
        report.dropped += summary.dropped;
        sink_reports.push(report);
    }
    let sources = merged.node_reports();
    let all_nodes = sources.iter().chain(stages.iter()).chain(sink_reports.iter());
    let (mut bytes_moved, mut chunks_cloned) = (0u64, 0u64);
    let (mut pool_hits, mut pool_misses) = (0u64, 0u64);
    let (mut buffer_bytes_on_disk, mut buffer_records_spilled) = (0u64, 0u64);
    let (mut buffer_records_replayed, mut buffer_corrupt_records_skipped) = (0u64, 0u64);
    let mut buffer_spill_active = false;
    for node in all_nodes {
        bytes_moved += node.bytes_moved;
        chunks_cloned += node.chunks_cloned;
        pool_hits += node.pool_hits;
        pool_misses += node.pool_misses;
        buffer_bytes_on_disk += node.buffer_bytes_on_disk;
        buffer_records_spilled += node.buffer_records_spilled;
        buffer_records_replayed += node.buffer_records_replayed;
        buffer_corrupt_records_skipped += node.buffer_corrupt_records_skipped;
        buffer_spill_active |= node.buffer_spill_active;
    }
    // The fused source/merge pool counts for itself (its gets are not
    // attributed to any single node); stage-graph pools counted above.
    let merge_pool = merged.pool_counters();
    pool_hits += merge_pool.hits;
    pool_misses += merge_pool.misses;
    // Plane counters snapshot at drive end: the sources are exhausted,
    // so the queue has drained — run_nodes re-reads them after the
    // worker join for the returned report.
    let decode = plane.map(CodecPlane::counters).unwrap_or_default();
    let report = StreamReport {
        events_in: outcome.events_in,
        events_out: outcome.events_out,
        frames,
        batches: outcome.batches,
        peak_in_flight: outcome.peak_in_flight,
        backpressure_waits: outcome.backpressure_waits,
        wall: t0.elapsed(),
        resolution: final_res,
        sources,
        stages,
        sinks: sink_reports,
        bytes_moved,
        chunks_cloned,
        pool_hits,
        pool_misses,
        merge_peak_buffered: merged.peak_buffered(),
        merge_dropped: merged.layout_dropped(),
        merge_stalls_broken: merged.stalls_broken(),
        merge_late_events: merged.late_events(),
        adaptive: adaptor.map(Adaptor::finish),
        decode_workers: decode.workers,
        decode_jobs: decode.jobs,
        decode_queue_depth: decode.queue_depth,
        decode_worker_busy: decode.worker_busy,
        decode_reassembly_lag: decode.reassembly_lag,
        buffer_bytes_on_disk,
        buffer_records_spilled,
        buffer_records_replayed,
        buffer_corrupt_records_skipped,
        buffer_spill_active,
    };
    if let Some(emitter) = &emitter {
        emitter.emit_final(&report)?;
    }
    Ok(report)
}

/// Baseline driver: one loop, no overlap, any fan-out width.
#[allow(clippy::too_many_arguments)]
fn drive_sync<S, P, K>(
    source: &mut FusedSource<S>,
    shared: &mut P,
    branches: &mut [BranchRun<K>],
    route: &RoutePolicy,
    canvas: Resolution,
    sink_nodes: &[Arc<LiveNode>],
    adaptor: &mut Option<Adaptor>,
) -> Result<DriveOutcome>
where
    S: EventSource,
    P: BatchProcessor + ?Sized,
    K: EventSink,
{
    let m = branches.len();
    let mut outcome = DriveOutcome {
        events_in: 0,
        events_out: 0,
        batches: 0,
        peak_in_flight: 0,
        backpressure_waits: 0,
    };
    let mut idle = IdleBackoff::new();
    while let Some(batch) = source.next_chunk().context("stream source")? {
        if batch.is_empty() {
            idle.wait(); // live source idle: bounded escalating wait
            continue;
        }
        idle.reset();
        outcome.events_in += batch.len() as u64;
        outcome.batches += 1;
        outcome.peak_in_flight = outcome.peak_in_flight.max(batch.len());
        let processed = process_shared(shared, batch).context("pipeline stage")?;
        outcome.events_out += processed.len() as u64;
        if m == 1 {
            branches[0].deliver(processed, &sink_nodes[0], true)?;
        } else if !processed.is_empty() {
            // Broadcast parts are refcount views of one buffer, so the
            // uniform partition path is as copy-free as the old
            // borrow-the-batch special case was.
            let parts = partition(processed, route, canvas, m);
            note_partition_traffic(route, &parts, sink_nodes);
            for (i, part) in parts.into_iter().enumerate() {
                if part.is_empty() {
                    continue;
                }
                branches[i].deliver(part, &sink_nodes[i], false)?;
            }
        }
        if let Some(adaptor) = adaptor.as_mut() {
            if let Some(chunk) = adaptor
                .after_batch(&mut *shared, outcome.events_in, outcome.backpressure_waits)
                .context("adaptive reconfiguration")?
            {
                source.set_chunk(chunk);
            }
        }
    }
    Ok(outcome)
}

/// Producer-side counters shared by the coroutine drivers (single-cell
/// interior mutability: everything runs on one executor thread).
#[derive(Default)]
struct ProducerGauges {
    events_in: Cell<u64>,
    batches: Cell<u64>,
    in_flight: Cell<usize>,
    peak_in_flight: Cell<usize>,
    backpressure_waits: Cell<u64>,
}

/// Spawn the shared producer coroutine: pull batches from the merged
/// source, count them, and push them into the edge channel with
/// try-then-suspend backpressure accounting. Used by both coroutine
/// drivers so the pull/backoff/error logic cannot diverge.
/// `chunk_request` is the consumer side's mailbox for adaptive chunk
/// changes (same executor thread, so a plain `Cell` suffices): the
/// producer applies a pending request before its next pull.
fn spawn_producer<'a, S: EventSource>(
    ex: &LocalExecutor<'a>,
    source: &'a mut FusedSource<S>,
    tx: Sender<EventChunk>,
    gauges: &'a ProducerGauges,
    source_err: &'a RefCell<Option<anyhow::Error>>,
    chunk_request: &'a Cell<Option<usize>>,
) {
    ex.spawn(async move {
        let mut idle = IdleBackoff::new();
        loop {
            if let Some(chunk) = chunk_request.take() {
                source.set_chunk(chunk);
            }
            let batch = match source.next_chunk() {
                Ok(Some(batch)) => batch,
                Ok(None) => break,
                Err(e) => {
                    *source_err.borrow_mut() = Some(e);
                    break;
                }
            };
            if batch.is_empty() {
                // Live source with nothing pending: let the consumer
                // drain, then wait a bounded, escalating amount instead
                // of spinning.
                yield_now().await;
                idle.wait();
                continue;
            }
            idle.reset();
            let n = batch.len();
            gauges.events_in.set(gauges.events_in.get() + n as u64);
            gauges.batches.set(gauges.batches.get() + 1);
            // The merge already emitted a refcounted chunk (a
            // zero-copy run view or a pooled owned buffer); the whole
            // downstream graph shares it — a pointer move, no copy.
            match tx.try_send(batch) {
                Ok(()) => {}
                Err(TrySendError::Closed(_)) => break, // consumer died
                Err(TrySendError::Full(batch)) => {
                    gauges.backpressure_waits.set(gauges.backpressure_waits.get() + 1);
                    if tx.send(batch).await.is_err() {
                        break;
                    }
                }
            }
            gauges.in_flight.set(gauges.in_flight.get() + n);
            gauges
                .peak_in_flight
                .set(gauges.peak_in_flight.get().max(gauges.in_flight.get()));
        }
        // `tx` drops here, letting the consumer observe the close.
    });
}

/// Coroutine driver, single branch: producer and consumer tasks on one
/// cooperative executor, batches handed through a bounded channel. The
/// producer suspends the moment the consumer is behind, which is the
/// backpressure that keeps memory O(chunk) for endless sources.
fn drive_coro_single<S, P, K>(
    source: &mut FusedSource<S>,
    shared: &mut P,
    branch: &mut BranchRun<K>,
    channel_capacity: usize,
    sink_node: &Arc<LiveNode>,
    adaptor: &mut Option<Adaptor>,
) -> Result<DriveOutcome>
where
    S: EventSource,
    P: BatchProcessor + ?Sized,
    K: EventSink,
{
    let gauges = ProducerGauges::default();
    let events_out = Cell::new(0u64);
    let chunk_request: Cell<Option<usize>> = Cell::new(None);
    let source_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let stage_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let sink_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);

    {
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel::<EventChunk>(channel_capacity);
        spawn_producer(&ex, source, tx, &gauges, &source_err, &chunk_request);

        // ---------------------------------------------------- consumer
        {
            let events_out = &events_out;
            let gauges = &gauges;
            let chunk_request = &chunk_request;
            let (stage_err, sink_err) = (&stage_err, &sink_err);
            let shared = &mut *shared;
            let branch = &mut *branch;
            let adaptor = &mut *adaptor;
            let sink_node = sink_node.clone();
            ex.spawn(async move {
                while let Some(batch) = rx.recv().await {
                    gauges.in_flight.set(gauges.in_flight.get() - batch.len());
                    let processed = match process_shared(shared, batch) {
                        Ok(processed) => processed,
                        Err(e) => {
                            *stage_err.borrow_mut() = Some(e);
                            break; // dropping `rx` fails producer sends fast
                        }
                    };
                    events_out.set(events_out.get() + processed.len() as u64);
                    if let Err(e) = branch.deliver(processed, &sink_node, true) {
                        *sink_err.borrow_mut() = Some(e);
                        break; // dropping `rx` fails producer sends fast
                    }
                    if let Some(adaptor) = adaptor.as_mut() {
                        match adaptor.after_batch(
                            &mut *shared,
                            gauges.events_in.get(),
                            gauges.backpressure_waits.get(),
                        ) {
                            Ok(Some(chunk)) => chunk_request.set(Some(chunk)),
                            Ok(None) => {}
                            Err(e) => {
                                *stage_err.borrow_mut() =
                                    Some(e.context("adaptive reconfiguration"));
                                break;
                            }
                        }
                    }
                }
            });
        }

        ex.run();
    }

    if let Some(e) = source_err.into_inner() {
        return Err(e.context("stream source"));
    }
    if let Some(e) = stage_err.into_inner() {
        return Err(e.context("pipeline stage"));
    }
    if let Some(e) = sink_err.into_inner() {
        // `deliver` already attached the branch/sink context.
        return Err(e);
    }
    Ok(DriveOutcome {
        events_in: gauges.events_in.get(),
        events_out: events_out.get(),
        batches: gauges.batches.get(),
        peak_in_flight: gauges.peak_in_flight.get(),
        backpressure_waits: gauges.backpressure_waits.get(),
    })
}

/// Coroutine driver, M ≥ 2 branches: producer → router → per-branch
/// tasks, all cooperative on one executor. The router applies the
/// shared chain once and distributes per [`RoutePolicy`]; each branch
/// sits behind its own bounded channel and runs its own stage chain (if
/// any) inside its task, so a slow branch backpressures the router (and
/// transitively the producer) without blocking its siblings' queues.
#[allow(clippy::too_many_arguments)]
fn drive_coro_fan<S, P, K>(
    source: &mut FusedSource<S>,
    shared: &mut P,
    branches: &mut [BranchRun<K>],
    route: &RoutePolicy,
    canvas: Resolution,
    channel_capacity: usize,
    sink_nodes: &[Arc<LiveNode>],
    adaptor: &mut Option<Adaptor>,
) -> Result<DriveOutcome>
where
    S: EventSource,
    P: BatchProcessor + ?Sized,
    K: EventSink,
{
    let m = branches.len();
    let gauges = ProducerGauges::default();
    let events_out = Cell::new(0u64);
    let chunk_request: Cell<Option<usize>> = Cell::new(None);
    let source_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let stage_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let sink_errs: Vec<RefCell<Option<anyhow::Error>>> =
        (0..m).map(|_| RefCell::new(None)).collect();

    {
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel::<EventChunk>(channel_capacity);
        spawn_producer(&ex, source, tx, &gauges, &source_err, &chunk_request);

        // ------------------------------------------------- branch tasks
        let mut sink_txs = Vec::with_capacity(m);
        for (i, branch) in branches.iter_mut().enumerate() {
            let (stx, mut srx) = channel::<EventChunk>(channel_capacity);
            sink_txs.push(stx);
            let err = &sink_errs[i];
            let node = sink_nodes[i].clone();
            ex.spawn(async move {
                while let Some(part) = srx.recv().await {
                    if let Err(e) = branch.deliver(part, &node, false) {
                        *err.borrow_mut() = Some(e);
                        break; // dropping `srx` fails router sends fast
                    }
                }
            });
        }

        // ------------------------------------------------------- router
        {
            let events_out = &events_out;
            let gauges = &gauges;
            let chunk_request = &chunk_request;
            let stage_err = &stage_err;
            let shared = &mut *shared;
            let adaptor = &mut *adaptor;
            let sink_nodes = sink_nodes.to_vec();
            let route = *route;
            ex.spawn(async move {
                let txs = sink_txs;
                'route: while let Some(batch) = rx.recv().await {
                    gauges.in_flight.set(gauges.in_flight.get() - batch.len());
                    let processed = match process_shared(shared, batch) {
                        Ok(processed) => processed,
                        Err(e) => {
                            *stage_err.borrow_mut() = Some(e);
                            break 'route; // dropping `rx` stops the producer
                        }
                    };
                    events_out.set(events_out.get() + processed.len() as u64);
                    if !processed.is_empty() {
                        let parts = partition(processed, &route, canvas, m);
                        note_partition_traffic(&route, &parts, &sink_nodes);
                        for (i, part) in parts.into_iter().enumerate() {
                            if part.is_empty() {
                                continue;
                            }
                            match txs[i].try_send(part) {
                                Ok(()) => {}
                                Err(TrySendError::Full(part)) => {
                                    sink_nodes[i].add_backpressure_wait();
                                    if txs[i].send(part).await.is_err() {
                                        // Branch tasks only hang up on error:
                                        // abort the whole topology promptly
                                        // (parity with the single-sink path)
                                        // instead of streaming on until every
                                        // branch dies.
                                        break 'route;
                                    }
                                }
                                Err(TrySendError::Closed(_)) => break 'route,
                            }
                        }
                    }
                    if let Some(adaptor) = adaptor.as_mut() {
                        match adaptor.after_batch(
                            &mut *shared,
                            gauges.events_in.get(),
                            gauges.backpressure_waits.get(),
                        ) {
                            Ok(Some(chunk)) => chunk_request.set(Some(chunk)),
                            Ok(None) => {}
                            Err(e) => {
                                *stage_err.borrow_mut() =
                                    Some(e.context("adaptive reconfiguration"));
                                break 'route;
                            }
                        }
                    }
                }
                // Dropping `rx` stops the producer; dropping `txs` lets
                // the surviving branch tasks drain their queues and end.
            });
        }

        ex.run();
    }

    if let Some(e) = source_err.into_inner() {
        return Err(e.context("stream source"));
    }
    if let Some(e) = stage_err.into_inner() {
        return Err(e.context("pipeline stage"));
    }
    for err in sink_errs {
        if let Some(e) = err.into_inner() {
            // `deliver` already attached the branch/sink context.
            return Err(e);
        }
    }
    Ok(DriveOutcome {
        events_in: gauges.events_in.get(),
        events_out: events_out.get(),
        batches: gauges.batches.get(),
        peak_in_flight: gauges.peak_in_flight.get(),
        backpressure_waits: gauges.backpressure_waits.get(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::validate_stream;
    use crate::pipeline::{fusion, Pipeline};
    use crate::stream::{MemorySource, NullSink};
    use crate::testutil::synthetic_events_seeded;

    fn mem(events: Vec<Event>, res: Resolution, chunk: usize) -> MemorySource {
        MemorySource::new(events, res, chunk)
    }

    #[test]
    fn streaming_merge_matches_batch_fusion() {
        let res = Resolution::new(64, 48);
        let a = synthetic_events_seeded(700, 64, 48, 11);
        let b = synthetic_events_seeded(300, 64, 48, 22);
        let c = synthetic_events_seeded(500, 64, 48, 33);
        let layout = SourceLayout::side_by_side(&[res, res, res]);
        let (expected, expected_dropped) = fusion::fuse(&[&a, &b, &c], &layout);

        for chunk in [1usize, 3, 64, 4096] {
            let sources = vec![
                mem(a.clone(), res, chunk),
                mem(b.clone(), res, chunk),
                mem(c.clone(), res, chunk),
            ];
            let mut fused = FusedSource::new(sources, Some(layout.clone()), chunk);
            let mut got = Vec::new();
            while let Some(batch) = fused.next_batch().unwrap() {
                got.extend(batch);
            }
            assert_eq!(got, expected, "chunk {chunk}");
            assert_eq!(fused.dropped(), expected_dropped);
            assert!(
                fused.peak_buffered() <= 3 * chunk,
                "chunk {chunk}: peak {} exceeds sources × chunk",
                fused.peak_buffered()
            );
            assert_eq!(validate_stream(&got, layout.canvas), None);
        }
    }

    #[test]
    fn single_source_passes_through_unchanged() {
        let res = Resolution::new(32, 32);
        let events = synthetic_events_seeded(500, 32, 32, 7);
        let mut fused = FusedSource::new(vec![mem(events.clone(), res, 128)], None, 128);
        assert_eq!(fused.resolution(), res);
        let mut got = Vec::new();
        while let Some(batch) = fused.next_batch().unwrap() {
            got.extend(batch);
        }
        assert_eq!(got, events);
        assert_eq!(fused.peak_buffered(), 0, "pass-through must not buffer");
        assert_eq!(fused.node_reports()[0].events, 500);
    }

    #[test]
    fn broadcast_fan_out_reaches_every_sink() {
        let res = Resolution::new(64, 64);
        let a = synthetic_events_seeded(600, 64, 64, 1);
        let b = synthetic_events_seeded(400, 64, 64, 2);
        let sources = vec![mem(a, res, 128), mem(b, res, 128)];
        let sinks = vec![NullSink::default(), NullSink::default(), NullSink::default()];
        let config = TopologyConfig { chunk_size: 128, ..Default::default() };
        let report =
            run_topology(sources, &mut Pipeline::new(), sinks, None, &config).unwrap();
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.events_out, 1000);
        assert_eq!(report.resolution, Resolution::new(128, 64));
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.sources[0].events, 600);
        assert_eq!(report.sources[1].events, 400);
        assert_eq!(report.sinks.len(), 3);
        for sink in &report.sinks {
            assert_eq!(sink.events, 1000, "broadcast must reach {}", sink.name);
        }
    }

    #[test]
    fn polarity_routing_splits_exactly() {
        let res = Resolution::new(64, 64);
        let events = synthetic_events_seeded(2000, 64, 64, 3);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        let config = TopologyConfig {
            route: RoutePolicy::Polarity,
            chunk_size: 256,
            ..Default::default()
        };
        let report = run_topology(
            vec![mem(events, res, 256)],
            &mut Pipeline::new(),
            vec![NullSink::default(), NullSink::default()],
            None,
            &config,
        )
        .unwrap();
        assert_eq!(report.sinks[0].events, on);
        assert_eq!(report.sinks[1].events, 2000 - on);
    }

    #[test]
    fn polarity_routing_rejects_wrong_sink_count() {
        let res = Resolution::new(8, 8);
        let config = TopologyConfig { route: RoutePolicy::Polarity, ..Default::default() };
        let err = run_topology(
            vec![mem(Vec::new(), res, 16)],
            &mut Pipeline::new(),
            vec![NullSink::default()],
            None,
            &config,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("polarity"));
    }

    #[test]
    fn stripes_cover_every_event_once() {
        let res = Resolution::new(90, 30);
        let events = synthetic_events_seeded(1500, 90, 30, 9);
        let config = TopologyConfig {
            route: RoutePolicy::Stripes,
            chunk_size: 128,
            ..Default::default()
        };
        let report = run_topology(
            vec![mem(events, res, 128)],
            &mut Pipeline::new(),
            vec![NullSink::default(), NullSink::default(), NullSink::default()],
            None,
            &config,
        )
        .unwrap();
        let routed: u64 = report.sinks.iter().map(|s| s.events).sum();
        assert_eq!(routed, 1500, "stripes must partition, not duplicate");
        assert!(report.sinks.iter().all(|s| s.events > 0), "90px / 3 stripes: all hit");
    }

    #[test]
    fn per_source_threads_deliver_everything_in_order() {
        let res = Resolution::new(64, 64);
        let a = synthetic_events_seeded(5000, 64, 64, 4);
        let b = synthetic_events_seeded(5000, 64, 64, 5);
        let config = TopologyConfig {
            chunk_size: 256,
            threads: ThreadMode::PerSourceThread,
            ..Default::default()
        };
        let report = run_topology(
            vec![mem(a, res, 256), mem(b, res, 256)],
            &mut Pipeline::new(),
            vec![NullSink::default()],
            None,
            &config,
        )
        .unwrap();
        assert_eq!(report.events_in, 10_000);
        assert_eq!(report.events_out, 10_000);
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.sources[0].events + report.sources[1].events, 10_000);
        assert!(
            report.merge_peak_buffered <= 2 * 256,
            "merge buffered {} exceeds sources × chunk",
            report.merge_peak_buffered
        );
    }

    #[test]
    fn threaded_source_error_propagates() {
        struct Failing(u32);
        impl EventSource for Failing {
            fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
                self.0 += 1;
                if self.0 < 3 {
                    Ok(Some(vec![Event::on(0, 0, u64::from(self.0))]))
                } else {
                    anyhow::bail!("sensor unplugged")
                }
            }
            fn resolution(&self) -> Resolution {
                Resolution::new(4, 4)
            }
        }
        let config =
            TopologyConfig { threads: ThreadMode::PerSourceThread, ..Default::default() };
        let err = run_topology(
            vec![Failing(0)],
            &mut Pipeline::new(),
            vec![NullSink::default()],
            None,
            &config,
        )
        .unwrap_err();
        assert!(format!("{err:?}").contains("sensor unplugged"));
    }

    /// A live source: a few events, then a stretch of "nothing pending"
    /// empty batches, then (optionally) more events, then EOF.
    struct Intermittent {
        phases: Vec<IntermittentPhase>,
        at: usize,
    }
    enum IntermittentPhase {
        Events(Vec<Event>),
        IdlePolls(u32),
    }
    impl Intermittent {
        fn new(phases: Vec<IntermittentPhase>) -> Self {
            Intermittent { phases, at: 0 }
        }
    }
    impl EventSource for Intermittent {
        fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
            loop {
                match self.phases.first_mut() {
                    None => return Ok(None),
                    Some(IntermittentPhase::Events(events)) => {
                        if self.at >= events.len() {
                            self.phases.remove(0);
                            self.at = 0;
                            continue;
                        }
                        let batch = events[self.at..].to_vec();
                        self.at = events.len();
                        return Ok(Some(batch));
                    }
                    Some(IntermittentPhase::IdlePolls(left)) => {
                        if *left == 0 {
                            self.phases.remove(0);
                            continue;
                        }
                        *left -= 1;
                        return Ok(Some(Vec::new()));
                    }
                }
            }
        }
        fn resolution(&self) -> Resolution {
            Resolution::new(64, 64)
        }
        fn geometry_known(&self) -> bool {
            true
        }
        fn is_live(&self) -> bool {
            true // empty batches mean "quiet wire", so heartbeats apply
        }
        fn describe(&self) -> String {
            "intermittent".into()
        }
    }

    /// One fused lane for the heartbeat tests: a finite in-memory
    /// source or a quiet-then-bursty live one.
    enum Lane {
        Mem(MemorySource),
        Quiet(Intermittent),
    }
    impl EventSource for Lane {
        fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
            match self {
                Lane::Mem(s) => s.next_batch(),
                Lane::Quiet(s) => s.next_batch(),
            }
        }
        fn resolution(&self) -> Resolution {
            match self {
                Lane::Mem(s) => s.resolution(),
                Lane::Quiet(s) => s.resolution(),
            }
        }
        fn is_live(&self) -> bool {
            matches!(self, Lane::Quiet(_))
        }
    }

    #[test]
    fn heartbeat_breaks_fan_in_stall_of_idle_live_source() {
        // Source A delivers everything immediately; source B goes quiet
        // long past the heartbeat grace before EOF. Without heartbeats
        // the merge would emit nothing until B ends; with them, A's
        // events flow while B idles, and the stall is counted.
        let a = synthetic_events_seeded(500, 64, 64, 41);
        let quiet = Intermittent::new(vec![
            IntermittentPhase::Events(vec![Event::on(1, 1, 5)]),
            IntermittentPhase::IdlePolls(HEARTBEAT_POLLS * 3),
        ]);
        let res = Resolution::new(64, 64);
        let layout = SourceLayout::side_by_side(&[res, res]);

        let sources = vec![Lane::Mem(MemorySource::new(a, res, 64)), Lane::Quiet(quiet)];
        let mut fused = FusedSource::new(sources, Some(layout), 64);
        let mut got = Vec::new();
        let mut polls = 0u32;
        loop {
            match fused.next_batch().unwrap() {
                None => break,
                Some(batch) => got.extend(batch),
            }
            polls += 1;
            assert!(polls < 10_000, "merge failed to progress past the idle source");
        }
        assert_eq!(got.len(), 501, "both sources' events must arrive");
        assert!(fused.stalls_broken() >= 1, "the broken stall must be counted");
        // B's lone event (t=5) lands before the heartbeat kicks in, so
        // nothing is late here.
        assert_eq!(fused.late_events(), 0);
    }

    #[test]
    fn late_events_after_heartbeat_are_delivered_and_counted() {
        // B idles past the grace (frontier advances over A), then wakes
        // with old timestamps: they must still be delivered, counted.
        let a: Vec<Event> = (0..200u64).map(|t| Event::on(2, 2, t * 10)).collect();
        let b_late = vec![Event::on(3, 3, 50), Event::on(3, 3, 60)];
        let quiet = Intermittent::new(vec![
            IntermittentPhase::IdlePolls(HEARTBEAT_POLLS * 2),
            IntermittentPhase::Events(b_late),
        ]);
        let res = Resolution::new(64, 64);

        let layout = SourceLayout::overlay(&[res, res]);
        let sources = vec![Lane::Mem(MemorySource::new(a, res, 16)), Lane::Quiet(quiet)];
        let mut fused = FusedSource::new(sources, Some(layout), 16);
        let mut got = Vec::new();
        let mut polls = 0u32;
        loop {
            match fused.next_batch().unwrap() {
                None => break,
                Some(batch) => got.extend(batch),
            }
            polls += 1;
            assert!(polls < 10_000, "merge failed to progress");
        }
        assert_eq!(got.len(), 202, "late events must not be dropped");
        assert!(fused.stalls_broken() >= 1);
        assert!(
            fused.late_events() >= 1,
            "events behind the frontier must be counted late"
        );
        // Late stragglers are clamped, so the merged stream is still
        // globally monotonic — the contract frame binners rely on.
        assert!(
            got.windows(2).all(|w| w[0].t <= w[1].t),
            "clamped output must stay time-ordered"
        );
    }

    #[test]
    fn exhausted_sources_never_heartbeat() {
        let res = Resolution::new(32, 32);
        let a = synthetic_events_seeded(300, 32, 32, 1);
        let b = synthetic_events_seeded(300, 32, 32, 2);
        let layout = SourceLayout::side_by_side(&[res, res]);
        let mut fused = FusedSource::new(
            vec![MemorySource::new(a, res, 32), MemorySource::new(b, res, 32)],
            Some(layout),
            32,
        );
        while let Some(_batch) = fused.next_batch().unwrap() {}
        assert_eq!(fused.stalls_broken(), 0, "finite sources need no heartbeats");
        assert_eq!(fused.late_events(), 0);
    }

    #[test]
    fn idle_backoff_escalates_and_resets() {
        let mut idle = IdleBackoff::new();
        for _ in 0..IdleBackoff::YIELDS {
            idle.wait(); // yield region: must not panic or sleep long
        }
        assert_eq!(idle.streak, IdleBackoff::YIELDS);
        idle.wait(); // first sleep step (50 µs)
        assert!(idle.streak > IdleBackoff::YIELDS);
        idle.reset();
        assert_eq!(idle.streak, 0);
    }
}
