//! The zero-copy batch currency: refcounted immutable event chunks.
//!
//! The paper's throughput argument is about *memory operations per
//! event*: coroutine handoff beats thread handoff because nothing is
//! copied between stages. Our topology layer used to undermine that by
//! cloning `Vec<Event>` at every broadcast branch, stripe scatter, and
//! client lane. [`EventChunk`] replaces the owned `Vec<Event>` as the
//! unit that moves between topology nodes:
//!
//! * a chunk wraps its buffer in an [`Arc`], so **broadcast is a
//!   refcount bump** — N sinks read the same allocation;
//! * [`EventChunk::slice`] is a range view (offset + length into the
//!   shared buffer) — **re-slicing is free**;
//! * stateless consumers borrow [`EventChunk::as_slice`]; stateful
//!   consumers that genuinely need ownership go through the
//!   copy-on-write [`EventChunk::into_vec`], which reuses the buffer
//!   when the chunk is the sole owner and only then falls back to a
//!   counted copy.
//!
//! The buffer is `Arc<Vec<Event>>` rather than `Arc<[Event]>`: a
//! `Vec<T>` converts to `Arc<[T]>` only by copying every element into a
//! fresh allocation (the refcount header must precede the data), which
//! would reintroduce exactly the per-batch copy this type exists to
//! remove. Wrapping the `Vec` keeps `from_vec` a pointer move at the
//! cost of one extra indirection on access.
//!
//! ## Copy accounting
//!
//! Every deep copy is counted, so "zero-copy" is an asserted property
//! rather than a hope:
//!
//! * process-wide counters ([`copy_counters`]/[`CopyCounters::delta`])
//!   feed the bench suite's `bytes_moved_per_event` column — benches run
//!   sequentially, so global deltas are exact there;
//! * per-node counters live on [`crate::metrics::LiveNode`]
//!   (`bytes_moved`/`chunks_cloned`) and surface in
//!   [`crate::stream::StreamReport`] — per-run objects, so parallel
//!   `cargo test` runs cannot pollute each other's assertions.
//!
//! `chunks_cloned` counts whole-batch deep copies (a [`to_vec`] or a
//! counted [`into_vec`]); `bytes_moved` additionally counts selection
//! copies (polarity/stripe scatter writes each surviving event once into
//! its destination part). A broadcast therefore moves zero bytes, and a
//! stripe scatter moves each event once *total* — not once per sink.
//!
//! [`to_vec`]: EventChunk::to_vec

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::aer::Event;

/// Size of one event in the in-memory representation (16 bytes: packed
/// `(t: u64, x: u16, y: u16, p)` plus padding). Copy counters measure
/// bytes as `events × EVENT_BYTES`.
pub const EVENT_BYTES: usize = std::mem::size_of::<Event>();

/// Process-wide count of whole-chunk deep copies.
static CHUNKS_CLONED: AtomicU64 = AtomicU64::new(0);
/// Process-wide count of event bytes physically copied between buffers.
static BYTES_MOVED: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-wide copy counters (see [`copy_counters`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CopyCounters {
    /// Whole-chunk deep copies since process start.
    pub chunks_cloned: u64,
    /// Event bytes physically copied since process start.
    pub bytes_moved: u64,
}

impl CopyCounters {
    /// Counters accumulated since an earlier snapshot.
    pub fn delta(&self, since: &CopyCounters) -> CopyCounters {
        CopyCounters {
            chunks_cloned: self.chunks_cloned - since.chunks_cloned,
            bytes_moved: self.bytes_moved - since.bytes_moved,
        }
    }
}

/// Read the process-wide copy counters. Exact only when nothing else is
/// streaming concurrently (the bench suite's situation); tests that run
/// in parallel must assert on the per-node counters in
/// [`crate::stream::StreamReport`] instead.
pub fn copy_counters() -> CopyCounters {
    CopyCounters {
        chunks_cloned: CHUNKS_CLONED.load(Ordering::Relaxed),
        bytes_moved: BYTES_MOVED.load(Ordering::Relaxed),
    }
}

/// Record a whole-chunk deep copy of `events` events.
pub(crate) fn note_chunk_cloned(events: usize) {
    CHUNKS_CLONED.fetch_add(1, Ordering::Relaxed);
    note_events_moved(events);
}

/// Record `events` events copied between buffers (selection copies:
/// polarity split, stripe scatter, stage output materialization).
pub(crate) fn note_events_moved(events: usize) {
    BYTES_MOVED.fetch_add((events * EVENT_BYTES) as u64, Ordering::Relaxed);
}

/// A refcounted, immutable view of a batch of events.
///
/// `Clone` is a refcount bump (never counted as a copy). The underlying
/// buffer is immutable for the chunk's whole life, so views handed to
/// concurrent sinks can never observe torn writes.
#[derive(Clone)]
pub struct EventChunk {
    buf: Arc<Vec<Event>>,
    start: usize,
    len: usize,
}

impl EventChunk {
    /// Wrap an owned buffer without copying (the zero-cost entry point
    /// used by sources and stage outputs).
    pub fn from_vec(events: Vec<Event>) -> EventChunk {
        let len = events.len();
        EventChunk { buf: Arc::new(events), start: 0, len }
    }

    /// Build a chunk by **copying** a slice (counted). Legacy bridge for
    /// callers that only hold a borrow.
    pub fn from_slice(events: &[Event]) -> EventChunk {
        note_chunk_cloned(events.len());
        EventChunk::from_vec(events.to_vec())
    }

    /// The empty chunk. Every call clones one process-wide shared
    /// buffer — stall polls and idle heartbeats that emit empties on
    /// the hot path cost a refcount bump, not an allocation.
    pub fn empty() -> EventChunk {
        static EMPTY: OnceLock<Arc<Vec<Event>>> = OnceLock::new();
        let buf = EMPTY.get_or_init(|| Arc::new(Vec::new()));
        EventChunk { buf: Arc::clone(buf), start: 0, len: 0 }
    }

    /// Reassemble a chunk from a shared buffer and a range — the
    /// merge's zero-copy run-emission path. Counterpart of
    /// [`into_parts`](Self::into_parts); never counted.
    pub(crate) fn from_parts(buf: Arc<Vec<Event>>, start: usize, len: usize) -> EventChunk {
        debug_assert!(start + len <= buf.len(), "parts out of bounds");
        EventChunk { buf, start, len }
    }

    /// Decompose the view into its shared buffer and range (free).
    pub(crate) fn into_parts(self) -> (Arc<Vec<Event>>, usize, usize) {
        (self.buf, self.start, self.len)
    }

    /// Borrow the shared backing buffer (for pool recycling, which
    /// needs the `Arc` identity rather than the event data).
    pub(crate) fn shared_buf(&self) -> &Arc<Vec<Event>> {
        &self.buf
    }

    /// Number of events in this view.
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` if the view contains no events.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the events. Free; this is how stateless consumers read.
    pub fn as_slice(&self) -> &[Event] {
        &self.buf[self.start..self.start + self.len]
    }

    /// A sub-view of this chunk (relative to this view). Free: shares
    /// the buffer, bumps the refcount.
    ///
    /// # Panics
    /// If the range exceeds the view.
    pub fn slice(&self, range: Range<usize>) -> EventChunk {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "slice {range:?} out of bounds for chunk of {}",
            self.len
        );
        EventChunk {
            buf: Arc::clone(&self.buf),
            start: self.start + range.start,
            len: range.end - range.start,
        }
    }

    /// How many chunks currently share this buffer (diagnostics/tests).
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.buf)
    }

    /// Deep-copy the view into an owned `Vec` (counted).
    pub fn to_vec(&self) -> Vec<Event> {
        note_chunk_cloned(self.len);
        self.as_slice().to_vec()
    }

    /// Copy-on-write extraction: when this chunk is the **sole** owner
    /// of its buffer and views it whole, the buffer is returned without
    /// copying (and without counting); otherwise falls back to a counted
    /// [`to_vec`](EventChunk::to_vec). This is the escape hatch for
    /// stateful consumers that need an owned buffer.
    pub fn into_vec(self) -> Vec<Event> {
        if self.len == 0 {
            // Empty views (including the shared static empty chunk)
            // extract to a fresh empty Vec: no data, no copy, and no
            // `chunks_cloned` tick for a zero-event "clone".
            return Vec::new();
        }
        if self.start == 0 && self.len == self.buf.len() {
            match Arc::try_unwrap(self.buf) {
                Ok(vec) => return vec,
                Err(shared) => {
                    note_chunk_cloned(shared.len());
                    return shared[..].to_vec();
                }
            }
        }
        self.to_vec()
    }
}

impl std::fmt::Debug for EventChunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventChunk")
            .field("len", &self.len)
            .field("start", &self.start)
            .field("refcount", &self.refcount())
            .finish()
    }
}

impl From<Vec<Event>> for EventChunk {
    fn from(events: Vec<Event>) -> EventChunk {
        EventChunk::from_vec(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn from_vec_is_uncounted_and_clone_is_refcount_only() {
        let events = synthetic_events(100, 64, 64);
        let before = copy_counters();
        let chunk = EventChunk::from_vec(events.clone());
        let copy = chunk.clone();
        let d = copy_counters().delta(&before);
        assert_eq!(d.chunks_cloned, 0);
        assert_eq!(d.bytes_moved, 0);
        assert_eq!(chunk.refcount(), 2);
        assert_eq!(copy.as_slice(), &events[..]);
    }

    #[test]
    fn slices_share_the_buffer_and_compose() {
        let events = synthetic_events(50, 64, 64);
        let chunk = EventChunk::from_vec(events.clone());
        let mid = chunk.slice(10..40);
        let inner = mid.slice(5..10);
        assert_eq!(mid.as_slice(), &events[10..40]);
        assert_eq!(inner.as_slice(), &events[15..20]);
        assert_eq!(chunk.refcount(), 3);
        let empty = chunk.slice(7..7);
        assert!(empty.is_empty());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_range_slice_panics() {
        EventChunk::from_vec(synthetic_events(5, 8, 8)).slice(0..6);
    }

    #[test]
    fn to_vec_counts_one_clone() {
        let chunk = EventChunk::from_vec(synthetic_events(32, 64, 64));
        let before = copy_counters();
        let owned = chunk.to_vec();
        let d = copy_counters().delta(&before);
        assert_eq!(owned, chunk.as_slice());
        assert_eq!(d.chunks_cloned, 1);
        assert_eq!(d.bytes_moved, (32 * EVENT_BYTES) as u64);
    }

    #[test]
    fn into_vec_is_free_for_a_unique_full_chunk() {
        let events = synthetic_events(64, 64, 64);
        let chunk = EventChunk::from_vec(events.clone());
        let before = copy_counters();
        let owned = chunk.into_vec();
        let d = copy_counters().delta(&before);
        assert_eq!(owned, events);
        assert_eq!(d.chunks_cloned, 0, "unique full-range into_vec must not copy");
        assert_eq!(d.bytes_moved, 0);
    }

    #[test]
    fn into_vec_copies_when_shared_or_partial() {
        let events = synthetic_events(64, 64, 64);
        let chunk = EventChunk::from_vec(events.clone());
        let keep = chunk.clone();
        let before = copy_counters();
        let owned = chunk.into_vec(); // shared: must copy
        assert_eq!(owned, events);
        assert_eq!(copy_counters().delta(&before).chunks_cloned, 1);

        let part = keep.slice(8..24);
        let before = copy_counters();
        let owned = part.into_vec(); // partial view: must copy
        assert_eq!(owned, &events[8..24]);
        assert_eq!(copy_counters().delta(&before).chunks_cloned, 1);
    }

    #[test]
    fn empty_chunks_share_one_buffer_and_never_count() {
        let before = copy_counters();
        let a = EventChunk::empty();
        let b = EventChunk::empty();
        assert!(
            Arc::ptr_eq(&a.buf, &b.buf),
            "every empty chunk must clone the one shared static buffer"
        );
        let c = a.clone();
        assert!(Arc::ptr_eq(&a.buf, &c.buf));
        assert!(c.is_empty());
        let owned = c.into_vec();
        assert!(owned.is_empty());
        let d = copy_counters().delta(&before);
        assert_eq!(d.chunks_cloned, 0, "empty(), clone(), into_vec() must all be uncounted");
        assert_eq!(d.bytes_moved, 0);
    }

    #[test]
    fn parts_round_trip_without_copying() {
        let events = synthetic_events(20, 64, 64);
        let chunk = EventChunk::from_vec(events.clone());
        let before = copy_counters();
        let (buf, start, len) = chunk.into_parts();
        assert_eq!((start, len), (0, 20));
        let view = EventChunk::from_parts(buf, 5, 10);
        assert_eq!(view.as_slice(), &events[5..15]);
        assert_eq!(copy_counters().delta(&before), CopyCounters::default());
    }

    #[test]
    fn from_slice_counts() {
        let events = synthetic_events(16, 64, 64);
        let before = copy_counters();
        let chunk = EventChunk::from_slice(&events);
        assert_eq!(chunk.as_slice(), &events[..]);
        assert_eq!(copy_counters().delta(&before).chunks_cloned, 1);
    }
}
