//! The k-way ordered-merge core, factored out so merge logic exists
//! exactly once.
//!
//! Two consumers share it:
//!
//! * [`super::FusedSource`] — the streaming fan-in merge of N event
//!   sources, keyed by timestamp (ties break to the lowest lane id,
//!   matching [`crate::pipeline::fusion::merge_streams`]);
//! * [`super::StageGraph`]'s sharded stage nodes — the re-merge of N
//!   shard outputs back into serial order, keyed by the per-batch
//!   sequence number each event carried through its shard.
//!
//! A [`MergeCore`] holds one carry per lane. Lanes are *blocking* by
//! default: an empty, unexhausted, blocking lane stalls the merge
//! (emitting could violate key order because the lane's next key is
//! unknown). Lanes whose future keys are known not to matter — an
//! exhausted source, a heartbeating idle live source, a shard that
//! already delivered its whole batch — are non-blocking.
//!
//! ## Bulk operation
//!
//! The merge is designed around two observations from the fan-in hot
//! path (and from EventNet-style event-by-event systems: dispatch cost,
//! not compute, caps throughput):
//!
//! 1. **Selection is `O(log k)`, not `O(k)`.** Lane heads compete in a
//!    *loser tree* (tournament tree storing the loser at each internal
//!    node and the overall winner at the root). After consuming from
//!    the winner, only its root path — `⌈log₂ k⌉` nodes — is replayed.
//!    Structural changes (a batch landing on an empty lane, a new lane
//!    attaching) mark the tree dirty; it is rebuilt bottom-up, `O(k)`,
//!    on the next pop — amortized across the whole batch.
//! 2. **Emission is per-run, not per-event.** Carries are kept at chunk
//!    granularity: a `VecDeque` of [`Arc`]-backed segments plus a start
//!    offset, never per-event ring buffers. [`pop_run`] finds how far
//!    the winning lane extends below the runner-up's next key with one
//!    `partition_point` gallop and hands back that whole region as a
//!    [`Run`] — a refcounted view into the producer's original buffer,
//!    so an uncontended stretch of events crosses the merge without
//!    being copied at all.
//!
//! Fully-drained segment buffers can be collected (see
//! [`MergeCore::set_keep_drained`]) and recycled through
//! [`super::pool::ChunkPool`], closing the allocation loop between
//! sources and the merge.
//!
//! [`pop_run`]: MergeCore::pop_run

use std::collections::VecDeque;
use std::sync::Arc;

use crate::aer::Event;

use super::chunk::EventChunk;

/// Sentinel lane id meaning "no contender" (always loses).
const NOBODY: usize = usize::MAX;

/// Bound on drained buffers retained for recycling when
/// [`MergeCore::set_keep_drained`] is on; beyond it, buffers are simply
/// dropped (correct, just not recycled).
const DRAIN_CAP: usize = 32;

/// A contiguous, individually key-ordered region of a shared buffer:
/// one producer batch (or the unconsumed suffix of one) sitting in a
/// lane's carry.
struct Segment<T> {
    buf: Arc<Vec<T>>,
    start: usize,
    len: usize,
}

/// One input lane of the merge.
struct Lane<T> {
    segs: VecDeque<Segment<T>>,
    /// Total items across `segs` (cached so occupancy is O(1)).
    len: usize,
    exhausted: bool,
    blocking: bool,
}

impl<T> Lane<T> {
    fn new(blocking: bool) -> Self {
        Lane { segs: VecDeque::new(), len: 0, exhausted: false, blocking }
    }
}

/// A maximal (up to the caller's cap) stretch of items popped from one
/// lane in a single step: a refcounted view into the buffer the
/// producer pushed, never a copy.
pub struct Run<T> {
    lane: usize,
    buf: Arc<Vec<T>>,
    start: usize,
    len: usize,
}

impl<T> Run<T> {
    /// Lane the run was emitted from.
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Number of items in the run (always ≥ 1).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Never true — runs are non-empty by construction — but paired
    /// with [`len`](Self::len) for form.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The run's items.
    pub fn as_slice(&self) -> &[T] {
        &self.buf[self.start..self.start + self.len]
    }
}

impl Run<Event> {
    /// Convert the run into an [`EventChunk`] view of the same buffer:
    /// a refcount bump, not a copy.
    pub fn into_chunk(self) -> EventChunk {
        EventChunk::from_parts(self.buf, self.start, self.len)
    }
}

/// N chunk-granularity carries plus the loser-tree selection logic of
/// an ordered k-way merge. Generic over the item and the (per-call)
/// sort key.
pub struct MergeCore<T> {
    lanes: Vec<Lane<T>>,
    /// Loser tree over lane heads: `tree[0]` is the overall winner,
    /// `tree[1..k]` hold the loser of each internal match (leaf for
    /// lane `i` is conceptual node `k + i`, parent of node `n` is
    /// `n / 2`). Valid only while `built`.
    tree: Vec<usize>,
    /// Scratch for bottom-up rebuilds (winner per node), kept to avoid
    /// re-allocating it every rebuild.
    scratch: Vec<usize>,
    /// False whenever a lane head may have changed behind the tree's
    /// back (push onto an empty lane, a new lane, a linear pop); the
    /// next selection rebuilds lazily.
    built: bool,
    /// Total items across all lanes (cached).
    buffered: usize,
    peak_buffered: usize,
    /// When set, fully-consumed segment buffers are parked in
    /// `drained` for the owner to recycle instead of being dropped.
    keep_drained: bool,
    drained: Vec<Arc<Vec<T>>>,
}

impl<T> MergeCore<T> {
    /// A merge over `n` initially-empty, blocking lanes.
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "merge needs at least one lane");
        MergeCore {
            lanes: (0..n).map(|_| Lane::new(true)).collect(),
            tree: Vec::new(),
            scratch: Vec::new(),
            built: false,
            buffered: 0,
            peak_buffered: 0,
            keep_drained: false,
            drained: Vec::new(),
        }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Add a lane while the merge runs (a serving-plane client
    /// attaching mid-stream); returns its id. Call only at a safe
    /// point — between pops, with nothing half-emitted. A new client
    /// joins *non-blocking* (`blocking: false`) so an admitted-but-
    /// quiet connection cannot stall the frontier; the owner flips it
    /// blocking once the lane first delivers data, exactly like a
    /// heartbeat recovery.
    pub fn add_lane(&mut self, blocking: bool) -> usize {
        self.lanes.push(Lane::new(blocking));
        self.built = false;
        self.lanes.len() - 1
    }

    /// Retire a lane: the disconnect path of a dynamic client. The
    /// lane's remaining carry still drains in key order (this is
    /// [`exhaust`](Self::exhaust) by another name, kept separate so the
    /// serving-plane call sites read as what they mean) — a client
    /// hang-up is a clean end of its lane, never an error.
    pub fn retire_lane(&mut self, lane: usize) {
        self.exhaust(lane);
    }

    /// Append items to a lane's carry (items must be in key order and
    /// keyed at or above everything previously pushed to that lane).
    pub fn push(&mut self, lane: usize, items: impl IntoIterator<Item = T>) {
        self.push_vec(lane, items.into_iter().collect());
    }

    /// Append one producer batch to a lane's carry as a single shared
    /// segment (same ordering contract as [`push`](Self::push)). The
    /// `Vec` becomes the backing store for any [`Run`]s later emitted
    /// from this stretch — no per-item copying on either side.
    pub fn push_vec(&mut self, lane: usize, items: Vec<T>) {
        if items.is_empty() {
            return;
        }
        let n = items.len();
        let l = &mut self.lanes[lane];
        if l.len == 0 {
            // The lane head changed; selection state is stale.
            self.built = false;
        }
        l.segs.push_back(Segment { buf: Arc::new(items), start: 0, len: n });
        l.len += n;
        self.buffered += n;
    }

    /// Mark a lane as ended: it can never produce again and stops
    /// blocking the merge once drained.
    pub fn exhaust(&mut self, lane: usize) {
        self.lanes[lane].exhausted = true;
    }

    /// `true` once `lane` was exhausted.
    pub fn is_exhausted(&self, lane: usize) -> bool {
        self.lanes[lane].exhausted
    }

    /// Set whether an *unexhausted* empty `lane` stalls the merge.
    /// Heartbeating live sources flip this off so one quiet sensor
    /// cannot freeze its siblings.
    pub fn set_blocking(&mut self, lane: usize, blocking: bool) {
        self.lanes[lane].blocking = blocking;
    }

    /// Events currently buffered in `lane`.
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lanes[lane].len
    }

    /// Every lane exhausted and drained: the merge is complete.
    pub fn all_done(&self) -> bool {
        self.lanes.iter().all(|l| l.exhausted && l.len == 0)
    }

    /// Some blocking, unexhausted lane is empty: emitting now could
    /// violate key order.
    pub fn stalled(&self) -> bool {
        self.lanes.iter().any(|l| !l.exhausted && l.blocking && l.len == 0)
    }

    /// Record the current total occupancy into the peak gauge.
    pub fn note_peak(&mut self) {
        self.peak_buffered = self.peak_buffered.max(self.buffered);
    }

    /// Peak events resident across all carries (the reorder depth).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// Park fully-drained segment buffers for the owner to recycle
    /// (see [`take_drained`](Self::take_drained)) instead of dropping
    /// them. Off by default: consumers that never drain the parking
    /// lot must not accumulate buffers.
    pub fn set_keep_drained(&mut self, keep: bool) {
        self.keep_drained = keep;
        if !keep {
            self.drained.clear();
        }
    }

    /// Take the buffers whose last item has been popped since the
    /// previous call. Each may still be aliased by emitted [`Run`]s /
    /// [`EventChunk`]s — recycling them through a pool's sole-owner
    /// reclaim is what makes that safe.
    pub fn take_drained(&mut self) -> Vec<Arc<Vec<T>>> {
        std::mem::take(&mut self.drained)
    }

    /// Key of a lane's head item; `None` for an empty lane.
    fn head_key<K: Ord>(&self, lane: usize, key: &impl Fn(&T) -> K) -> Option<K> {
        self.lanes[lane].segs.front().map(|s| key(&s.buf[s.start]))
    }

    /// Strict "lane `a` wins against lane `b`" on (head key, lane id):
    /// empty lanes (and the `NOBODY` sentinel) always lose; equal keys
    /// break to the lowest lane id — the same total order the linear
    /// scan applied, so winners are bit-identical.
    fn beats<K: Ord>(&self, a: usize, b: usize, key: &impl Fn(&T) -> K) -> bool {
        if a == NOBODY {
            return false;
        }
        if b == NOBODY {
            return true;
        }
        match (self.head_key(a, key), self.head_key(b, key)) {
            (None, None) => a < b,
            (None, Some(_)) => false,
            (Some(_), None) => true,
            (Some(ka), Some(kb)) => match ka.cmp(&kb) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => a < b,
            },
        }
    }

    /// Full bottom-up rebuild of the loser tree. Safe for *any* prior
    /// state (incremental replay is only sound along the champion's
    /// path, so head changes on arbitrary lanes funnel through here).
    fn rebuild<K: Ord>(&mut self, key: &impl Fn(&T) -> K) {
        let k = self.lanes.len();
        self.tree.clear();
        self.tree.resize(k, NOBODY);
        if k == 1 {
            self.tree[0] = 0;
            self.built = true;
            return;
        }
        // scratch[n] = winner of the subtree rooted at node n
        // (leaves are nodes k..2k, leaf k + i holding lane i).
        self.scratch.clear();
        self.scratch.resize(2 * k, NOBODY);
        for i in 0..k {
            self.scratch[k + i] = i;
        }
        for n in (1..k).rev() {
            let a = self.scratch[2 * n];
            let b = self.scratch[2 * n + 1];
            let (w, l) = if self.beats(b, a, key) { (b, a) } else { (a, b) };
            self.scratch[n] = w;
            self.tree[n] = l;
        }
        self.tree[0] = self.scratch[1];
        self.built = true;
    }

    /// Replay the champion's root path after its head changed (items
    /// consumed, possibly emptying the lane). `O(log k)`; sound only
    /// for the lane currently at `tree[0]`.
    fn replay_champion<K: Ord>(&mut self, key: &impl Fn(&T) -> K) {
        let k = self.lanes.len();
        if k == 1 {
            return;
        }
        let mut winner = self.tree[0];
        let mut node = (k + winner) / 2;
        while node > 0 {
            let other = self.tree[node];
            if self.beats(other, winner, key) {
                self.tree[node] = winner;
                winner = other;
            }
            node /= 2;
        }
        self.tree[0] = winner;
    }

    fn ensure_tree<K: Ord>(&mut self, key: &impl Fn(&T) -> K) {
        if !self.built {
            self.rebuild(key);
        }
    }

    /// Consume the first `n` items of `lane`'s carry, parking the
    /// backing buffer if it drained (and parking is on).
    fn advance(&mut self, lane: usize, n: usize) {
        let l = &mut self.lanes[lane];
        let seg = l.segs.front_mut().expect("advance on empty lane");
        debug_assert!(n <= seg.len, "run longer than its segment");
        seg.start += n;
        seg.len -= n;
        l.len -= n;
        self.buffered -= n;
        if seg.len == 0 {
            let seg = l.segs.pop_front().expect("front segment vanished");
            if self.keep_drained && self.drained.len() < DRAIN_CAP {
                self.drained.push(seg.buf);
            }
        }
    }

    /// Pop the item with the minimal key across lane heads; ties break
    /// to the lowest lane id (full determinism). `None` when every
    /// carry is empty. `O(log k)` via the loser tree.
    pub fn pop_min<K: Ord>(&mut self, key: impl Fn(&T) -> K) -> Option<(usize, T)>
    where
        T: Clone,
    {
        self.ensure_tree(&key);
        let w = self.tree[0];
        if w == NOBODY || self.lanes[w].len == 0 {
            return None;
        }
        let seg = self.lanes[w].segs.front().expect("winner lane is non-empty");
        let item = seg.buf[seg.start].clone();
        self.advance(w, 1);
        self.replay_champion(&key);
        Some((w, item))
    }

    /// The pre-tournament reference: pop the minimum via an `O(k)`
    /// linear scan over lane heads. Kept verbatim as the equivalence
    /// oracle for property tests and the baseline for the lane-sweep
    /// bench; it bypasses (and invalidates) the tree.
    pub fn pop_min_linear<K: Ord>(&mut self, key: impl Fn(&T) -> K) -> Option<(usize, T)>
    where
        T: Clone,
    {
        let mut best: Option<(K, usize)> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            if let Some(seg) = lane.segs.front() {
                let k = key(&seg.buf[seg.start]);
                let better = match &best {
                    None => true,
                    Some((bk, _)) => k < *bk,
                };
                if better {
                    best = Some((k, i));
                }
            }
        }
        let (_, i) = best?;
        let seg = self.lanes[i].segs.front().expect("nonempty carry");
        let item = seg.buf[seg.start].clone();
        self.advance(i, 1);
        // The tree (if any) did not see this consumption.
        self.built = false;
        Some((i, item))
    }

    /// Pop a maximal run: the longest stretch (≤ `max`) of the winning
    /// lane's front segment that sorts before the runner-up lane's
    /// next key under the same (key, lane-id) order `pop_min` applies.
    /// One `partition_point` gallop replaces up to `run.len()`
    /// individual pops, and the returned [`Run`] aliases the
    /// producer's buffer instead of copying out of it.
    ///
    /// `None` when every carry is empty (or `max == 0`). Runs never
    /// span segment boundaries: within one batch order is the
    /// producer's promise, across batches it is re-checked.
    pub fn pop_run<K: Ord>(&mut self, max: usize, key: impl Fn(&T) -> K) -> Option<Run<T>> {
        if max == 0 {
            return None;
        }
        self.ensure_tree(&key);
        let w = self.tree[0];
        if w == NOBODY || self.lanes[w].len == 0 {
            return None;
        }
        // Runner-up = best among the losers on the winner's root path
        // (every lane that lost its match directly against the
        // champion sits there; one of them is the global #2).
        let k = self.lanes.len();
        let mut runner = NOBODY;
        if k > 1 {
            let mut node = (k + w) / 2;
            while node > 0 {
                let cand = self.tree[node];
                if cand != NOBODY
                    && self.lanes[cand].len > 0
                    && (runner == NOBODY || self.beats(cand, runner, &key))
                {
                    runner = cand;
                }
                node /= 2;
            }
        }
        let seg = self.lanes[w].segs.front().expect("winner lane is non-empty");
        let slice = &seg.buf[seg.start..seg.start + seg.len];
        let limit = slice.len().min(max);
        let end = if runner == NOBODY {
            limit
        } else {
            let rseg = self.lanes[runner].segs.front().expect("runner lane is non-empty");
            let rk = key(&rseg.buf[rseg.start]);
            slice[..limit].partition_point(|item| {
                let ik = key(item);
                ik < rk || (ik == rk && w < runner)
            })
        };
        // The winner beat the runner on its own head, so at least the
        // head itself is below the runner's key.
        debug_assert!(end >= 1, "winner's head must precede the runner-up");
        let run = Run { lane: w, buf: Arc::clone(&seg.buf), start: seg.start, len: end };
        self.advance(w, end);
        self.replay_champion(&key);
        Some(run)
    }
}

/// One-shot merge of fully-materialized, individually key-ordered lanes
/// — the shard re-merge path (each shard's batch output is complete
/// before reassembly, so no lane ever blocks). Rides [`MergeCore::
/// pop_run`], so long single-shard stretches move as bulk copies.
pub fn merge_ordered<T: Clone, K: Ord>(mut parts: Vec<Vec<T>>, key: impl Fn(&T) -> K) -> Vec<T> {
    if parts.len() == 1 {
        return parts.pop().expect("len checked");
    }
    let total: usize = parts.iter().map(Vec::len).sum();
    let mut core = MergeCore::new(parts.len().max(1));
    for (i, part) in parts.into_iter().enumerate() {
        core.push_vec(i, part);
        core.exhaust(i);
    }
    let mut out = Vec::with_capacity(total);
    while let Some(run) = core.pop_run(usize::MAX, &key) {
        out.extend_from_slice(run.as_slice());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_min_is_ordered_and_tie_breaks_to_lowest_lane() {
        let mut core: MergeCore<(u64, char)> = MergeCore::new(3);
        core.push(0, [(5, 'a'), (9, 'b')]);
        core.push(1, [(5, 'c')]);
        core.push(2, [(1, 'd')]);
        (0..3).for_each(|i| core.exhaust(i));
        let mut got = Vec::new();
        while let Some((lane, item)) = core.pop_min(|it| it.0) {
            got.push((lane, item.1));
        }
        assert_eq!(got, vec![(2, 'd'), (0, 'a'), (1, 'c'), (0, 'b')]);
        assert!(core.all_done());
    }

    #[test]
    fn blocking_semantics_gate_stalls() {
        let mut core: MergeCore<u64> = MergeCore::new(2);
        core.push(0, [1, 2]);
        assert!(core.stalled(), "live empty lane 1 must stall");
        core.set_blocking(1, false);
        assert!(!core.stalled(), "non-blocking empty lane must not stall");
        core.set_blocking(1, true);
        core.exhaust(1);
        assert!(!core.stalled(), "exhausted lane must not stall");
        assert!(!core.all_done(), "lane 0 still has items");
    }

    #[test]
    fn peak_tracks_total_occupancy() {
        let mut core: MergeCore<u64> = MergeCore::new(2);
        core.push(0, [1, 2, 3]);
        core.push(1, [4]);
        core.note_peak();
        assert_eq!(core.peak_buffered(), 4);
        core.pop_min(|&v| v);
        core.note_peak();
        assert_eq!(core.peak_buffered(), 4, "peak is a high-water mark");
        assert_eq!(core.lane_len(0), 2);
    }

    #[test]
    fn lanes_attach_and_retire_mid_merge() {
        let mut core: MergeCore<u64> = MergeCore::new(1);
        core.push(0, [1, 5]);
        // A client attaches mid-stream: non-blocking until it delivers,
        // so the merge keeps moving.
        let lane = core.add_lane(false);
        assert_eq!(lane, 1);
        assert_eq!(core.lanes(), 2);
        assert!(!core.stalled(), "fresh empty client lane must not stall the frontier");
        assert_eq!(core.pop_min(|&v| v), Some((0, 1)));
        // First data arrives: the lane becomes an ordinary blocking one.
        core.push(lane, [3, 7]);
        core.set_blocking(lane, true);
        assert_eq!(core.pop_min(|&v| v), Some((1, 3)));
        // Disconnect: the retired lane drains in order, then stops
        // counting — never an error, never a stall.
        core.retire_lane(lane);
        assert!(core.is_exhausted(lane));
        assert_eq!(core.pop_min(|&v| v), Some((0, 5)));
        assert_eq!(core.pop_min(|&v| v), Some((1, 7)));
        core.exhaust(0);
        assert!(core.all_done());
        assert!(!core.stalled());
    }

    #[test]
    fn merge_ordered_restores_sequence() {
        let parts = vec![vec![(0u32, 'a'), (3, 'b')], vec![(1u32, 'c')], vec![(2u32, 'd')]];
        let merged = merge_ordered(parts, |it| it.0);
        assert_eq!(merged, vec![(0, 'a'), (1, 'c'), (2, 'd'), (3, 'b')]);
        assert!(merge_ordered(Vec::<Vec<u32>>::new(), |&v| v).is_empty());
    }

    #[test]
    fn tree_pop_min_matches_linear_reference() {
        // Two identically-fed cores, drained through the loser tree and
        // the linear scan respectively, with mid-drain pushes landing
        // on emptied lanes (the rebuild trigger).
        let feed: [&[u64]; 3] = [&[1, 4, 4, 9], &[2, 4, 7], &[4, 4]];
        let mut tree: MergeCore<u64> = MergeCore::new(3);
        let mut lin: MergeCore<u64> = MergeCore::new(3);
        for (i, part) in feed.iter().enumerate() {
            tree.push_vec(i, part.to_vec());
            lin.push_vec(i, part.to_vec());
        }
        for step in 0..7 {
            assert_eq!(tree.pop_min(|&v| v), lin.pop_min(|&v| v), "step {step}");
        }
        // Lane 2 has drained; refill it below the others' heads.
        tree.push_vec(2, vec![5, 6]);
        lin.push_vec(2, vec![5, 6]);
        loop {
            let a = tree.pop_min(|&v| v);
            let b = lin.pop_min(|&v| v);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn runs_cap_at_max_and_alias_the_pushed_buffer() {
        let mut core: MergeCore<u64> = MergeCore::new(2);
        let batch = vec![1u64, 2, 3, 4];
        let base = batch.as_ptr();
        core.push_vec(0, batch);
        core.push_vec(1, vec![10u64]);
        (0..2).for_each(|i| core.exhaust(i));
        // All four lane-0 items sort below lane 1's head, but the cap
        // splits them into 3 + 1.
        let run = core.pop_run(3, |&v| v).expect("run");
        assert_eq!((run.lane(), run.as_slice()), (0, &[1u64, 2, 3][..]));
        assert_eq!(run.as_slice().as_ptr(), base, "run must alias the pushed buffer");
        let run = core.pop_run(usize::MAX, |&v| v).expect("run");
        assert_eq!((run.lane(), run.as_slice()), (0, &[4u64][..]));
        let run = core.pop_run(usize::MAX, |&v| v).expect("run");
        assert_eq!((run.lane(), run.as_slice()), (1, &[10u64][..]));
        assert!(core.pop_run(usize::MAX, |&v| v).is_none());
        assert!(core.all_done());
    }

    #[test]
    fn run_tie_break_matches_pop_min() {
        // Duplicate keys across lanes: a run from lane 1 must stop at
        // a tie with lane 0 (lower id wins), but a run from lane 0 may
        // gallop through a tie with lane 1.
        let mut core: MergeCore<(u64, char)> = MergeCore::new(2);
        core.push_vec(0, vec![(3, 'a'), (5, 'b')]);
        core.push_vec(1, vec![(1, 'c'), (3, 'd'), (3, 'e')]);
        (0..2).for_each(|i| core.exhaust(i));
        let mut got = Vec::new();
        while let Some(run) = core.pop_run(usize::MAX, |it| it.0) {
            got.extend(run.as_slice().iter().map(|it| (run.lane(), it.1)));
        }
        assert_eq!(
            got,
            vec![(1, 'c'), (0, 'a'), (1, 'd'), (1, 'e'), (0, 'b')],
            "ties break to the lowest lane id, run-wise exactly as pop-wise"
        );
    }

    #[test]
    fn drained_buffers_park_for_recycling() {
        let mut core: MergeCore<u64> = MergeCore::new(1);
        core.set_keep_drained(true);
        let batch = vec![1u64, 2];
        let base = batch.as_ptr();
        core.push_vec(0, batch);
        core.exhaust(0);
        assert!(core.take_drained().is_empty(), "nothing drained yet");
        let run = core.pop_run(usize::MAX, |&v| v).expect("run");
        assert_eq!(run.len(), 2);
        let drained = core.take_drained();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].as_ptr(), base, "the drained Arc is the pushed buffer");
        assert_eq!(
            Arc::strong_count(&drained[0]),
            2,
            "still aliased by the emitted run until the consumer drops it"
        );
    }
}
