//! The k-way ordered-merge core, factored out so merge logic exists
//! exactly once.
//!
//! Two consumers share it:
//!
//! * [`super::FusedSource`] — the streaming fan-in merge of N event
//!   sources, keyed by timestamp (ties break to the lowest lane id,
//!   matching [`crate::pipeline::fusion::merge_streams`]);
//! * [`super::StageGraph`]'s sharded stage nodes — the re-merge of N
//!   shard outputs back into serial order, keyed by the per-batch
//!   sequence number each event carried through its shard.
//!
//! A [`MergeCore`] holds one carry buffer per lane. Lanes are *blocking*
//! by default: an empty, unexhausted, blocking lane stalls the merge
//! (emitting could violate key order because the lane's next key is
//! unknown). Lanes whose future keys are known not to matter — an
//! exhausted source, a heartbeating idle live source, a shard that
//! already delivered its whole batch — are non-blocking.

use std::collections::VecDeque;

/// One input lane of the merge.
struct Lane<T> {
    carry: VecDeque<T>,
    exhausted: bool,
    blocking: bool,
}

/// N carry buffers plus the min-key pop logic of an ordered k-way
/// merge. Generic over the item and the (per-pop) sort key.
pub(crate) struct MergeCore<T> {
    lanes: Vec<Lane<T>>,
    peak_buffered: usize,
}

impl<T> MergeCore<T> {
    /// A merge over `n` initially-empty, blocking lanes.
    pub(crate) fn new(n: usize) -> Self {
        assert!(n > 0, "merge needs at least one lane");
        MergeCore {
            lanes: (0..n)
                .map(|_| Lane { carry: VecDeque::new(), exhausted: false, blocking: true })
                .collect(),
            peak_buffered: 0,
        }
    }

    /// Number of lanes.
    pub(crate) fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Add a lane while the merge runs (a serving-plane client
    /// attaching mid-stream); returns its id. Call only at a safe
    /// point — between pops, with nothing half-emitted. A new client
    /// joins *non-blocking* (`blocking: false`) so an admitted-but-
    /// quiet connection cannot stall the frontier; the owner flips it
    /// blocking once the lane first delivers data, exactly like a
    /// heartbeat recovery.
    pub(crate) fn add_lane(&mut self, blocking: bool) -> usize {
        self.lanes.push(Lane { carry: VecDeque::new(), exhausted: false, blocking });
        self.lanes.len() - 1
    }

    /// Retire a lane: the disconnect path of a dynamic client. The
    /// lane's remaining carry still drains in key order (this is
    /// [`exhaust`](Self::exhaust) by another name, kept separate so the
    /// serving-plane call sites read as what they mean) — a client
    /// hang-up is a clean end of its lane, never an error.
    pub(crate) fn retire_lane(&mut self, lane: usize) {
        self.exhaust(lane);
    }

    /// Append items to a lane's carry (items must be in key order and
    /// keyed at or above everything previously pushed to that lane).
    pub(crate) fn push(&mut self, lane: usize, items: impl IntoIterator<Item = T>) {
        self.lanes[lane].carry.extend(items);
    }

    /// Mark a lane as ended: it can never produce again and stops
    /// blocking the merge once drained.
    pub(crate) fn exhaust(&mut self, lane: usize) {
        self.lanes[lane].exhausted = true;
    }

    /// `true` once `lane` was exhausted.
    pub(crate) fn is_exhausted(&self, lane: usize) -> bool {
        self.lanes[lane].exhausted
    }

    /// Set whether an *unexhausted* empty `lane` stalls the merge.
    /// Heartbeating live sources flip this off so one quiet sensor
    /// cannot freeze its siblings.
    pub(crate) fn set_blocking(&mut self, lane: usize, blocking: bool) {
        self.lanes[lane].blocking = blocking;
    }

    /// Events currently buffered in `lane`.
    pub(crate) fn lane_len(&self, lane: usize) -> usize {
        self.lanes[lane].carry.len()
    }

    /// Every lane exhausted and drained: the merge is complete.
    pub(crate) fn all_done(&self) -> bool {
        self.lanes.iter().all(|l| l.exhausted && l.carry.is_empty())
    }

    /// Some blocking, unexhausted lane is empty: emitting now could
    /// violate key order.
    pub(crate) fn stalled(&self) -> bool {
        self.lanes.iter().any(|l| !l.exhausted && l.blocking && l.carry.is_empty())
    }

    /// Record the current total occupancy into the peak gauge.
    pub(crate) fn note_peak(&mut self) {
        let buffered: usize = self.lanes.iter().map(|l| l.carry.len()).sum();
        self.peak_buffered = self.peak_buffered.max(buffered);
    }

    /// Peak events resident across all carries (the reorder depth).
    pub(crate) fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// Pop the item with the minimal key across lane heads; ties break
    /// to the lowest lane id (full determinism). `None` when every
    /// carry is empty.
    pub(crate) fn pop_min<K: Ord>(&mut self, key: impl Fn(&T) -> K) -> Option<(usize, T)> {
        let mut best: Option<(K, usize)> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            if let Some(head) = lane.carry.front() {
                let k = key(head);
                let better = match &best {
                    None => true,
                    Some((bk, _)) => k < *bk,
                };
                if better {
                    best = Some((k, i));
                }
            }
        }
        let (_, i) = best?;
        let item = self.lanes[i].carry.pop_front().expect("nonempty carry");
        Some((i, item))
    }
}

/// One-shot merge of fully-materialized, individually key-ordered lanes
/// — the shard re-merge path (each shard's batch output is complete
/// before reassembly, so no lane ever blocks).
pub(crate) fn merge_ordered<T, K: Ord>(
    parts: Vec<Vec<T>>,
    key: impl Fn(&T) -> K,
) -> Vec<T> {
    let total: usize = parts.iter().map(Vec::len).sum();
    let mut core = MergeCore::new(parts.len().max(1));
    for (i, part) in parts.into_iter().enumerate() {
        core.push(i, part);
        core.exhaust(i);
    }
    let mut out = Vec::with_capacity(total);
    while let Some((_, item)) = core.pop_min(&key) {
        out.push(item);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_min_is_ordered_and_tie_breaks_to_lowest_lane() {
        let mut core: MergeCore<(u64, char)> = MergeCore::new(3);
        core.push(0, [(5, 'a'), (9, 'b')]);
        core.push(1, [(5, 'c')]);
        core.push(2, [(1, 'd')]);
        (0..3).for_each(|i| core.exhaust(i));
        let mut got = Vec::new();
        while let Some((lane, item)) = core.pop_min(|it| it.0) {
            got.push((lane, item.1));
        }
        assert_eq!(got, vec![(2, 'd'), (0, 'a'), (1, 'c'), (0, 'b')]);
        assert!(core.all_done());
    }

    #[test]
    fn blocking_semantics_gate_stalls() {
        let mut core: MergeCore<u64> = MergeCore::new(2);
        core.push(0, [1, 2]);
        assert!(core.stalled(), "live empty lane 1 must stall");
        core.set_blocking(1, false);
        assert!(!core.stalled(), "non-blocking empty lane must not stall");
        core.set_blocking(1, true);
        core.exhaust(1);
        assert!(!core.stalled(), "exhausted lane must not stall");
        assert!(!core.all_done(), "lane 0 still has items");
    }

    #[test]
    fn peak_tracks_total_occupancy() {
        let mut core: MergeCore<u64> = MergeCore::new(2);
        core.push(0, [1, 2, 3]);
        core.push(1, [4]);
        core.note_peak();
        assert_eq!(core.peak_buffered(), 4);
        core.pop_min(|&v| v);
        core.note_peak();
        assert_eq!(core.peak_buffered(), 4, "peak is a high-water mark");
        assert_eq!(core.lane_len(0), 2);
    }

    #[test]
    fn lanes_attach_and_retire_mid_merge() {
        let mut core: MergeCore<u64> = MergeCore::new(1);
        core.push(0, [1, 5]);
        // A client attaches mid-stream: non-blocking until it delivers,
        // so the merge keeps moving.
        let lane = core.add_lane(false);
        assert_eq!(lane, 1);
        assert_eq!(core.lanes(), 2);
        assert!(!core.stalled(), "fresh empty client lane must not stall the frontier");
        assert_eq!(core.pop_min(|&v| v), Some((0, 1)));
        // First data arrives: the lane becomes an ordinary blocking one.
        core.push(lane, [3, 7]);
        core.set_blocking(lane, true);
        assert_eq!(core.pop_min(|&v| v), Some((1, 3)));
        // Disconnect: the retired lane drains in order, then stops
        // counting — never an error, never a stall.
        core.retire_lane(lane);
        assert!(core.is_exhausted(lane));
        assert_eq!(core.pop_min(|&v| v), Some((0, 5)));
        assert_eq!(core.pop_min(|&v| v), Some((1, 7)));
        core.exhaust(0);
        assert!(core.all_done());
        assert!(!core.stalled());
    }

    #[test]
    fn merge_ordered_restores_sequence() {
        let parts = vec![vec![(0u32, 'a'), (3, 'b')], vec![(1u32, 'c')], vec![(2u32, 'd')]];
        let merged = merge_ordered(parts, |it| it.0);
        assert_eq!(merged, vec![(0, 'a'), (1, 'c'), (2, 'd'), (3, 'b')]);
        assert!(merge_ordered(Vec::<Vec<u32>>::new(), |&v| v).is_empty());
    }
}
