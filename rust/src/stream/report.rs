//! `--report-json`: a line-oriented JSON view of the telemetry plane.
//!
//! A serving topology runs indefinitely, so a single report printed at
//! exit is useless for operating it — the observability slice of the
//! ROADMAP's telemetry item wants the stream graphable *in flight*.
//! This module emits one self-contained JSON object per line:
//!
//! * `{"type":"epoch", …}` — per adaptive epoch, from the epoch loop's
//!   [`EpochSample`]: edge counters, per-stage shard histograms, and
//!   per-client serving-plane counters (window, credit stalls);
//! * `{"type":"final", …}` — once at shutdown, the whole
//!   [`StreamReport`] including per-node counters and the adaptive
//!   history (chunk and per-client window changes).
//!
//! The writer is hand-rolled (no serde in the dependency budget) and
//! flushes per line, so `tail -f report.jsonl | jq` works while the
//! stream serves. With `--report-json` but no `--adaptive`, the driver
//! synthesizes an empty controller list so epochs still tick.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, Write};
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context as _, Result};

use super::adapt::EpochSample;
use super::StreamReport;

/// Where `--report-json` lines go.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReportTarget {
    /// One JSON line per epoch on stdout (`--report-json -`).
    Stdout,
    /// Create/truncate this file and stream lines into it.
    File(PathBuf),
}

impl ReportTarget {
    /// Parse the CLI operand: `-` is stdout, anything else a path.
    pub fn parse(s: &str) -> ReportTarget {
        if s == "-" {
            ReportTarget::Stdout
        } else {
            ReportTarget::File(PathBuf::from(s))
        }
    }
}

/// Line-oriented JSON emitter shared by the adaptive epoch loop (one
/// `"epoch"` line per telemetry epoch) and the topology driver (one
/// `"final"` line as the stream shuts down).
pub struct ReportEmitter {
    out: Mutex<Box<dyn Write + Send>>,
}

impl ReportEmitter {
    /// Open the emitter (creates/truncates a file target).
    pub fn open(target: &ReportTarget) -> Result<ReportEmitter> {
        let out: Box<dyn Write + Send> = match target {
            ReportTarget::Stdout => Box::new(io::stdout()),
            ReportTarget::File(path) => Box::new(File::create(path).with_context(|| {
                format!("creating --report-json file {}", path.display())
            })?),
        };
        Ok(ReportEmitter { out: Mutex::new(out) })
    }

    fn emit_line(&self, line: &str) -> Result<()> {
        let mut out = self.out.lock().unwrap();
        writeln!(out, "{line}").context("writing --report-json line")?;
        out.flush().context("flushing --report-json line")
    }

    /// One `"epoch"` line from the adaptive epoch loop. Counters are
    /// epoch deltas, matching what controllers saw.
    pub fn emit_epoch(&self, sample: &EpochSample) -> Result<()> {
        let mut line = String::new();
        let _ = write!(
            line,
            "{{\"type\":\"epoch\",\"epoch\":{},\"batches\":{},\"events_in\":{},\
             \"backpressure_waits\":{},\"chunk\":{},\"stages\":[",
            sample.epoch,
            sample.batches,
            sample.events_in,
            sample.backpressure_waits,
            sample.chunk_size,
        );
        for (i, stage) in sample.stages.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let events: u64 = stage.epoch_shard_events.iter().sum();
            let _ = write!(
                line,
                "{{\"name\":{},\"events\":{events},\"shards\":[",
                json_str(&stage.name)
            );
            for (j, n) in stage.epoch_shard_events.iter().enumerate() {
                if j > 0 {
                    line.push(',');
                }
                let _ = write!(line, "{n}");
            }
            line.push_str("]}");
        }
        line.push_str("],\"clients\":[");
        for (i, client) in sample.clients.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let _ = write!(
                line,
                "{{\"name\":{},\"events\":{},\"batches\":{},\"backpressure_waits\":{},\
                 \"window\":{}}}",
                json_str(&client.name),
                client.events,
                client.batches,
                client.backpressure_waits,
                client.window,
            );
        }
        line.push_str("]}");
        self.emit_line(&line)
    }

    /// The `"final"` line: the complete [`StreamReport`] at shutdown.
    pub fn emit_final(&self, report: &StreamReport) -> Result<()> {
        let mut line = String::new();
        let _ = write!(
            line,
            "{{\"type\":\"final\",\"events_in\":{},\"events_out\":{},\"frames\":{},\
             \"batches\":{},\"peak_in_flight\":{},\"backpressure_waits\":{},\
             \"wall_s\":{:.6},\"resolution\":[{},{}],\
             \"bytes_moved\":{},\"chunks_cloned\":{},\
             \"pool_hits\":{},\"pool_misses\":{},\"merge\":{{\
             \"peak_buffered\":{},\"dropped\":{},\"stalls_broken\":{},\"late_events\":{}}}",
            report.events_in,
            report.events_out,
            report.frames,
            report.batches,
            report.peak_in_flight,
            report.backpressure_waits,
            report.wall.as_secs_f64(),
            report.resolution.width,
            report.resolution.height,
            report.bytes_moved,
            report.chunks_cloned,
            report.pool_hits,
            report.pool_misses,
            report.merge_peak_buffered,
            report.merge_dropped,
            report.merge_stalls_broken,
            report.merge_late_events,
        );
        let _ = write!(
            line,
            ",\"decode\":{{\"workers\":{},\"jobs\":{},\"queue_depth\":{},\
             \"worker_busy\":{},\"reassembly_lag\":{}}}",
            report.decode_workers,
            report.decode_jobs,
            report.decode_queue_depth,
            report.decode_worker_busy,
            report.decode_reassembly_lag,
        );
        let _ = write!(
            line,
            ",\"buffer\":{{\"bytes_on_disk\":{},\"records_spilled\":{},\
             \"records_replayed\":{},\"corrupt_records_skipped\":{},\
             \"spill_active\":{}}}",
            report.buffer_bytes_on_disk,
            report.buffer_records_spilled,
            report.buffer_records_replayed,
            report.buffer_corrupt_records_skipped,
            report.buffer_spill_active,
        );
        for (key, nodes) in
            [("sources", &report.sources), ("stages", &report.stages), ("sinks", &report.sinks)]
        {
            let _ = write!(line, ",\"{key}\":[");
            for (i, node) in nodes.iter().enumerate() {
                if i > 0 {
                    line.push(',');
                }
                let _ = write!(
                    line,
                    "{{\"name\":{},\"events\":{},\"batches\":{},\
                     \"backpressure_waits\":{},\"dropped\":{},\"frames\":{},\
                     \"bytes_moved\":{},\"chunks_cloned\":{},\
                     \"pool_hits\":{},\"pool_misses\":{}}}",
                    json_str(&node.name),
                    node.events,
                    node.batches,
                    node.backpressure_waits,
                    node.dropped,
                    node.frames,
                    node.bytes_moved,
                    node.chunks_cloned,
                    node.pool_hits,
                    node.pool_misses,
                );
            }
            line.push(']');
        }
        match &report.adaptive {
            None => line.push_str(",\"adaptive\":null}"),
            Some(adaptive) => {
                let _ = write!(
                    line,
                    ",\"adaptive\":{{\"epochs\":{},\"recuts\":{},\"final_chunk\":{},\
                     \"chunk_changes\":[",
                    adaptive.epochs,
                    adaptive.recuts.len(),
                    adaptive.final_chunk,
                );
                for (i, change) in adaptive.chunk_changes.iter().enumerate() {
                    if i > 0 {
                        line.push(',');
                    }
                    let _ = write!(
                        line,
                        "{{\"epoch\":{},\"from\":{},\"to\":{}}}",
                        change.epoch, change.from, change.to
                    );
                }
                line.push_str("],\"window_changes\":[");
                for (i, change) in adaptive.window_changes.iter().enumerate() {
                    if i > 0 {
                        line.push(',');
                    }
                    let _ = write!(
                        line,
                        "{{\"epoch\":{},\"client\":{},\"from\":{},\"to\":{}}}",
                        change.epoch,
                        json_str(&change.client),
                        change.from,
                        change.to
                    );
                }
                line.push_str("]}}");
            }
        }
        self.emit_line(&line)
    }
}

/// Escape `s` as a JSON string literal, quotes included.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::adapt::{ClientSample, StageSample};

    #[test]
    fn targets_parse() {
        assert_eq!(ReportTarget::parse("-"), ReportTarget::Stdout);
        assert_eq!(
            ReportTarget::parse("out.jsonl"),
            ReportTarget::File(PathBuf::from("out.jsonl"))
        );
    }

    #[test]
    fn json_strings_escape() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny\u{1}"), "\"x\\ny\\u0001\"");
    }

    #[test]
    fn epoch_lines_are_valid_shape() {
        let dir = std::env::temp_dir().join(format!(
            "aestream-report-{}-{}",
            std::process::id(),
            line!()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("epochs.jsonl");
        let emitter = ReportEmitter::open(&ReportTarget::File(path.clone())).unwrap();
        let sample = EpochSample {
            epoch: 3,
            batches: 32,
            events_in: 4096,
            backpressure_waits: 5,
            backpressure_gauged: true,
            chunk_size: 1024,
            stages: vec![StageSample {
                stage: 0,
                name: "refractory".into(),
                epoch_shard_events: vec![10, 20],
                bounds: vec![16, 32],
                halo: 1,
            }],
            clients: vec![ClientSample {
                name: "client:0".into(),
                events: 100,
                batches: 4,
                backpressure_waits: 1,
                window: 512,
            }],
        };
        emitter.emit_epoch(&sample).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let line = text.lines().next().unwrap();
        assert!(line.starts_with("{\"type\":\"epoch\",\"epoch\":3,"), "{line}");
        assert!(line.contains("\"name\":\"refractory\",\"events\":30,\"shards\":[10,20]"));
        assert!(line.contains("\"name\":\"client:0\""));
        assert!(line.contains("\"window\":512"));
        assert!(line.ends_with('}'), "one complete object per line: {line}");
        assert_eq!(
            line.matches('{').count(),
            line.matches('}').count(),
            "balanced braces: {line}"
        );
    }
}
