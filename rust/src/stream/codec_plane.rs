//! The shared codec worker plane: packed-format decode off the ingest
//! threads, on a *bounded* pool.
//!
//! The paper's thesis is that event production must be decoupled from
//! event consumption for throughput on conventional hardware. The
//! ingest side used to violate it twice over: every file pump and every
//! serving-plane client ran byte I/O **and** the packed-format state
//! machine on the same thread — decode latency stalled reads, and 128
//! clients cost 128 decoding threads. This module is the decoupling
//! point:
//!
//! * Readers fill pooled byte buffers ([`super::pool::BytePool`]) and
//!   [`submit`](DecodeStream::submit) them; each buffer is cut into
//!   `(stream, seq)`-tagged pieces (ranges over one `Arc<Vec<u8>>` —
//!   the split itself is zero-copy) on a shared work queue.
//! * `W` workers (`--decode-threads`, default derived from
//!   `available_parallelism`) run the [`crate::formats::simd`] kernels.
//!   The thread budget is fixed: client count no longer buys threads.
//! * A sequence-keyed reassembly per stream (the same pattern as the
//!   shard re-merge in [`super::stage`]) restores order at
//!   [`poll`](DecodeStream::poll) — byte-identical to inline decode.
//!
//! How much *intra*-stream concurrency a format admits is its
//! [`SplitPoints`] class: `raw`/AEDAT 2.0/DAT pieces are fully
//! independent; EVT2 pieces decode under the exact entry state found by
//! a vectorized backward pre-scan for the last `TIME_HIGH` word
//! ([`crate::formats::simd::evt2_scan_last_time_high`] — `TIME_HIGH`
//! resets the decoder's only state, so the scan result *is* the inline
//! state at the cut); EVT3/AEDAT 3.1/CSV streams stay sequential, one
//! in-flight piece batch per stream, but still decode off the reader
//! thread and concurrently *across* streams.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::aer::{Event, Resolution};
use crate::formats::simd;
use crate::formats::streaming::{split_points, SplitPoints, StreamingDecoder};
use crate::formats::Format;
use crate::net::spif;

use super::pool::BytePool;

/// Target bytes per parallel decode piece: large enough that per-job
/// decode time dwarfs queue/wakeup overhead (~64 KiB ≈ 8k events),
/// small enough that one read fans out across several workers.
const PIECE_BYTES: usize = 64 << 10;

/// Soft cap on undelivered pieces per stream before a submitter should
/// drain ([`DecodeStream::backlog`]): bounds per-stream memory at
/// `O(backlog × piece)` when readers outrun the workers.
pub const MAX_BACKLOG: usize = 16;

/// Sizing for the worker pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecPlaneConfig {
    /// Decode worker threads (`--decode-threads`).
    pub workers: usize,
}

impl CodecPlaneConfig {
    /// Exactly `workers` threads (floored at 1).
    pub fn with_workers(workers: usize) -> CodecPlaneConfig {
        CodecPlaneConfig { workers: workers.max(1) }
    }
}

impl Default for CodecPlaneConfig {
    /// `available_parallelism`-derived: leave a core for the merge
    /// driver and one for ingest, cap at 8 (decode is memory-bound well
    /// before that).
    fn default() -> Self {
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        CodecPlaneConfig { workers: cores.saturating_sub(2).clamp(1, 8) }
    }
}

/// Lifetime counters for the plane (peaks are high-water marks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CodecPlaneCounters {
    /// Worker threads in the pool.
    pub workers: u64,
    /// Decode jobs executed.
    pub jobs: u64,
    /// Peak depth of the shared work queue.
    pub queue_depth: u64,
    /// Peak concurrently-busy workers.
    pub worker_busy: u64,
    /// Peak out-of-order results buffered in any stream's reassembly.
    pub reassembly_lag: u64,
}

/// What a worker must know to decode one piece independently.
#[derive(Debug, Clone, Copy)]
enum Entry {
    Raw,
    Aedat2,
    Dat,
    /// Entry `TIME_HIGH` state — exactly the inline decoder state at
    /// the cut, from the submitter's pre-scan.
    Evt2 { time_high: Option<u64> },
    /// SPIF wire words: arrival timestamp and the canvas to filter to.
    Spif { t: u64, geometry: Resolution },
}

/// One decoded piece, keyed into the reassembly map by its seq.
#[derive(Debug, Default)]
struct PieceOutput {
    events: Vec<Event>,
    /// Events rejected by the geometry filter (SPIF streams).
    rejected: u64,
}

/// A sequential stream's queued input piece.
struct SeqPiece {
    seq: u64,
    bytes: Arc<Vec<u8>>,
    start: usize,
    end: usize,
    /// End-of-stream marker: run `finish()` after this piece.
    finish: bool,
}

/// Per-stream state shared between the submitting reader, the workers,
/// and the polling side (all three may be the same thread for files).
struct StreamShared {
    state: Mutex<StreamState>,
    /// Signaled whenever a result lands in `done`.
    delivered: Condvar,
}

struct StreamState {
    /// Sequential formats only: the live decoder, `None` while checked
    /// out by the worker that owns the current drain.
    seqdec: Option<StreamingDecoder>,
    /// Sequential formats only: pieces awaiting the next drain.
    seq_input: VecDeque<SeqPiece>,
    /// A `Drain` job for this stream is queued or running (at most one
    /// worker touches a sequential decoder at a time).
    scheduled: bool,
    /// The stream hit a decode error; later pieces complete empty (the
    /// error surfaces, once, at its own seq during in-order poll).
    errored: bool,
    /// Seq-keyed reassembly: results land here in completion order and
    /// leave in seq order.
    done: BTreeMap<u64, Result<PieceOutput>>,
    /// Next seq to hand to the poller.
    next_emit: u64,
    /// Geometry discovered by a worker-held sequential decoder.
    res: Option<Resolution>,
}

enum Job {
    /// An independently decodable piece (split-capable formats).
    Piece { stream: Arc<StreamShared>, seq: u64, bytes: Arc<Vec<u8>>, start: usize, end: usize, entry: Entry },
    /// Drain a sequential stream's input queue through its decoder.
    Drain { stream: Arc<StreamShared> },
}

struct PlaneShared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    jobs: AtomicU64,
    queue_depth_peak: AtomicU64,
    busy_now: AtomicU64,
    busy_peak: AtomicU64,
    lag_peak: AtomicU64,
}

impl PlaneShared {
    fn bump_peak(peak: &AtomicU64, value: u64) {
        peak.fetch_max(value, Ordering::Relaxed);
    }

    fn enqueue(&self, job: Job) {
        let mut q = self.queue.lock().expect("codec queue lock");
        q.push_back(job);
        Self::bump_peak(&self.queue_depth_peak, q.len() as u64);
        drop(q);
        self.available.notify_one();
    }
}

/// The fixed-size shared decode worker pool. One per topology run
/// (`Arc`-shared into every packed-format ingest path via
/// [`EventSource::set_codec_plane`](super::EventSource::set_codec_plane)).
pub struct CodecPlane {
    shared: Arc<PlaneShared>,
    bytes: Arc<BytePool>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    worker_count: usize,
}

impl CodecPlane {
    /// Spawn the worker pool (threads are named `codec:<i>` so a thread
    /// census can assert the budget).
    pub fn new(config: CodecPlaneConfig) -> Arc<CodecPlane> {
        let shared = Arc::new(PlaneShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            jobs: AtomicU64::new(0),
            queue_depth_peak: AtomicU64::new(0),
            busy_now: AtomicU64::new(0),
            busy_peak: AtomicU64::new(0),
            lag_peak: AtomicU64::new(0),
        });
        let worker_count = config.workers.max(1);
        let workers = (0..worker_count)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("codec:{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn codec worker")
            })
            .collect();
        Arc::new(CodecPlane {
            shared,
            bytes: Arc::new(BytePool::new()),
            workers: Mutex::new(workers),
            worker_count,
        })
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.worker_count
    }

    /// The plane's pooled byte buffers (readers draw read buffers here
    /// so steady-state ingest allocates nothing).
    pub fn byte_pool(&self) -> &Arc<BytePool> {
        &self.bytes
    }

    /// Lifetime counters (peaks are high-water marks).
    pub fn counters(&self) -> CodecPlaneCounters {
        CodecPlaneCounters {
            workers: self.worker_count as u64,
            jobs: self.shared.jobs.load(Ordering::Relaxed),
            queue_depth: self.shared.queue_depth_peak.load(Ordering::Relaxed),
            worker_busy: self.shared.busy_peak.load(Ordering::Relaxed),
            reassembly_lag: self.shared.lag_peak.load(Ordering::Relaxed),
        }
    }

    /// Open a decode stream for a container format. The submitter-side
    /// handle consumes the header sequentially, then fans body pieces
    /// out per the format's [`SplitPoints`] class.
    pub fn open_stream(self: &Arc<Self>, format: Format) -> DecodeStream {
        let kind = match split_points(format) {
            SplitPoints::Stateless { word } | SplitPoints::ScanBoundaries { word } => {
                StreamKind::Parallel { format, word }
            }
            SplitPoints::Sequential => StreamKind::Sequential,
        };
        DecodeStream::new(Arc::clone(self), kind, StreamingDecoder::new(format))
    }

    /// Open a decode stream for SPIF wire words (the serving plane's
    /// TCP framing): headerless 4-byte words, stateless, filtered to
    /// `geometry` with rejects counted per piece.
    pub fn open_spif_stream(self: &Arc<Self>, geometry: Resolution) -> DecodeStream {
        DecodeStream {
            plane: Arc::clone(self),
            shared: Arc::new(StreamShared::new(None)),
            kind: StreamKind::Spif { geometry },
            header: None,
            carry: Vec::new(),
            evt2_entry: None,
            next_seq: 0,
            finished: false,
            failed: None,
            res: Some(geometry),
        }
    }

    /// `true` once the plane has shut down and its workers are joined:
    /// anything submitted from here on will never decode.
    fn is_closed(&self) -> bool {
        self.shared.shutdown.load(Ordering::Relaxed)
            && self.workers.lock().expect("codec workers lock").is_empty()
    }

    /// Stop accepting work, finish queued jobs, and join the workers.
    /// Idempotent; also run by `Drop`.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.available.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().expect("codec workers lock"));
        for handle in handles {
            let _ = handle.join();
        }
    }
}

impl Drop for CodecPlane {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl StreamShared {
    fn new(seqdec: Option<StreamingDecoder>) -> StreamShared {
        StreamShared {
            state: Mutex::new(StreamState {
                seqdec,
                seq_input: VecDeque::new(),
                scheduled: false,
                errored: false,
                done: BTreeMap::new(),
                next_emit: 0,
                res: None,
            }),
            delivered: Condvar::new(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum StreamKind {
    /// Stateless or scan-boundary format: body pieces fan out.
    Parallel { format: Format, word: usize },
    /// Serial state machine: pieces queue through one decoder.
    Sequential,
    /// Headerless SPIF wire words (stateless, geometry-filtered).
    Spif { geometry: Resolution },
}

/// Submitter-side handle for one stream. Single-owner (`Send`, not
/// `Sync`): the reader thread that fills it also polls it. Pieces
/// submitted here decode on the plane's workers; [`poll`](Self::poll)
/// returns them in submission order, byte-identical to inline decode.
pub struct DecodeStream {
    plane: Arc<CodecPlane>,
    shared: Arc<StreamShared>,
    kind: StreamKind,
    /// Parallel formats: the header-phase decoder, `Some` until the
    /// framing header is fully consumed.
    header: Option<StreamingDecoder>,
    /// Bytes of a torn trailing word, carried to the next submit.
    carry: Vec<u8>,
    /// EVT2: entry state for the next piece (the last `TIME_HIGH` seen
    /// by the pre-scan across every byte submitted so far).
    evt2_entry: Option<u64>,
    next_seq: u64,
    finished: bool,
    /// Sticky: the first error surfaced, re-returned on later polls.
    failed: Option<String>,
    res: Option<Resolution>,
}

impl DecodeStream {
    fn new(plane: Arc<CodecPlane>, kind: StreamKind, decoder: StreamingDecoder) -> DecodeStream {
        let (header, seqdec) = match kind {
            // The sequential decoder lives with the stream state so any
            // worker can check it out; header handling is part of it.
            StreamKind::Sequential => (None, Some(decoder)),
            // Parallel formats consume the header on the submit side.
            _ => (Some(decoder), None),
        };
        DecodeStream {
            plane,
            shared: Arc::new(StreamShared::new(seqdec)),
            kind,
            header,
            carry: Vec::new(),
            evt2_entry: None,
            next_seq: 0,
            finished: false,
            failed: None,
            res: None,
        }
    }

    /// Geometry, once known (parallel formats: after the header;
    /// sequential formats: once a worker's decoder has seen it; SPIF:
    /// the declared canvas).
    pub fn resolution(&self) -> Option<Resolution> {
        if self.res.is_some() {
            return self.res;
        }
        self.shared.state.lock().expect("stream state lock").res
    }

    /// Pieces submitted but not yet delivered through `poll`.
    pub fn backlog(&self) -> usize {
        let state = self.shared.state.lock().expect("stream state lock");
        (self.next_seq - state.next_emit) as usize
    }

    /// Submit one chunk of stream bytes (file read, socket read) for
    /// decode. Byte boundaries are arbitrary — torn words and split
    /// headers carry exactly as they do in [`StreamingDecoder::feed`].
    pub fn submit(&mut self, bytes: &[u8]) -> Result<()> {
        self.submit_stamped(bytes, 0)
    }

    /// [`submit`](Self::submit) with an arrival timestamp, for wire
    /// formats that carry none (SPIF words are stamped `t`).
    pub fn submit_stamped(&mut self, bytes: &[u8], t: u64) -> Result<()> {
        debug_assert!(!self.finished, "submit after finish");
        match self.kind {
            StreamKind::Sequential => {
                self.submit_sequential(bytes, false);
                Ok(())
            }
            StreamKind::Spif { geometry } => {
                self.submit_words(bytes, 4, |_| Entry::Spif { t, geometry });
                Ok(())
            }
            StreamKind::Parallel { format, word } => {
                let body_owned;
                let mut body = bytes;
                if let Some(dec) = self.header.as_mut() {
                    if !dec.feed_header(bytes)? {
                        return Ok(()); // still inside the header
                    }
                    let mut dec = self.header.take().expect("header decoder present");
                    self.res = dec.resolution();
                    body_owned = dec.take_pending_body();
                    body = &body_owned;
                }
                self.submit_parallel_body(body, format, word);
                Ok(())
            }
        }
    }

    /// Split word-aligned body bytes into pieces and queue them, with
    /// per-format entry state.
    fn submit_parallel_body(&mut self, body: &[u8], format: Format, word: usize) {
        match format {
            Format::Raw => self.submit_words(body, word, |_| Entry::Raw),
            Format::Aedat2 => self.submit_words(body, word, |_| Entry::Aedat2),
            Format::Dat => self.submit_words(body, word, |_| Entry::Dat),
            Format::Evt2 => {
                // Thread the pre-scanned TIME_HIGH state through the
                // pieces: each decodes under exactly the inline state.
                let mut entry = self.evt2_entry;
                self.submit_words(body, word, |piece| {
                    let this = Entry::Evt2 { time_high: entry };
                    if let Some(th) = simd::evt2_scan_last_time_high(piece) {
                        entry = Some(th);
                    }
                    this
                });
                self.evt2_entry = entry;
            }
            _ => unreachable!("sequential formats never take the parallel path"),
        }
    }

    /// Copy `carry + bytes` into one pooled buffer, cut it into
    /// word-aligned pieces (ranges over the shared allocation), and
    /// queue each with the entry state `entry_for` assigns. The torn
    /// tail becomes the next carry.
    fn submit_words(
        &mut self,
        bytes: &[u8],
        word: usize,
        mut entry_for: impl FnMut(&[u8]) -> Entry,
    ) {
        let total = self.carry.len() + bytes.len();
        let aligned = total / word * word;
        if aligned == 0 {
            self.carry.extend_from_slice(bytes);
            return;
        }
        let mut buf = self.plane.bytes.get(aligned);
        let from_carry = self.carry.len().min(aligned);
        buf.extend_from_slice(&self.carry[..from_carry]);
        buf.extend_from_slice(&bytes[..aligned - from_carry]);
        self.carry.drain(..from_carry);
        self.carry.extend_from_slice(&bytes[aligned - from_carry..]);
        let shared_buf = Arc::new(buf);
        let pieces = aligned.div_ceil(PIECE_BYTES);
        let per = (aligned / pieces / word).max(1) * word;
        let mut start = 0;
        while start < aligned {
            let end = if aligned - start < per + word { aligned } else { start + per };
            let entry = entry_for(&shared_buf[start..end]);
            let seq = self.next_seq;
            self.next_seq += 1;
            self.plane.shared.enqueue(Job::Piece {
                stream: Arc::clone(&self.shared),
                seq,
                bytes: Arc::clone(&shared_buf),
                start,
                end,
                entry,
            });
            start = end;
        }
        // Reclaimed for a future read once every piece has decoded.
        self.plane.bytes.recycle_arc(shared_buf);
    }

    /// Queue bytes for a sequential stream and make sure a drain job
    /// is scheduled (at most one in flight per stream).
    fn submit_sequential(&mut self, bytes: &[u8], finish: bool) {
        let mut buf = self.plane.bytes.get(bytes.len());
        buf.extend_from_slice(bytes);
        let end = buf.len();
        let shared_buf = Arc::new(buf);
        let seq = self.next_seq;
        self.next_seq += 1;
        let mut state = self.shared.state.lock().expect("stream state lock");
        state.seq_input.push_back(SeqPiece {
            seq,
            bytes: Arc::clone(&shared_buf),
            start: 0,
            end,
            finish,
        });
        let need_job = !state.scheduled;
        state.scheduled = true;
        drop(state);
        self.plane.bytes.recycle_arc(shared_buf);
        if need_job {
            self.plane.shared.enqueue(Job::Drain { stream: Arc::clone(&self.shared) });
        }
    }

    /// End of stream: flush trailing state and validate completeness
    /// with the same errors inline decode raises. Results still in
    /// flight after `finish` are drained with [`poll`](Self::poll) /
    /// [`poll_wait`](Self::poll_wait) until [`done`](Self::done).
    pub fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        match self.kind {
            StreamKind::Sequential => {
                self.submit_sequential(&[], true);
                Ok(())
            }
            // A torn SPIF word at disconnect is dropped, exactly as the
            // inline reader loop drops its carry.
            StreamKind::Spif { .. } => Ok(()),
            StreamKind::Parallel { format, word } => {
                if let Some(dec) = self.header.as_mut() {
                    // EOF inside the header: legal only for the
                    // comment-header formats, same as inline.
                    dec.finish_header_at_eof()?;
                    let mut dec = self.header.take().expect("header decoder present");
                    self.res = dec.resolution();
                    let body = dec.take_pending_body();
                    self.submit_parallel_body(&body, format, word);
                }
                if !self.carry.is_empty() {
                    // Short names exactly as StreamingDecoder::finish
                    // spells them (Display says "aedat2.0").
                    let name = match format {
                        Format::Raw => "raw",
                        Format::Aedat2 => "aedat2",
                        Format::Dat => "dat",
                        _ => "evt2",
                    };
                    let n = self.carry.len();
                    bail!("{name}: trailing {n} bytes (body not a multiple of {word})");
                }
                Ok(())
            }
        }
    }

    /// `true` once every submitted piece has been delivered (or the
    /// stream failed).
    pub fn done(&self) -> bool {
        let state = self.shared.state.lock().expect("stream state lock");
        state.next_emit >= self.next_seq || self.failed.is_some()
    }

    /// Non-blocking drain: append every in-order completed result to
    /// `out`, returning the geometry-rejected count surfaced with them.
    pub fn poll(&mut self, out: &mut Vec<Event>) -> Result<u64> {
        let mut state = self.shared.state.lock().expect("stream state lock");
        self.drain_ready(&mut state, out)
    }

    /// Blocking drain: wait until at least the next in-order result is
    /// available (no-op when nothing is outstanding), then drain.
    pub fn poll_wait(&mut self, out: &mut Vec<Event>) -> Result<u64> {
        if let Some(msg) = &self.failed {
            return Err(anyhow!("{msg}"));
        }
        let mut state = self.shared.state.lock().expect("stream state lock");
        while state.next_emit < self.next_seq && !state.done.contains_key(&state.next_emit) {
            // Bounded waits: workers drain everything queued before a
            // shutdown joins them, but a piece submitted *after* the
            // join will never decode — surface that instead of hanging
            // a detached reader thread forever.
            let (next, timeout) = self
                .shared
                .delivered
                .wait_timeout(state, std::time::Duration::from_millis(50))
                .expect("stream state lock");
            state = next;
            if timeout.timed_out()
                && self.plane.is_closed()
                && !state.done.contains_key(&state.next_emit)
            {
                bail!("codec plane shut down with pieces still undecoded");
            }
        }
        self.drain_ready(&mut state, out)
    }

    fn drain_ready(&mut self, state: &mut StreamState, out: &mut Vec<Event>) -> Result<u64> {
        if let Some(msg) = &self.failed {
            return Err(anyhow!("{msg}"));
        }
        let mut rejected = 0u64;
        while let Some(result) = state.done.remove(&state.next_emit) {
            state.next_emit += 1;
            match result {
                Ok(mut piece) => {
                    rejected += piece.rejected;
                    if out.is_empty() {
                        *out = std::mem::take(&mut piece.events);
                    } else {
                        out.append(&mut piece.events);
                    }
                }
                Err(e) => {
                    self.failed = Some(format!("{e:#}"));
                    return Err(e);
                }
            }
        }
        if self.res.is_none() {
            self.res = state.res;
        }
        Ok(rejected)
    }
}

/// One worker: pull jobs until shutdown *and* the queue is empty.
fn worker_loop(shared: &PlaneShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("codec queue lock");
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Relaxed) {
                    break None;
                }
                q = shared.available.wait(q).expect("codec queue lock");
            }
        };
        let Some(job) = job else { return };
        let busy = shared.busy_now.fetch_add(1, Ordering::Relaxed) + 1;
        PlaneShared::bump_peak(&shared.busy_peak, busy);
        shared.jobs.fetch_add(1, Ordering::Relaxed);
        match job {
            Job::Piece { stream, seq, bytes, start, end, entry } => {
                let result = decode_piece(&bytes[start..end], entry);
                drop(bytes); // release the pooled buffer before parking
                deliver(shared, &stream, seq, result);
            }
            Job::Drain { stream } => drain_sequential(shared, &stream),
        }
        shared.busy_now.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Decode one independent piece under its entry state.
fn decode_piece(bytes: &[u8], entry: Entry) -> Result<PieceOutput> {
    let mut out = PieceOutput::default();
    match entry {
        Entry::Raw => simd::decode_raw_words(bytes, &mut out.events),
        Entry::Aedat2 => simd::decode_aedat2_words(bytes, &mut out.events),
        Entry::Dat => simd::decode_dat_words(bytes, &mut out.events),
        Entry::Evt2 { time_high } => {
            let mut th = time_high;
            simd::decode_evt2_words(bytes, &mut th, &mut out.events)?;
        }
        Entry::Spif { t, geometry } => {
            out.events.reserve(bytes.len() / 4);
            for word in bytes.chunks_exact(4) {
                let ev = spif::unpack_word(u32::from_le_bytes(word.try_into().unwrap()), t);
                if geometry.contains(&ev) {
                    out.events.push(ev);
                } else {
                    out.rejected += 1;
                }
            }
        }
    }
    Ok(out)
}

/// Check the sequential decoder out of the stream, run every queued
/// piece through it, and check it back in — re-enqueueing another drain
/// if input raced in meanwhile.
fn drain_sequential(shared: &PlaneShared, stream: &Arc<StreamShared>) {
    loop {
        let (mut dec, pieces, errored) = {
            let mut state = stream.state.lock().expect("stream state lock");
            debug_assert!(state.scheduled);
            if state.seq_input.is_empty() {
                state.scheduled = false;
                return;
            }
            let pieces: Vec<SeqPiece> = state.seq_input.drain(..).collect();
            (state.seqdec.take(), pieces, state.errored)
        };
        let mut results: Vec<(u64, Result<PieceOutput>)> = Vec::with_capacity(pieces.len());
        for piece in pieces {
            if errored || dec.is_none() {
                // The stream already failed: later pieces complete
                // empty (the error surfaced at its own seq).
                results.push((piece.seq, Ok(PieceOutput::default())));
                continue;
            }
            let decoder = dec.as_mut().expect("sequential decoder checked out");
            let mut out = PieceOutput::default();
            let fed = decoder.feed(&piece.bytes[piece.start..piece.end], &mut out.events);
            let finished = match (fed, piece.finish) {
                (Ok(()), true) => decoder.finish(&mut out.events),
                (result, _) => result,
            };
            match finished {
                Ok(()) => results.push((piece.seq, Ok(out))),
                Err(e) => {
                    results.push((piece.seq, Err(e)));
                    dec = None; // the state machine is poisoned
                }
            }
        }
        let mut state = stream.state.lock().expect("stream state lock");
        if dec.is_none() {
            state.errored = true;
        }
        if let Some(decoder) = &dec {
            if state.res.is_none() {
                state.res = decoder.resolution();
            }
        }
        state.seqdec = dec;
        for (seq, result) in results {
            state.done.insert(seq, result);
        }
        PlaneShared::bump_peak(&shared.lag_peak, state.done.len() as u64);
        let more = !state.seq_input.is_empty();
        if !more {
            state.scheduled = false;
        }
        drop(state);
        stream.delivered.notify_all();
        if !more {
            return;
        }
    }
}

/// Insert one piece result into its stream's reassembly and wake the
/// poller.
fn deliver(shared: &PlaneShared, stream: &StreamShared, seq: u64, result: Result<PieceOutput>) {
    let mut state = stream.state.lock().expect("stream state lock");
    state.done.insert(seq, result);
    PlaneShared::bump_peak(&shared.lag_peak, state.done.len() as u64);
    drop(state);
    stream.delivered.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::EventCodec;
    use crate::testutil::synthetic_events_seeded;

    fn plane(workers: usize) -> Arc<CodecPlane> {
        CodecPlane::new(CodecPlaneConfig::with_workers(workers))
    }

    #[test]
    fn every_format_decodes_identically_through_the_plane() {
        let events = synthetic_events_seeded(5000, 346, 260, 0xC0DEC);
        let res = Resolution::DAVIS_346;
        let plane = plane(3);
        for format in Format::ALL {
            let mut bytes = Vec::new();
            format.codec().encode(&events, res, &mut bytes).unwrap();
            // The contract is inline equivalence: same events, same
            // discovered geometry, for any submit chunking.
            let mut inline = Vec::new();
            let mut dec = StreamingDecoder::new(format);
            dec.feed(&bytes, &mut inline).unwrap();
            dec.finish(&mut inline).unwrap();
            for chunk in [13usize, 1024, 65536] {
                let mut stream = plane.open_stream(format);
                let mut out = Vec::new();
                for piece in bytes.chunks(chunk) {
                    stream.submit(piece).unwrap();
                    stream.poll(&mut out).unwrap();
                }
                stream.finish().unwrap();
                while !stream.done() {
                    stream.poll_wait(&mut out).unwrap();
                }
                assert_eq!(out, inline, "{format} chunk={chunk}");
                assert_eq!(stream.resolution(), dec.resolution(), "{format} chunk={chunk}");
            }
        }
    }

    #[test]
    fn truncated_streams_error_like_inline_decode() {
        let events = synthetic_events_seeded(300, 64, 64, 0xBAD);
        for format in [Format::Raw, Format::Evt2, Format::Evt3, Format::Aedat] {
            let mut bytes = Vec::new();
            format.codec().encode(&events, Resolution::new(64, 64), &mut bytes).unwrap();
            bytes.truncate(bytes.len() - 1);
            let plane = plane(2);
            let mut stream = plane.open_stream(format);
            let mut out = Vec::new();
            let result = stream
                .submit(&bytes)
                .and_then(|()| stream.finish())
                .and_then(|()| {
                    while !stream.done() {
                        stream.poll_wait(&mut out)?;
                    }
                    Ok(())
                });
            assert!(result.is_err(), "{format} accepted a truncated stream");
        }
    }

    #[test]
    fn evt2_cd_before_time_high_surfaces_at_the_right_seq() {
        // An EVT2 stream whose very first body word is CD (type 0x1,
        // no preceding TIME_HIGH): the error belongs to seq 0 and must
        // surface exactly once.
        let mut bytes = Vec::new();
        Format::Evt2.codec().encode(&[], Resolution::new(64, 64), &mut bytes).unwrap();
        bytes.extend_from_slice(&((0x1u32 << 28) | 7).to_le_bytes());
        let plane = plane(2);
        let mut stream = plane.open_stream(Format::Evt2);
        stream.submit(&bytes).unwrap();
        stream.finish().unwrap();
        let mut out = Vec::new();
        let err = loop {
            match stream.poll_wait(&mut out) {
                Err(e) => break e,
                Ok(_) if stream.done() => panic!("expected a decode error"),
                Ok(_) => continue,
            }
        };
        assert!(format!("{err}").contains("before any TIME_HIGH"), "{err}");
        // Sticky: the poller keeps seeing the failure.
        assert!(stream.poll(&mut out).is_err());
    }

    #[test]
    fn spif_streams_stamp_filter_and_count_rejects() {
        let geometry = Resolution::new(16, 16);
        let plane = plane(2);
        let mut stream = plane.open_spif_stream(geometry);
        let inside = Event::on(3, 4, 0);
        let outside = Event::on(300, 4, 0);
        let mut wire = Vec::new();
        wire.extend_from_slice(&spif::pack_word(&inside).to_le_bytes());
        wire.extend_from_slice(&spif::pack_word(&outside).to_le_bytes());
        stream.submit_stamped(&wire, 77).unwrap();
        stream.finish().unwrap();
        let mut out = Vec::new();
        let mut rejected = 0;
        while !stream.done() {
            rejected += stream.poll_wait(&mut out).unwrap();
        }
        assert_eq!(rejected, 1);
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].x, out[0].y, out[0].t), (3, 4, 77));
    }

    #[test]
    fn shutdown_finishes_queued_work_and_joins() {
        let events = synthetic_events_seeded(2000, 128, 128, 0x0FF);
        let mut bytes = Vec::new();
        Format::Raw.codec().encode(&events, Resolution::new(128, 128), &mut bytes).unwrap();
        let plane = plane(4);
        let mut stream = plane.open_stream(Format::Raw);
        stream.submit(&bytes).unwrap();
        stream.finish().unwrap();
        plane.shutdown(); // queued pieces still complete
        let mut out = Vec::new();
        while !stream.done() {
            stream.poll_wait(&mut out).unwrap();
        }
        assert_eq!(out, events);
        assert!(plane.counters().jobs >= 1);
    }
}
