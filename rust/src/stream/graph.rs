//! Declarative topology graphs: describe *any* source→…→sink shape as
//! a value, validate it, and compile it onto the streaming machinery.
//!
//! The paper's claim is that coroutine streaming composes freely "from
//! inputs to outputs" — yet until this layer the public API ran exactly
//! one hard-coded shape: N sources → one fused merge → one shared stage
//! chain → M routed sinks. A [`GraphSpec`] makes the graph itself a
//! first-class, user-composable value (the same move vector makes with
//! its source→transform→sink config graph):
//!
//! * **Nodes** — `Source`, `Merge`, `Stages` (a [`PipelineSpec`] with
//!   its own shard placement), `Router` (a [`RoutePolicy`]), `Sink` —
//!   each **named**, with per-node placement (a source on its own pump
//!   thread, a stage chain sharded ×4, a sink behind its own pump)
//!   instead of today's global flags.
//! * **Edges** — explicit, by node name.
//! * [`GraphSpec::validate`] — acyclicity, per-kind degree rules,
//!   dangling-node detection, geometry propagation (layout/offset
//!   conflicts are hard errors), route arity — all with readable
//!   errors, before anything runs.
//! * [`GraphSpec::compile`] — lowers the validated graph onto the
//!   existing execution machinery: the fan-in [`FusedSource`] merge
//!   (per-lane pump threads), a shared [`StageGraph`] chain, the
//!   fan-out router, per-branch [`StageGraph`]s running inside their
//!   branch tasks, [`ThreadedSink`] pumps, per-node
//!   [`LiveNode`](crate::metrics::LiveNode) telemetry and the
//!   [`adapt`](super::adapt) epoch loop. Everything expressible before
//!   lowers to the *same* driver code, so legacy output is
//!   byte-identical (property-tested in `rust/tests/graph_topology.rs`).
//!
//! Build graphs fluently with [`Topology::builder`]:
//!
//! ```no_run
//! use aestream::stream::{Topology, GraphConfig, RoutePolicy, MemorySource, NullSink};
//! use aestream::aer::Resolution;
//! use aestream::pipeline::PipelineSpec;
//!
//! let res = Resolution::new(64, 64);
//! let _report = Topology::builder()
//!     .source("cam", MemorySource::new(Vec::new(), res, 1024))
//!     .source("file", MemorySource::new(Vec::new(), res, 1024))
//!     .merge("fuse", &["cam", "file"])
//!     .stages("denoise", PipelineSpec::new())
//!     .route("split", RoutePolicy::Broadcast)
//!     .stages("left", PipelineSpec::new())
//!     .sink("a", NullSink::default())
//!     .after("split")
//!     .stages("right", PipelineSpec::new())
//!     .sink("b", NullSink::default())
//!     .build()
//!     .run(GraphConfig::default())?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The first genuinely new shape this unlocks is the ROADMAP's
//! multi-device fan-out: one merged stream splitting into two
//! independent stage chains feeding two detector sessions — see
//! `examples/graph_topology.rs`.
//!
//! Current compile support is one merge trunk with one fan-out point
//! (an explicit router, or implicitly the node whose output several
//! branches consume), **or** the sharded fan-in: several merge nodes,
//! each fed by *every* source, where merge *i* owns stripe *i* of the
//! fused canvas (in declaration order) and runs its own stage chain and
//! sink. Per-stripe merges lower to the single physical fan-in plus a
//! [`RoutePolicy::Stripes`] router — byte-identical to writing the
//! router explicitly, and copy-free now that stripe scatter builds
//! refcounted chunk views. Nested routers remain future work and are
//! rejected with readable errors.

use std::collections::HashMap;

use anyhow::{bail, Context as _, Result};

use crate::aer::Resolution;
use crate::pipeline::fusion::SourceLayout;
use crate::pipeline::PipelineSpec;

use super::adapt::AdaptiveConfig;
use super::buffer::{DiskBufferConfig, DiskBufferedSink};
use super::report::ReportTarget;
use super::stage::{StageGraph, StageOptions};
use super::topology::{
    default_layout, explicit_layout, grid_layout, run_nodes, BranchRun, RoutePolicy,
};
use super::{EventSink, EventSource, StreamConfig, StreamDriver, StreamReport, ThreadedSink};

/// Fused-canvas arrangement policy for a merge node (the CLI's
/// `--layout`). Explicit per-source offsets
/// ([`SourceOptions::offset`]) replace the policy entirely — declaring
/// both is a validation error.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FusionLayout {
    /// Sources in one row, left to right (the historical default).
    #[default]
    SideBySide,
    /// Sources tiled in a near-square row-major grid.
    Grid,
    /// All sources share the origin on one address plane.
    Overlay,
}

impl FusionLayout {
    fn label(&self) -> &'static str {
        match self {
            FusionLayout::SideBySide => "side-by-side",
            FusionLayout::Grid => "grid",
            FusionLayout::Overlay => "overlay",
        }
    }
}

/// Per-source-node placement options.
#[derive(Debug, Clone, Copy, Default)]
pub struct SourceOptions {
    /// Explicit placement on the fused canvas (the CLI's `--offset`).
    /// Any offset switches the merge to the explicit layout; combining
    /// offsets with a declared [`FusionLayout`] is a validation error.
    pub offset: Option<(u16, u16)>,
    /// Pin this source to its own OS pump thread, feeding the merge
    /// through the lock-free ring (per-node form of the legacy
    /// all-or-nothing `--threads`).
    pub threaded: bool,
}

/// Execution parameters for a compiled graph. Threading and routing are
/// *per-node* properties of the graph itself; only the edge-level knobs
/// remain global.
#[derive(Debug, Clone)]
pub struct GraphConfig {
    /// Target events per batch (and the per-hop memory unit).
    pub chunk_size: usize,
    /// Edge scheduling strategy.
    pub driver: StreamDriver,
    /// Adaptive controllers run at epoch barriers against the shared
    /// trunk chain (`None` = static runtime).
    pub adaptive: Option<AdaptiveConfig>,
    /// Stream one JSON line per telemetry epoch (plus a final report
    /// line) to a file or stdout — the CLI's `--report-json`. With no
    /// adaptive config an empty epoch loop is synthesized so the
    /// emitter still ticks.
    pub report_json: Option<ReportTarget>,
    /// Decode worker budget for the shared codec plane
    /// (`--decode-threads`); `None` keeps packed-format decode inline
    /// on each ingest thread. See [`super::codec_plane`].
    pub decode_threads: Option<usize>,
}

impl From<StreamConfig> for GraphConfig {
    fn from(config: StreamConfig) -> Self {
        GraphConfig {
            chunk_size: config.chunk_size,
            driver: config.driver,
            adaptive: None,
            report_json: None,
            decode_threads: None,
        }
    }
}

impl Default for GraphConfig {
    fn default() -> Self {
        StreamConfig::default().into()
    }
}

/// A sink slot: inline, or deferred-wrapped behind its own pump thread
/// (the wrap happens at compile so the pump only spawns for graphs that
/// actually run).
enum SinkSlot<'a> {
    Inline(Box<dyn EventSink + 'a>),
    Threaded { describe: String, spawn: Box<dyn FnOnce() -> ThreadedSink + Send + 'a> },
    /// A durable edge (`buffer = disk{cap, dir}`): the sink drains
    /// through a [`DiskBufferedSink`] journal, spawned at compile like
    /// the pump above.
    Buffered { describe: String, config: DiskBufferConfig, sink: Box<dyn EventSink> },
}

impl SinkSlot<'_> {
    fn describe(&self) -> String {
        match self {
            SinkSlot::Inline(sink) => sink.describe(),
            SinkSlot::Threaded { describe, .. } => format!("thread({describe})"),
            SinkSlot::Buffered { describe, .. } => format!("diskbuf({describe})"),
        }
    }
}

/// What a named node *is*.
enum NodeKind<'a> {
    Source { source: Box<dyn EventSource + 'a>, offset: Option<(u16, u16)>, threaded: bool },
    /// A serving-plane listener (e.g. [`crate::serve::ListenerSource`]):
    /// a graph root like a source, but one whose
    /// [`client_plane`](EventSource::client_plane) attaches dynamic
    /// per-client lanes to the merge while the graph runs. Never
    /// pumped — its control lane must stay in-process so the plane
    /// reaches the merge driver.
    Listener { source: Box<dyn EventSource + 'a> },
    Merge { layout: Option<FusionLayout> },
    Stages { spec: PipelineSpec, opts: StageOptions },
    Router { policy: RoutePolicy },
    Sink { slot: SinkSlot<'a> },
}

impl NodeKind<'_> {
    fn word(&self) -> &'static str {
        match self {
            NodeKind::Source { .. } => "source",
            NodeKind::Listener { .. } => "listen",
            NodeKind::Merge { .. } => "merge",
            NodeKind::Stages { .. } => "stages",
            NodeKind::Router { .. } => "route",
            NodeKind::Sink { .. } => "sink",
        }
    }
}

struct GraphNode<'a> {
    name: String,
    kind: NodeKind<'a>,
}

/// A declarative topology: named nodes plus explicit edges. Build one
/// with [`Topology::builder`], check it with
/// [`validate`](GraphSpec::validate), execute it with
/// [`compile`](GraphSpec::compile)/[`run`](GraphSpec::run).
///
/// The lifetime `'a` bounds the sources and sinks; `'static` for the
/// common case, shorter when a sink borrows (e.g. a detector session
/// borrowing its device).
pub struct GraphSpec<'a> {
    nodes: Vec<GraphNode<'a>>,
    edges: Vec<(String, String)>,
}

/// Namespace for [`Topology::builder`].
pub struct Topology;

impl Topology {
    /// Start a fluent graph description.
    pub fn builder<'a>() -> TopologyBuilder<'a> {
        TopologyBuilder {
            spec: GraphSpec { nodes: Vec::new(), edges: Vec::new() },
            cursor: None,
        }
    }
}

/// Fluent [`GraphSpec`] construction. Every node-adding call connects
/// the new node after the *cursor* (the most recently added node) and
/// moves the cursor onto it; [`after`](TopologyBuilder::after) repoints
/// the cursor at any existing node, which is how sibling branches fork
/// from a router. Nothing is checked until
/// [`GraphSpec::validate`]/[`compile`](GraphSpec::compile) — the
/// builder itself never fails, so chains stay fluent.
pub struct TopologyBuilder<'a> {
    spec: GraphSpec<'a>,
    cursor: Option<String>,
}

impl<'a> TopologyBuilder<'a> {
    fn push(&mut self, name: &str, kind: NodeKind<'a>, link_from_cursor: bool) {
        if link_from_cursor {
            if let Some(cursor) = &self.cursor {
                self.spec.edges.push((cursor.clone(), name.to_string()));
            }
        }
        self.spec.nodes.push(GraphNode { name: name.to_string(), kind });
        self.cursor = Some(name.to_string());
    }

    /// Add a source node (a graph root: no inbound edge).
    pub fn source(self, name: &str, source: impl EventSource + 'a) -> Self {
        self.source_with(name, source, SourceOptions::default())
    }

    /// [`source`](Self::source) with placement options.
    pub fn source_with(
        mut self,
        name: &str,
        source: impl EventSource + 'a,
        opts: SourceOptions,
    ) -> Self {
        self.push(
            name,
            NodeKind::Source {
                source: Box::new(source),
                offset: opts.offset,
                threaded: opts.threaded,
            },
            false,
        );
        self
    }

    /// Add a serving-plane listener node (a graph root, like
    /// [`source`](Self::source)). The listener's declared geometry
    /// joins the merge canvas once; every client admitted while the
    /// graph runs becomes a dynamic merge lane with its own
    /// [`LiveNode`](crate::metrics::LiveNode), attached at the next
    /// safe merge point.
    pub fn listen(mut self, name: &str, source: impl EventSource + 'a) -> Self {
        self.push(name, NodeKind::Listener { source: Box::new(source) }, false);
        self
    }

    /// Add the timestamp-ordered fan-in merge of the named sources.
    /// With no declared layout, explicit source offsets win; otherwise
    /// the sources sit side by side.
    pub fn merge(mut self, name: &str, inputs: &[&str]) -> Self {
        for input in inputs {
            self.spec.edges.push((input.to_string(), name.to_string()));
        }
        self.push(name, NodeKind::Merge { layout: None }, false);
        self
    }

    /// [`merge`](Self::merge) with an explicit canvas arrangement.
    /// Combining this with per-source offsets is a validation error.
    pub fn merge_with_layout(mut self, name: &str, inputs: &[&str], layout: FusionLayout) -> Self {
        for input in inputs {
            self.spec.edges.push((input.to_string(), name.to_string()));
        }
        self.push(name, NodeKind::Merge { layout: Some(layout) }, false);
        self
    }

    /// Add a stage-chain node after the cursor (serial placement).
    pub fn stages(self, name: &str, spec: PipelineSpec) -> Self {
        self.stages_with(name, spec, StageOptions::default())
    }

    /// [`stages`](Self::stages) with shard placement: the chain's
    /// shardable stages run as `opts.shards` stripe-shard workers,
    /// inline or one OS thread each.
    pub fn stages_with(mut self, name: &str, spec: PipelineSpec, opts: StageOptions) -> Self {
        self.push(name, NodeKind::Stages { spec, opts }, true);
        self
    }

    /// Add a fan-out router after the cursor. Each node subsequently
    /// attached `.after()` this router starts its own branch.
    pub fn route(mut self, name: &str, policy: RoutePolicy) -> Self {
        self.push(name, NodeKind::Router { policy }, true);
        self
    }

    /// Add a sink node after the cursor (terminates a branch).
    pub fn sink(mut self, name: &str, sink: impl EventSink + 'a) -> Self {
        self.push(name, NodeKind::Sink { slot: SinkSlot::Inline(Box::new(sink)) }, true);
        self
    }

    /// [`sink`](Self::sink) pinned behind its own OS pump thread (the
    /// per-node form of `--sink-threads`); requires a `'static` sink
    /// because the pump outlives the builder's borrows.
    pub fn sink_threaded(mut self, name: &str, sink: impl EventSink + 'static) -> Self {
        let sink: Box<dyn EventSink> = Box::new(sink);
        let describe = sink.describe();
        self.push(
            name,
            NodeKind::Sink {
                slot: SinkSlot::Threaded {
                    describe,
                    spawn: Box::new(move || ThreadedSink::spawn(sink)),
                },
            },
            true,
        );
        self
    }

    /// [`sink`](Self::sink) behind a durable spill-to-disk edge: every
    /// batch is journaled to `config.dir` with CRC framing, a bounded
    /// in-memory front spills to the journal when the sink falls
    /// behind, and delivery is tracked in `acked.offset` for
    /// at-least-once replay ([`super::buffer`]). Requires a `'static`
    /// sink because the drainer thread outlives the builder's borrows.
    pub fn sink_buffered(
        mut self,
        name: &str,
        sink: impl EventSink + 'static,
        config: DiskBufferConfig,
    ) -> Self {
        let sink: Box<dyn EventSink> = Box::new(sink);
        let describe = sink.describe();
        self.push(
            name,
            NodeKind::Sink { slot: SinkSlot::Buffered { describe, config, sink } },
            true,
        );
        self
    }

    /// Repoint the cursor at an existing node, so the next added node
    /// chains after *it* — how sibling branches fork from one router.
    pub fn after(mut self, node: &str) -> Self {
        self.cursor = Some(node.to_string());
        self
    }

    /// Add an explicit extra edge by name (power users; most chains
    /// never need it).
    pub fn edge(mut self, from: &str, to: &str) -> Self {
        self.spec.edges.push((from.to_string(), to.to_string()));
        self
    }

    /// Geometry the graph-so-far propagates: the fused canvas and
    /// whether every source's extent is declared (vs observed-only).
    /// Useful for opening geometry-recording sinks before adding their
    /// nodes — the coordinator's lowering does exactly that.
    pub fn planned_geometry(&self) -> Result<(Resolution, bool)> {
        let (_, canvas, known) = planned_layout(&self.spec.nodes)?;
        Ok((canvas, known))
    }

    /// Finish the description. Nothing has been checked yet — call
    /// [`GraphSpec::validate`] (or let [`compile`](GraphSpec::compile)
    /// do it) for the full pass.
    pub fn build(self) -> GraphSpec<'a> {
        self.spec
    }
}

// ------------------------------------------------------------ validation

/// The validated execution plan: node indices arranged into the
/// supported trunk-and-branches family.
struct Plan {
    sources: Vec<usize>,
    trunk: Vec<usize>,
    route: RoutePolicy,
    /// Per branch: its stage-chain nodes (possibly empty) and its sink.
    branches: Vec<(Vec<usize>, usize)>,
    layout: Option<SourceLayout>,
    canvas: Resolution,
}

/// Geometry propagation over the node list alone (no edges needed):
/// the merge layout — from explicit offsets or the declared policy —
/// plus the resulting canvas and whether every source declares its
/// extent. Shared by [`GraphSpec::plan`] and
/// [`TopologyBuilder::planned_geometry`].
fn planned_layout(nodes: &[GraphNode<'_>]) -> Result<(Option<SourceLayout>, Resolution, bool)> {
    let mut resolutions = Vec::new();
    let mut offsets: Vec<Option<(u16, u16)>> = Vec::new();
    let mut known = true;
    let mut first_offset: Option<&str> = None;
    let mut merges: Vec<(&str, Option<FusionLayout>)> = Vec::new();
    for node in nodes {
        match &node.kind {
            NodeKind::Source { source, offset, .. } => {
                resolutions.push(source.resolution());
                offsets.push(*offset);
                known &= source.geometry_known();
                if offset.is_some() && first_offset.is_none() {
                    first_offset = Some(&node.name);
                }
            }
            NodeKind::Listener { source } => {
                if !source.geometry_known() {
                    bail!(
                        "listener {:?} needs a declared geometry (clients attach to a \
                         fixed canvas; there is nothing to observe before they do)",
                        node.name
                    );
                }
                resolutions.push(source.resolution());
                offsets.push(None);
            }
            NodeKind::Merge { layout } => merges.push((&node.name, *layout)),
            _ => {}
        }
    }
    if resolutions.is_empty() {
        bail!("graph has no source nodes");
    }
    // Several merge nodes = the sharded fan-in (merge i owns stripe i of
    // the fused canvas). They all see the same canvas, so their layout
    // declarations must agree.
    if let Some(&(first_name, first_layout)) = merges.first() {
        for &(other_name, other_layout) in &merges[1..] {
            if other_layout != first_layout {
                bail!(
                    "per-stripe merges must agree on the canvas layout: {first_name:?} \
                     declares {:?}, {other_name:?} declares {:?}",
                    first_layout.map(|l| l.label()),
                    other_layout.map(|l| l.label()),
                );
            }
        }
    }
    let any_offset = first_offset.is_some();
    let Some(&(merge_name, layout_choice)) = merges.first() else {
        if resolutions.len() > 1 {
            bail!(
                "{} sources but no merge node; add .merge(name, inputs) to fan them in",
                resolutions.len()
            );
        }
        if let Some(source) = first_offset {
            bail!(
                "source {source:?} declares an offset but the graph has no merge node \
                 to place it on a canvas"
            );
        }
        return Ok((None, resolutions[0], known));
    };
    if let (Some(layout), Some(source)) = (layout_choice, first_offset) {
        // The documented-but-invisible legacy behavior (offsets
        // silently overriding --layout) is now a hard error.
        bail!(
            "merge {merge_name:?} declares layout {:?} but source {source:?} also \
             declares an explicit --offset; offsets define the canvas — drop \
             one of the two",
            layout.label(),
        );
    }
    if !known {
        bail!(
            "fusing a source with unknown geometry needs a declared extent \
             (the CLI's --geometry WxH): a live or headerless source only \
             observes its bounds"
        );
    }
    let layout = if any_offset {
        let offsets: Vec<(u16, u16)> = offsets.iter().map(|o| o.unwrap_or((0, 0))).collect();
        explicit_layout(&resolutions, &offsets)?
    } else {
        match layout_choice.unwrap_or_default() {
            FusionLayout::SideBySide => default_layout(&resolutions)?,
            FusionLayout::Grid => grid_layout(&resolutions)?,
            FusionLayout::Overlay => SourceLayout::overlay(&resolutions),
        }
    };
    let canvas = layout.canvas;
    Ok((Some(layout), canvas, known))
}

impl<'a> GraphSpec<'a> {
    /// One line per node: kind, name, inputs, payload description. The
    /// canonical comparison form — the CLI-lowering golden test asserts
    /// clause syntax and builder calls produce identical summaries.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for node in &self.nodes {
            let inputs: Vec<&str> = self
                .edges
                .iter()
                .filter(|(_, to)| *to == node.name)
                .map(|(from, _)| from.as_str())
                .collect();
            let arrow = if inputs.is_empty() {
                String::new()
            } else {
                format!(" <- {}", inputs.join(", "))
            };
            let detail = match &node.kind {
                NodeKind::Source { source, offset, threaded } => {
                    let mut d = format!(": {}", source.describe());
                    if let Some((x, y)) = offset {
                        d.push_str(&format!(" [offset {x},{y}]"));
                    }
                    if *threaded {
                        d.push_str(" [thread]");
                    }
                    d
                }
                NodeKind::Listener { source } => format!(": {}", source.describe()),
                NodeKind::Merge { layout } => {
                    let label = match layout {
                        Some(l) => l.label(),
                        None => "by-offsets-or-default",
                    };
                    format!(" [{label}]")
                }
                NodeKind::Stages { spec, opts } => {
                    let mut d = format!(": {}", spec.describe());
                    if opts.shards > 1 || opts.shard_threads {
                        d.push_str(&format!(
                            " [shards {}{}]",
                            opts.shards.max(1),
                            if opts.shard_threads { ", threads" } else { "" }
                        ));
                    }
                    d
                }
                NodeKind::Router { policy } => format!(" [{policy:?}]"),
                NodeKind::Sink { slot } => format!(": {}", slot.describe()),
            };
            out.push_str(&format!("{} {}{arrow}{detail}\n", node.kind.word(), node.name));
        }
        out
    }

    /// Full validation pass: unique names, resolvable edges, per-kind
    /// degree rules, acyclicity, dangling-node detection, geometry
    /// propagation (with layout/offset conflict rejection), and route
    /// arity — every failure a readable error naming the node.
    pub fn validate(&self) -> Result<()> {
        self.plan().map(|_| ())
    }

    fn plan(&self) -> Result<Plan> {
        // ---- per-node config sanity (cheap, before any graph walk).
        for node in &self.nodes {
            if let NodeKind::Sink { slot: SinkSlot::Buffered { config, .. } } = &node.kind {
                if config.cap_bytes == 0 {
                    bail!("buffered sink {:?}: cap_bytes must be > 0", node.name);
                }
                if config.front_batches == 0 {
                    bail!("buffered sink {:?}: front_batches must be >= 1", node.name);
                }
            }
        }
        // ---- names and edges resolve.
        let mut index: HashMap<&str, usize> = HashMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            if index.insert(node.name.as_str(), i).is_some() {
                bail!("duplicate node name {:?}", node.name);
            }
        }
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(self.edges.len());
        for (from, to) in &self.edges {
            let f = *index.get(from.as_str()).with_context(|| {
                format!("edge {from:?} -> {to:?} references unknown node {from:?}")
            })?;
            let t = *index.get(to.as_str()).with_context(|| {
                format!("edge {from:?} -> {to:?} references unknown node {to:?}")
            })?;
            if edges.contains(&(f, t)) {
                bail!("duplicate edge {from:?} -> {to:?}");
            }
            edges.push((f, t));
        }
        let n = self.nodes.len();
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for &(f, t) in &edges {
            out[f].push(t);
            indeg[t] += 1;
        }
        let name = |i: usize| self.nodes[i].name.as_str();

        // ---- per-kind degree rules.
        for (i, node) in self.nodes.iter().enumerate() {
            match &node.kind {
                NodeKind::Source { .. } | NodeKind::Listener { .. } => {
                    if indeg[i] != 0 {
                        bail!("{} {:?} cannot receive an edge", node.kind.word(), node.name);
                    }
                }
                NodeKind::Merge { .. } => {
                    if indeg[i] == 0 {
                        bail!("merge {:?} has no inputs", node.name);
                    }
                    for &(f, t) in &edges {
                        if t == i
                            && !matches!(
                                self.nodes[f].kind,
                                NodeKind::Source { .. } | NodeKind::Listener { .. }
                            )
                        {
                            bail!(
                                "merge {:?} input {:?} is not a source; only sources \
                                 fan into the merge",
                                node.name,
                                name(f)
                            );
                        }
                    }
                }
                NodeKind::Stages { .. } => {
                    if indeg[i] == 0 {
                        bail!(
                            "stage node {:?} has no input; chain it after another \
                             node (or point the cursor with .after())",
                            node.name
                        );
                    }
                    if indeg[i] > 1 {
                        bail!(
                            "stage node {:?} has {} inputs; expected exactly 1",
                            node.name,
                            indeg[i]
                        );
                    }
                }
                NodeKind::Router { policy } => {
                    if indeg[i] != 1 {
                        bail!("router {:?} needs exactly 1 input, has {}", node.name, indeg[i]);
                    }
                    if out[i].is_empty() {
                        bail!("router {:?} has no outputs", node.name);
                    }
                    if *policy == RoutePolicy::Polarity && out[i].len() != 2 {
                        bail!(
                            "polarity routing requires exactly 2 sinks, got {} \
                             (router {:?})",
                            out[i].len(),
                            node.name
                        );
                    }
                }
                NodeKind::Sink { .. } => {
                    if indeg[i] == 0 {
                        bail!(
                            "sink {:?} has no input; chain it after another node \
                             (or point the cursor with .after())",
                            node.name
                        );
                    }
                    if indeg[i] > 1 {
                        bail!("sink {:?} has {} inputs; expected exactly 1", node.name, indeg[i]);
                    }
                    if !out[i].is_empty() {
                        bail!("sink {:?} cannot feed {:?}", node.name, name(out[i][0]));
                    }
                }
            }
        }

        // ---- acyclicity (Kahn), so the walks below always terminate.
        {
            let mut indeg = indeg.clone();
            let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
            let mut seen = 0usize;
            while let Some(i) = queue.pop() {
                seen += 1;
                for &t in &out[i] {
                    indeg[t] -= 1;
                    if indeg[t] == 0 {
                        queue.push(t);
                    }
                }
            }
            if seen < n {
                let cyclic: Vec<&str> =
                    (0..n).filter(|&i| indeg[i] > 0).map(name).collect();
                bail!("graph has a cycle through {:?}", cyclic);
            }
        }

        // ---- geometry propagation (layout, canvas, conflicts).
        let (layout, canvas, geometry_known) = planned_layout(&self.nodes)?;

        // ---- trunk extraction.
        let sources: Vec<usize> = (0..n)
            .filter(|&i| {
                matches!(
                    self.nodes[i].kind,
                    NodeKind::Source { .. } | NodeKind::Listener { .. }
                )
            })
            .collect();
        let merges: Vec<usize> =
            (0..n).filter(|&i| matches!(self.nodes[i].kind, NodeKind::Merge { .. })).collect();
        let mut visited = vec![false; n];
        for &s in &sources {
            visited[s] = true;
        }
        let mut trunk = Vec::new();
        let (route, branch_heads): (RoutePolicy, Vec<usize>) = if merges.len() >= 2 {
            // The sharded fan-in: merge i owns stripe i of the fused
            // canvas (declaration order), and its chain becomes branch i
            // behind a stripes router over the one physical fan-in —
            // byte-identical to declaring the router explicitly, and
            // copy-free since stripe scatter builds chunk views.
            for &s in &sources {
                let feeds_all = out[s].len() == merges.len()
                    && merges.iter().all(|m| out[s].contains(m));
                if !feeds_all {
                    bail!(
                        "per-stripe merges need every source to feed every merge; \
                         source {:?} feeds {:?}",
                        name(s),
                        out[s].iter().map(|&t| name(t)).collect::<Vec<_>>(),
                    );
                }
            }
            if !geometry_known {
                bail!(
                    "per-stripe merges cut the canvas by pixel column and so require \
                     known source geometry (declare --geometry)"
                );
            }
            let mut heads = Vec::with_capacity(merges.len());
            for &m in &merges {
                visited[m] = true;
                if out[m].len() > 1 {
                    bail!(
                        "merge {:?} fans out; a per-stripe merge owns exactly one \
                         stripe chain (stages, then one sink)",
                        name(m)
                    );
                }
                let Some(&c) = out[m].first() else {
                    bail!("node {:?} dangles: no path to a sink", name(m))
                };
                heads.push(c);
            }
            (RoutePolicy::Stripes, heads)
        } else {
            let head = match merges.first().copied() {
                Some(m) => {
                    for &s in &sources {
                        if out[s].len() != 1 || out[s][0] != m {
                            bail!(
                                "source {:?} must feed the merge {:?} and nothing else \
                                 (or feed every merge, for the per-stripe shape)",
                                name(s),
                                name(m)
                            );
                        }
                    }
                    m
                }
                None => sources[0], // planned_layout guarantees exactly one
            };
            visited[head] = true;
            let mut at = head;
            loop {
                let children = &out[at];
                match children.len() {
                    0 => bail!("node {:?} dangles: no path to a sink", name(at)),
                    1 => {
                        let c = children[0];
                        match &self.nodes[c].kind {
                            NodeKind::Stages { .. } => {
                                visited[c] = true;
                                trunk.push(c);
                                at = c;
                            }
                            NodeKind::Router { policy } => {
                                visited[c] = true;
                                break (*policy, out[c].clone());
                            }
                            NodeKind::Sink { .. } => break (RoutePolicy::Broadcast, vec![c]),
                            NodeKind::Source { .. }
                            | NodeKind::Listener { .. }
                            | NodeKind::Merge { .. } => {
                                // Degree rules above already rejected these.
                                bail!("node {:?} cannot follow {:?}", name(c), name(at));
                            }
                        }
                    }
                    // Several children of a non-router node: an implicit
                    // broadcast fork (the builder's natural shape for
                    // "every branch sees everything").
                    _ => break (RoutePolicy::Broadcast, children.clone()),
                }
            }
        };

        // ---- branches: stage chains ending in exactly one sink.
        let mut branches = Vec::with_capacity(branch_heads.len());
        for head in branch_heads {
            let mut stages = Vec::new();
            let mut at = head;
            let sink = loop {
                visited[at] = true;
                match &self.nodes[at].kind {
                    NodeKind::Sink { .. } => break at,
                    NodeKind::Stages { .. } => {
                        stages.push(at);
                        if out[at].len() > 1 {
                            bail!(
                                "branch node {:?} fans out; only one fan-out point \
                                 per graph is supported",
                                name(at)
                            );
                        }
                        let Some(&c) = out[at].first() else {
                            bail!("node {:?} dangles: no path to a sink", name(at))
                        };
                        at = c;
                    }
                    NodeKind::Router { .. } => bail!(
                        "nested router {:?} is not supported yet (one fan-out \
                         point per graph)",
                        name(at)
                    ),
                    NodeKind::Source { .. }
                    | NodeKind::Listener { .. }
                    | NodeKind::Merge { .. } => {
                        bail!("node {:?} cannot sit on a branch", name(at));
                    }
                }
            };
            branches.push((stages, sink));
        }
        if route == RoutePolicy::Polarity && branches.len() != 2 {
            bail!("polarity routing requires exactly 2 sinks, got {}", branches.len());
        }
        if route == RoutePolicy::Stripes && !geometry_known {
            bail!("stripes routing requires known source geometry (declare --geometry)");
        }

        // ---- nothing may float outside the trunk-and-branches family.
        let orphans: Vec<&str> = (0..n).filter(|&i| !visited[i]).map(name).collect();
        if !orphans.is_empty() {
            bail!(
                "dangling node(s) {:?}: not connected between a source and a sink",
                orphans
            );
        }

        Ok(Plan { sources, trunk, route, branches, layout, canvas })
    }

    /// Validate, then lower onto the execution machinery: the fan-in
    /// merge (per-lane pump threads), the shared trunk [`StageGraph`],
    /// the router, per-branch [`StageGraph`]s (report names prefixed
    /// `branch/`), and the sinks (pump threads spawning now for
    /// [`TopologyBuilder::sink_threaded`] nodes).
    pub fn compile(self, config: GraphConfig) -> Result<CompiledTopology<'a>> {
        let plan = self.plan()?;
        let names: Vec<String> = self.nodes.iter().map(|n| n.name.clone()).collect();
        let mut slots: Vec<Option<NodeKind<'a>>> =
            self.nodes.into_iter().map(|n| Some(n.kind)).collect();

        let mut sources = Vec::with_capacity(plan.sources.len());
        for &i in &plan.sources {
            match slots[i].take() {
                Some(NodeKind::Source { source, threaded, .. }) => {
                    sources.push((source, threaded));
                }
                // Listeners are never pumped: their client plane must
                // stay visible to the in-process merge driver.
                Some(NodeKind::Listener { source }) => sources.push((source, false)),
                _ => unreachable!("plan.sources holds source/listener nodes"),
            }
        }

        let mut shared = StageGraph::empty();
        for &i in &plan.trunk {
            let Some(NodeKind::Stages { spec, opts }) = slots[i].take() else {
                unreachable!("plan.trunk holds stage nodes");
            };
            shared.append(StageGraph::compile(&spec, plan.canvas, &opts));
        }

        let mut branches = Vec::with_capacity(plan.branches.len());
        for (stage_idxs, sink_idx) in &plan.branches {
            let mut graph: Option<StageGraph> = None;
            for &i in stage_idxs {
                let Some(NodeKind::Stages { spec, opts }) = slots[i].take() else {
                    unreachable!("plan branch stages hold stage nodes");
                };
                let prefix = format!("{}/", names[i]);
                let compiled =
                    StageGraph::compile_prefixed(&spec, plan.canvas, &opts, &prefix);
                match &mut graph {
                    None => graph = Some(compiled),
                    Some(acc) => acc.append(compiled),
                }
            }
            let Some(NodeKind::Sink { slot }) = slots[*sink_idx].take() else {
                unreachable!("plan branch sinks hold sink nodes");
            };
            let sink: Box<dyn EventSink + 'a> = match slot {
                SinkSlot::Inline(sink) => sink,
                SinkSlot::Threaded { spawn, .. } => Box::new(spawn()),
                SinkSlot::Buffered { config, sink, .. } => {
                    // The edge (node) name labels the buf:w/buf:r
                    // threads and telemetry.
                    Box::new(DiskBufferedSink::spawn(sink, config, &names[*sink_idx])?)
                }
            };
            branches.push(BranchRun { graph, sink, label: names[*sink_idx].clone() });
        }

        Ok(CompiledTopology {
            sources,
            shared,
            branches,
            layout: plan.layout,
            route: plan.route,
            config,
        })
    }

    /// [`compile`](GraphSpec::compile) and drive to completion.
    pub fn run(self, config: GraphConfig) -> Result<StreamReport> {
        self.compile(config)?.run()
    }
}

/// A validated graph lowered onto concrete execution structures, ready
/// to [`run`](CompiledTopology::run) once.
pub struct CompiledTopology<'a> {
    sources: Vec<(Box<dyn EventSource + 'a>, bool)>,
    shared: StageGraph,
    branches: Vec<BranchRun<Box<dyn EventSink + 'a>>>,
    layout: Option<SourceLayout>,
    route: RoutePolicy,
    config: GraphConfig,
}

impl CompiledTopology<'_> {
    /// Drive the compiled graph to completion. Per-branch stage nodes
    /// report after the trunk's in
    /// [`StreamReport::stages`](super::StreamReport::stages), named
    /// `branchnode/stagename`.
    pub fn run(mut self) -> Result<StreamReport> {
        let adaptive = match &self.config.adaptive {
            Some(cfg) => Some(cfg.build().context("assembling adaptive controllers")?),
            None => None,
        };
        run_nodes(
            self.sources,
            &mut self.shared,
            self.branches,
            self.layout,
            self.route,
            self.config.chunk_size,
            self.config.driver,
            adaptive,
            self.config.report_json.take(),
            self.config.decode_threads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::{Event, Resolution};
    use crate::pipeline::{ops, StageSpec};
    use crate::stream::{CaptureSink, MemorySource, NullSink};
    use crate::testutil::synthetic_events_seeded;

    fn mem(seed: u64, n: usize, res: Resolution) -> MemorySource {
        MemorySource::new(synthetic_events_seeded(n, res.width, res.height, seed), res, 256)
    }

    #[test]
    fn builder_chain_runs_the_legacy_shape() {
        let res = Resolution::new(64, 64);
        let report = Topology::builder()
            .source("a", mem(1, 600, res))
            .source("b", mem(2, 400, res))
            .merge("fuse", &["a", "b"])
            .route("split", RoutePolicy::Broadcast)
            .sink("x", NullSink::default())
            .after("split")
            .sink("y", NullSink::default())
            .build()
            .run(GraphConfig { chunk_size: 128, ..Default::default() })
            .unwrap();
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.resolution, Resolution::new(128, 64));
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.sinks.len(), 2);
        for sink in &report.sinks {
            assert_eq!(sink.events, 1000, "broadcast must reach {}", sink.name);
        }
    }

    #[test]
    fn multi_branch_chains_run_independently_and_report_per_branch() {
        let res = Resolution::new(64, 48);
        let a = synthetic_events_seeded(2000, 64, 48, 7);
        let b = synthetic_events_seeded(1500, 64, 48, 8);
        let layout = SourceLayout::side_by_side(&[res, res]);
        let (fused, _) = crate::pipeline::fusion::fuse(&[&a, &b], &layout);
        let canvas = layout.canvas;

        // Serial references: each branch chain applied to the whole
        // merged stream (broadcast).
        let on_spec = || {
            PipelineSpec::new()
                .then(StageSpec::new(|_| ops::PolarityFilter::keep(crate::aer::Polarity::On)))
        };
        let refr_spec = || {
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100)))
        };
        let expect_on = on_spec().build_pipeline(canvas).process(&fused);
        let expect_refr = refr_spec().build_pipeline(canvas).process(&fused);

        let (sink_on, got_on) = CaptureSink::new();
        let (sink_refr, got_refr) = CaptureSink::new();
        let report = Topology::builder()
            .source("a", MemorySource::new(a, res, 256))
            .source("b", MemorySource::new(b, res, 256))
            .merge("fuse", &["a", "b"])
            .route("split", RoutePolicy::Broadcast)
            .stages("keep-on", on_spec())
            .sink("on", sink_on)
            .after("split")
            .stages_with(
                "cooldown",
                refr_spec(),
                StageOptions { shards: 2, shard_threads: false },
            )
            .sink("refr", sink_refr)
            .build()
            .run(GraphConfig { chunk_size: 256, ..Default::default() })
            .unwrap();

        assert_eq!(*got_on.lock().unwrap(), expect_on, "branch chain ≠ serial");
        assert_eq!(*got_refr.lock().unwrap(), expect_refr, "sharded branch chain ≠ serial");
        // Per-branch stage nodes land in the report, prefixed.
        let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
        assert!(
            names.iter().any(|n| n.starts_with("keep-on/")),
            "missing keep-on branch report in {names:?}"
        );
        assert!(
            names.iter().any(|n| n.starts_with("cooldown/")),
            "missing cooldown branch report in {names:?}"
        );
        assert_eq!(report.sinks[0].events, expect_on.len() as u64);
        assert_eq!(report.sinks[1].events, expect_refr.len() as u64);
    }

    #[test]
    fn validate_rejects_malformed_graphs() {
        let res = Resolution::new(32, 32);
        // Duplicate name.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .sink("a", NullSink::default())
            .build();
        assert!(format!("{}", g.validate().unwrap_err()).contains("duplicate node name"));
        // Unknown edge target.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .sink("out", NullSink::default())
            .edge("a", "ghost")
            .build();
        assert!(format!("{}", g.validate().unwrap_err()).contains("unknown node"));
        // Cycle: s1 ↔ s2 feed each other (each with exactly one input,
        // so the cycle — not a degree rule — is what must fire).
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .after("s2")
            .stages("s1", PipelineSpec::new())
            .stages("s2", PipelineSpec::new())
            .after("a")
            .sink("out", NullSink::default())
            .build();
        assert!(format!("{}", g.validate().unwrap_err()).contains("cycle"));
        // Dangling node.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .sink("out", NullSink::default())
            .source("floating", mem(2, 10, res))
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("no merge node"), "got {err}");
        // Sink with no input.
        let g = Topology::builder().source("a", mem(1, 10, res)).build();
        assert!(format!("{}", g.validate().unwrap_err()).contains("dangles"));
        // Polarity arity through a router.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .route("split", RoutePolicy::Polarity)
            .sink("only", NullSink::default())
            .build();
        assert!(format!("{}", g.validate().unwrap_err()).contains("polarity"));
        // Two merges with disjoint sources: the per-stripe shape needs
        // every source feeding every merge.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .source("b", mem(2, 10, res))
            .merge("m1", &["a"])
            .merge("m2", &["b"])
            .sink("out", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("every source to feed every merge"), "got {err}");
        // Per-stripe merges must agree on the canvas layout.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .merge_with_layout("m1", &["a"], FusionLayout::Grid)
            .sink("x", NullSink::default())
            .merge_with_layout("m2", &["a"], FusionLayout::Overlay)
            .sink("y", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("agree on the canvas layout"), "got {err}");
        // A per-stripe merge owns exactly one chain: fanning out of one
        // is a nested fan-out, still unsupported.
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .merge("m1", &["a"])
            .sink("x", NullSink::default())
            .merge("m2", &["a"])
            .sink("y", NullSink::default())
            .after("m2")
            .sink("z", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("fans out"), "got {err}");
    }

    /// The sharded fan-in: N merges, each fed by every source, each
    /// owning one stripe of the fused canvas — must produce exactly what
    /// the explicit stripes router produces, branch for branch, with
    /// zero whole-batch copies on the way.
    #[test]
    fn per_stripe_merges_match_the_stripes_router() {
        let res = Resolution::new(48, 32);
        let a = synthetic_events_seeded(1200, 48, 32, 13);
        let b = synthetic_events_seeded(800, 48, 32, 14);

        // Reference: one merge + an explicit stripes router.
        let (r0, ref0) = CaptureSink::new();
        let (r1, ref1) = CaptureSink::new();
        let (r2, ref2) = CaptureSink::new();
        Topology::builder()
            .source("a", MemorySource::new(a.clone(), res, 128))
            .source("b", MemorySource::new(b.clone(), res, 128))
            .merge("fuse", &["a", "b"])
            .route("split", RoutePolicy::Stripes)
            .sink("x", r0)
            .after("split")
            .sink("y", r1)
            .after("split")
            .sink("z", r2)
            .build()
            .run(GraphConfig { chunk_size: 128, ..Default::default() })
            .unwrap();

        // Same topology written as three per-stripe merges.
        let (s0, got0) = CaptureSink::new();
        let (s1, got1) = CaptureSink::new();
        let (s2, got2) = CaptureSink::new();
        let report = Topology::builder()
            .source("a", MemorySource::new(a, res, 128))
            .source("b", MemorySource::new(b, res, 128))
            .merge("m0", &["a", "b"])
            .sink("x", s0)
            .merge("m1", &["a", "b"])
            .sink("y", s1)
            .merge("m2", &["a", "b"])
            .sink("z", s2)
            .build()
            .run(GraphConfig { chunk_size: 128, ..Default::default() })
            .unwrap();

        assert_eq!(*got0.lock().unwrap(), *ref0.lock().unwrap(), "stripe 0 diverged");
        assert_eq!(*got1.lock().unwrap(), *ref1.lock().unwrap(), "stripe 1 diverged");
        assert_eq!(*got2.lock().unwrap(), *ref2.lock().unwrap(), "stripe 2 diverged");
        assert_eq!(report.sinks.len(), 3);
        let routed: u64 = report.sinks.iter().map(|s| s.events).sum();
        assert_eq!(routed, 2000, "stripes partition, never duplicate");
        // Stripe scatter is a selection copy into chunk views — no node
        // on the path may perform a whole-batch deep copy.
        assert_eq!(report.chunks_cloned, 0, "per-stripe fan-in must be clone-free");
    }

    /// A per-stripe merge chain may run its own stages before the sink.
    #[test]
    fn per_stripe_merge_chains_run_their_stages() {
        let res = Resolution::new(64, 32);
        let events = synthetic_events_seeded(1500, 64, 32, 23);
        let canvas = res; // single source: canvas = source extent
        let on_spec = || {
            PipelineSpec::new()
                .then(StageSpec::new(|_| ops::PolarityFilter::keep(crate::aer::Polarity::On)))
        };
        // Serial reference: stripe the stream by hand, filter stripe 0.
        let stripe_w = 32usize; // 64px / 2 merges
        let stripe0: Vec<Event> =
            events.iter().copied().filter(|e| (e.x as usize) < stripe_w).collect();
        let stripe1: Vec<Event> =
            events.iter().copied().filter(|e| (e.x as usize) >= stripe_w).collect();
        let expect0 = on_spec().build_pipeline(canvas).process(&stripe0);

        let (s0, got0) = CaptureSink::new();
        let (s1, got1) = CaptureSink::new();
        let report = Topology::builder()
            .source("cam", MemorySource::new(events, res, 173))
            .merge("m0", &["cam"])
            .stages("keep-on", on_spec())
            .sink("x", s0)
            .merge("m1", &["cam"])
            .sink("y", s1)
            .build()
            .run(GraphConfig { chunk_size: 173, ..Default::default() })
            .unwrap();

        assert_eq!(*got0.lock().unwrap(), expect0, "filtered stripe 0 diverged");
        assert_eq!(*got1.lock().unwrap(), stripe1, "raw stripe 1 diverged");
        // The branch chain's report lands prefixed, like router branches.
        assert!(
            report.stages.iter().any(|s| s.name.starts_with("keep-on/")),
            "missing per-stripe branch stage report"
        );
    }

    #[test]
    fn layout_and_offset_conflict_is_a_hard_error() {
        let res = Resolution::new(32, 32);
        let g = Topology::builder()
            .source_with(
                "a",
                mem(1, 10, res),
                SourceOptions { offset: Some((0, 0)), threaded: false },
            )
            .source("b", mem(2, 10, res))
            .merge_with_layout("fuse", &["a", "b"], FusionLayout::Grid)
            .sink("out", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("--offset"), "got {err}");
        // Offsets alone are fine.
        let g = Topology::builder()
            .source_with(
                "a",
                mem(1, 10, res),
                SourceOptions { offset: Some((0, 0)), threaded: false },
            )
            .source_with(
                "b",
                mem(2, 10, res),
                SourceOptions { offset: Some((0, 40)), threaded: false },
            )
            .merge("fuse", &["a", "b"])
            .sink("out", NullSink::default())
            .build();
        g.validate().unwrap();
    }

    #[test]
    fn implicit_broadcast_fork_without_a_router() {
        let res = Resolution::new(32, 32);
        let events = synthetic_events_seeded(500, 32, 32, 3);
        let (s1, got1) = CaptureSink::new();
        let (s2, got2) = CaptureSink::new();
        let report = Topology::builder()
            .source("a", MemorySource::new(events.clone(), res, 64))
            .sink("x", s1)
            .after("a")
            .sink("y", s2)
            .build()
            .run(GraphConfig { chunk_size: 64, ..Default::default() })
            .unwrap();
        assert_eq!(report.sinks.len(), 2);
        assert_eq!(*got1.lock().unwrap(), events);
        assert_eq!(*got2.lock().unwrap(), events);
    }

    #[test]
    fn threaded_source_and_sink_placement_flow_through() {
        let res = Resolution::new(64, 64);
        let report = Topology::builder()
            .source_with(
                "a",
                mem(4, 3000, res),
                SourceOptions { offset: None, threaded: true },
            )
            .source("b", mem(5, 2000, res))
            .merge("fuse", &["a", "b"])
            .sink_threaded("out", NullSink::default())
            .build()
            .run(GraphConfig { chunk_size: 256, ..Default::default() })
            .unwrap();
        assert_eq!(report.events_in, 5000);
        assert_eq!(report.sources[0].name, "thread(memory(3000 events))");
        assert_eq!(report.sources[1].name, "memory(2000 events)");
        assert!(report.sinks[0].name.starts_with("thread("), "{:?}", report.sinks[0].name);
    }

    #[test]
    fn summary_is_deterministic_and_names_every_node() {
        let res = Resolution::new(32, 32);
        let g = Topology::builder()
            .source("cam", mem(1, 10, res))
            .source("file", mem(2, 10, res))
            .merge_with_layout("fuse", &["cam", "file"], FusionLayout::Overlay)
            .stages_with(
                "filters",
                PipelineSpec::new()
                    .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 50))),
                StageOptions { shards: 2, shard_threads: true },
            )
            .route("split", RoutePolicy::Stripes)
            .sink("left", NullSink::default())
            .after("split")
            .sink("right", NullSink::default())
            .build();
        let summary = g.summary();
        assert!(summary.contains("merge fuse <- cam, file [overlay]"), "{summary}");
        assert!(summary.contains("[shards 2, threads]"), "{summary}");
        assert!(summary.contains("route split <- filters [Stripes]"), "{summary}");
        assert!(summary.contains("sink right <- split: null"), "{summary}");
        assert_eq!(summary, g.summary(), "summary must be stable");
    }

    #[test]
    fn listener_nodes_join_the_merge_like_sources() {
        let res = Resolution::new(32, 32);
        // Any EventSource works as a listener payload at the graph
        // layer; the serving plane plugs in a real ListenerSource.
        let g = Topology::builder()
            .source("file", mem(1, 300, res))
            .listen("net", mem(2, 200, res))
            .merge("fuse", &["file", "net"])
            .sink("out", NullSink::default())
            .build();
        let summary = g.summary();
        assert!(summary.contains("listen net"), "{summary}");
        let report = g.run(GraphConfig { chunk_size: 64, ..Default::default() }).unwrap();
        assert_eq!(report.events_in, 500);
        assert_eq!(report.sources.len(), 2);
        assert_eq!(report.resolution, Resolution::new(64, 32));
    }

    #[test]
    fn listener_validation_rules() {
        struct NoGeom;
        impl EventSource for NoGeom {
            fn next_batch(&mut self) -> anyhow::Result<Option<Vec<Event>>> {
                Ok(None)
            }
            fn resolution(&self) -> Resolution {
                Resolution::new(1, 1)
            }
            fn geometry_known(&self) -> bool {
                false
            }
        }
        // Listeners must declare their canvas up front.
        let g = Topology::builder()
            .listen("net", NoGeom)
            .sink("out", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("declared geometry"), "got {err}");
        // Listeners are graph roots: no inbound edges.
        let res = Resolution::new(32, 32);
        let g = Topology::builder()
            .source("a", mem(1, 10, res))
            .listen("net", mem(2, 10, res))
            .edge("a", "net")
            .merge("fuse", &["a", "net"])
            .sink("out", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("cannot receive an edge"), "got {err}");
    }

    #[test]
    fn compile_rejects_stripes_over_observed_geometry() {
        struct Observed;
        impl EventSource for Observed {
            fn next_batch(&mut self) -> anyhow::Result<Option<Vec<Event>>> {
                Ok(None)
            }
            fn resolution(&self) -> Resolution {
                Resolution::new(1, 1)
            }
            fn geometry_known(&self) -> bool {
                false
            }
        }
        let g = Topology::builder()
            .source("live", Observed)
            .route("split", RoutePolicy::Stripes)
            .sink("x", NullSink::default())
            .after("split")
            .sink("y", NullSink::default())
            .build();
        let err = format!("{}", g.validate().unwrap_err());
        assert!(err.contains("stripes"), "got {err}");
    }
}
