//! True incremental streaming: the `EventSource` → [`Pipeline`] →
//! `EventSink` layer.
//!
//! The paper's architecture streams events from inputs to outputs with
//! per-event coroutine handoff; this module is the library's uniform
//! interface for that flow. An [`EventSource`] *pulls* bounded batches
//! (chunked file decoders, UDP receivers, synthetic cameras, in-memory
//! slices), an [`EventSink`] consumes them and `finish()`es to flush,
//! and [`run`] drives the pair through the cooperative coroutine
//! runtime ([`crate::rt::LocalExecutor`] + a bounded
//! [`crate::rt::channel`]) so memory stays **O(chunk)** instead of
//! O(stream) and I/O overlaps compute. A `Sync` fallback driver exists
//! for baseline comparisons (the Fig. 1(A)-vs-(B) contrast at the
//! orchestration layer).
//!
//! Graphs wider than one edge live in [`topology`]: N sources fan in
//! through a streaming timestamp-ordered merge (optionally one OS
//! thread per source, fed through the lock-free
//! [`crate::rt::sync_channel`] ring), share one stage chain, and fan
//! out to M sinks by [`RoutePolicy`]. [`run`] itself is a thin
//! single-edge wrapper over [`topology::run_topology`].
//!
//! The graph *shape* is itself a first-class value ([`graph`]): a
//! [`GraphSpec`] of named source/merge/stage/router/sink nodes with
//! explicit edges, built fluently with [`Topology::builder`], checked
//! by `validate()` (acyclicity, geometry propagation, readable errors)
//! and lowered by `compile()` onto the same driver —
//! [`topology::run_topology`] is the one fixed shape, the graph layer
//! composes every other one (per-branch stage chains into independent
//! sinks, per-node thread placement).
//!
//! The stage chain between fan-in and fan-out is any
//! [`BatchProcessor`]: the serial [`Pipeline`], or a [`StageGraph`]
//! ([`stage`]) that compiles each stage into its own topology node —
//! stateless/stateful stages stripe-sharded across N workers (inline
//! coroutines or one OS thread each) with a sequence-keyed re-merge,
//! barrier stages pinned to single nodes. The k-way merge logic itself
//! lives once, in [`merge`] — a loser-tree core that emits zero-copy
//! *runs* instead of single events — shared by the fan-in merge and the
//! shard re-merge, with batch buffers recycled through [`pool`].
//!
//! The split mirrors vector's `FunctionTransform`/`TaskTransform`
//! idiom: per-event functions stay in [`crate::pipeline`] and declare a
//! [`crate::pipeline::TransformClass`], while the topology layer
//! decides where each one runs.
//!
//! The runtime is **adaptive** ([`adapt`]): per-node counters live in
//! the shared-atomic telemetry plane ([`crate::metrics::LiveNode`]),
//! which the driver samples every N batches; configured controllers
//! (`skew` re-cuts stripe boundaries from the observed per-shard
//! histogram, `chunk` runs AIMD on the batch size) issue
//! [`Reconfigure`] actions applied at epoch barriers — with stateful
//! stages handing per-column state to their new owner shards, so output
//! stays byte-identical to serial across arbitrarily many re-cuts.

pub mod adapt;
pub mod buffer;
pub mod chunk;
pub mod codec_plane;
pub mod graph;
pub mod merge;
pub mod pool;
pub mod report;
pub mod sinks;
pub mod sources;
pub mod stage;
pub mod topology;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::aer::{Event, Resolution};
use crate::metrics::{LiveNode, NodeReport};
use crate::pipeline::Pipeline;

pub use adapt::{
    registry::register_controller, AdaptiveConfig, AdaptiveReport, AdaptiveRuntime, Aimd,
    ChunkController, ClientSample, ClientWindowController, Controller, ControllerKind,
    EpochSample, Reconfigure, SkewController, StageSample, StageTelemetry, WindowChange,
};
pub use buffer::{
    read_acked_offset, BufferSnapshot, DiskBufferConfig, DiskBufferedSink, ReplaySource,
    ReplaySpeed,
};
pub use chunk::{copy_counters, CopyCounters, EventChunk, EVENT_BYTES};
pub use codec_plane::{CodecPlane, CodecPlaneConfig, CodecPlaneCounters, DecodeStream};
pub use pool::{pool_counters, BytePool, ChunkPool, PoolCounters};
pub use graph::{
    CompiledTopology, FusionLayout, GraphConfig, GraphSpec, SourceOptions, Topology,
    TopologyBuilder,
};
pub use report::{ReportEmitter, ReportTarget};
pub use sinks::{
    CaptureSink, FileSink, FrameSink, NullSink, SinkSummary, StdoutSink, ThreadedSink, UdpSink,
    ViewSink,
};
pub use sources::{CameraSource, FileSource, MemorySource, SliceSource, UdpSource};
pub use stage::{BatchProcessor, StageGraph, StageOptions, StripeCut};
pub use topology::{
    run_topology, run_topology_with_adaptive, FusedSource, RoutePolicy, ThreadMode,
    TopologyConfig,
};

/// A pull-based, bounded-batch event producer.
///
/// Implementations must never materialize the whole stream: each
/// [`next_batch`](EventSource::next_batch) call returns at most a
/// chunk's worth of events.
pub trait EventSource: Send {
    /// Pull the next batch.
    ///
    /// * `Ok(Some(batch))` — more events; an **empty** batch means
    ///   "nothing available right now" (live sources between datagrams),
    ///   not end of stream — drivers yield and poll again.
    /// * `Ok(None)` — the stream is exhausted.
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>>;

    /// Best-effort sensor geometry. Sources that only learn geometry by
    /// observing events (headerless files, UDP) report a growing
    /// bounding box; read it after the stream for the final value.
    fn resolution(&self) -> Resolution;

    /// `false` when [`resolution`](EventSource::resolution) is only an
    /// observed lower bound that may still grow (live sources).
    /// Geometry-recording sinks spool and re-encode in that case.
    fn geometry_known(&self) -> bool {
        true
    }

    /// `true` for sources fed by the outside world (UDP), whose empty
    /// batches mean "quiet right now" rather than "momentarily starved".
    /// Only live sources may heartbeat in a fan-in merge: a finite
    /// source's empty batch is always transient, so stalling on it
    /// keeps global timestamp order exact. Default `false`.
    fn is_live(&self) -> bool {
        false
    }

    /// Events this source discarded before emission (e.g. outside a
    /// claimed geometry). Surfaced per node in reports. Default 0.
    fn dropped(&self) -> u64 {
        0
    }

    /// Advisory retarget of the batch size (the adaptive chunk
    /// controller re-tunes it at epoch barriers). Sources that chunk a
    /// backing store honour it; sources whose batch size is dictated by
    /// the outside world (datagrams, pump rings) may ignore it — the
    /// fan-in merge re-chunks merged output regardless. Default:
    /// ignored.
    fn set_chunk_hint(&mut self, _chunk: usize) {}

    /// Adopt a shared buffer pool for batch allocations. Sources that
    /// materialize their own batch `Vec`s (memory/file chunkers) draw
    /// them from the pool so the fan-in merge can hand buffers back
    /// after emission; sources whose batches arrive from the outside
    /// world (datagrams, pump rings) may ignore it. Default: ignored.
    fn set_buffer_pool(&mut self, _pool: Arc<pool::ChunkPool>) {}

    /// Adopt the shared codec worker plane. Sources that decode a
    /// packed wire/file format inline (file chunkers, serving-plane
    /// listeners) submit raw byte buffers to the plane's bounded worker
    /// pool instead, keeping their own thread on I/O; sources that
    /// produce events directly (memory, cameras) ignore it. Default:
    /// ignored.
    fn set_codec_plane(&mut self, _plane: Arc<codec_plane::CodecPlane>) {}

    /// Adopt this source's live telemetry node. Sources with internal
    /// machinery worth reporting (replay progress, buffer gauges)
    /// publish through it; plain sources ignore it — the driver counts
    /// their batches externally either way. Default: ignored.
    fn set_live_node(&mut self, _node: Arc<LiveNode>) {}

    /// Human-readable description (logs, reports).
    fn describe(&self) -> String {
        "source".into()
    }

    /// The dynamic-client plane behind this source, if it is a
    /// serving-plane listener. The fan-in merge collects these at
    /// construction and adopts each plane's newly admitted clients as
    /// dynamic lanes at safe merge points; the adaptive epoch loop
    /// samples them and retargets per-client windows. Default: `None`
    /// (ordinary sources have no clients).
    fn client_plane(&self) -> Option<Arc<dyn ClientPlane>> {
        None
    }
}

impl<S: EventSource + ?Sized> EventSource for &mut S {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        (**self).next_batch()
    }
    fn resolution(&self) -> Resolution {
        (**self).resolution()
    }
    fn geometry_known(&self) -> bool {
        (**self).geometry_known()
    }
    fn is_live(&self) -> bool {
        (**self).is_live()
    }
    fn dropped(&self) -> u64 {
        (**self).dropped()
    }
    fn set_chunk_hint(&mut self, chunk: usize) {
        (**self).set_chunk_hint(chunk)
    }
    fn set_buffer_pool(&mut self, pool: Arc<pool::ChunkPool>) {
        (**self).set_buffer_pool(pool)
    }
    fn set_codec_plane(&mut self, plane: Arc<codec_plane::CodecPlane>) {
        (**self).set_codec_plane(plane)
    }
    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        (**self).set_live_node(node)
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
    fn client_plane(&self) -> Option<Arc<dyn ClientPlane>> {
        (**self).client_plane()
    }
}

impl<S: EventSource + ?Sized> EventSource for Box<S> {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        (**self).next_batch()
    }
    fn resolution(&self) -> Resolution {
        (**self).resolution()
    }
    fn geometry_known(&self) -> bool {
        (**self).geometry_known()
    }
    fn is_live(&self) -> bool {
        (**self).is_live()
    }
    fn dropped(&self) -> u64 {
        (**self).dropped()
    }
    fn set_chunk_hint(&mut self, chunk: usize) {
        (**self).set_chunk_hint(chunk)
    }
    fn set_buffer_pool(&mut self, pool: Arc<pool::ChunkPool>) {
        (**self).set_buffer_pool(pool)
    }
    fn set_codec_plane(&mut self, plane: Arc<codec_plane::CodecPlane>) {
        (**self).set_codec_plane(plane)
    }
    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        (**self).set_live_node(node)
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
    fn client_plane(&self) -> Option<Arc<dyn ClientPlane>> {
        (**self).client_plane()
    }
}

/// One dynamic client lane handed from a [`ClientPlane`] to the fan-in
/// merge: the client's batch source plus its live counter node (already
/// registered with the plane, so admission shows up in telemetry even
/// before the merge adopts the lane).
pub struct ClientLane {
    /// The client's pull side (decoded, timestamped batches).
    pub source: Box<dyn EventSource>,
    /// The client's live counters (events/batches/credit stalls).
    pub node: Arc<LiveNode>,
}

/// A dynamic-client registry exposed by a serving-plane listener
/// through [`EventSource::client_plane`]. Implementations (e.g.
/// [`crate::serve::ClientHub`]) are shared between the accept loop
/// (producing lanes), the merge driver (adopting them), and the
/// adaptive epoch loop (sampling and retargeting windows) — hence
/// `Send + Sync` behind an [`Arc`].
pub trait ClientPlane: Send + Sync {
    /// Drain the lanes of clients admitted since the last call. The
    /// merge adopts each as a dynamic lane at its next safe point.
    fn take_lanes(&self) -> Vec<ClientLane>;

    /// Cumulative per-client counters (the epoch sampler computes
    /// deltas). Includes disconnected clients — their history stays in
    /// the final report.
    fn client_samples(&self) -> Vec<ClientSample>;

    /// Retarget one client's in-flight credit window. Returns `false`
    /// when the client is unknown to this plane.
    fn set_window(&self, client: &str, window: usize) -> bool;
}

/// A batch consumer with an explicit end-of-stream flush.
pub trait EventSink: Send {
    /// Consume one batch (already pipeline-processed).
    fn consume(&mut self, batch: &[Event]) -> Result<()>;

    /// Consume one refcounted chunk — the zero-copy delivery path the
    /// topology drivers use. The default borrows the chunk's slice into
    /// [`consume`](EventSink::consume), which is already copy-free for
    /// sinks that read in place; sinks that *retain* the batch
    /// (queue-handoff, capture buffers) override this and keep a
    /// refcount clone instead of a deep copy.
    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        self.consume(chunk.as_slice())
    }

    /// The driver's report of the *source* geometry, delivered once
    /// just before [`finish`](EventSink::finish). Geometry-recording
    /// sinks fed through a thinning pipeline use it so the recorded
    /// geometry covers the sensor, not just the surviving events
    /// (parity with the batch path). Default: ignored.
    fn observe_geometry(&mut self, _res: Resolution) {}

    /// Adopt this sink's live telemetry node. Sinks with internal
    /// machinery worth reporting (disk-buffer gauges) publish through
    /// it; plain sinks ignore it. Default: ignored.
    fn set_live_node(&mut self, _node: Arc<LiveNode>) {}

    /// End of stream: flush buffered state and report sink-side totals.
    /// Called exactly once, after the last `consume`.
    fn finish(&mut self) -> Result<SinkSummary>;

    /// Human-readable description (logs, reports).
    fn describe(&self) -> String {
        "sink".into()
    }
}

impl<K: EventSink + ?Sized> EventSink for &mut K {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        (**self).consume(batch)
    }
    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        (**self).consume_chunk(chunk)
    }
    fn observe_geometry(&mut self, res: Resolution) {
        (**self).observe_geometry(res)
    }
    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        (**self).set_live_node(node)
    }
    fn finish(&mut self) -> Result<SinkSummary> {
        (**self).finish()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

impl<K: EventSink + ?Sized> EventSink for Box<K> {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        (**self).consume(batch)
    }
    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        (**self).consume_chunk(chunk)
    }
    fn observe_geometry(&mut self, res: Resolution) {
        (**self).observe_geometry(res)
    }
    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        (**self).set_live_node(node)
    }
    fn finish(&mut self) -> Result<SinkSummary> {
        (**self).finish()
    }
    fn describe(&self) -> String {
        (**self).describe()
    }
}

/// How [`run`] schedules the source and sink.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDriver {
    /// Producer and consumer coroutines on one cooperative executor,
    /// handing batches through a bounded async channel — the paper's
    /// Fig. 1(B) shape. `channel_capacity` is in *batches*; 1 is a
    /// rendezvous (strictest backpressure, lowest memory).
    Coroutine {
        /// Queue capacity in batches (min 1).
        channel_capacity: usize,
    },
    /// Plain pull-process-push loop on the calling thread (baseline).
    Sync,
}

/// Streaming run parameters.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Target events per batch for chunkable sources and the peak
    /// per-hop memory unit.
    pub chunk_size: usize,
    /// Scheduling strategy.
    pub driver: StreamDriver,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            chunk_size: 4096,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
        }
    }
}

impl StreamConfig {
    /// The synchronous baseline with the default chunk size.
    pub fn sync() -> Self {
        StreamConfig { driver: StreamDriver::Sync, ..Default::default() }
    }
}

/// Outcome of a streaming run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Events read from the source (for topologies: events emitted by
    /// the fan-in merge onto the shared canvas).
    pub events_in: u64,
    /// Events that survived the pipeline into the sink(s). Counted once
    /// per event even when broadcast to several sinks — see
    /// [`sinks`](StreamReport::sinks) for per-sink delivery counts.
    pub events_out: u64,
    /// Frames produced, summed over frame-binning sinks.
    pub frames: u64,
    /// Batches pulled from the (merged) source.
    pub batches: u64,
    /// Peak events queued between producer and consumer at any instant
    /// (coroutine driver: channel occupancy; sync driver: the single
    /// resident batch). Bounded by
    /// `channel_capacity × max_batch_len` — the O(chunk) guarantee.
    pub peak_in_flight: usize,
    /// Times the producer found the channel full and suspended
    /// (coroutine driver only): a backpressure gauge.
    pub backpressure_waits: u64,
    /// Wall time.
    pub wall: Duration,
    /// Sensor geometry of the source (final value for growing sources;
    /// the fused canvas for topologies).
    pub resolution: Resolution,
    /// Per-source counters: events/batches pulled from each source, and
    /// (threaded topologies) full-ring suspensions of its pump thread.
    /// Single-edge runs have exactly one entry.
    pub sources: Vec<NodeReport>,
    /// Per-stage-node counters when the edge ran a [`StageGraph`]: for
    /// each stage, events in, events it dropped, shard traffic (skew),
    /// and scatter backpressure. Empty for plain [`Pipeline`] edges.
    /// Counters chain: stage n+1's `events` equals stage n's
    /// `events - dropped`, and stage 0's `events` equals
    /// [`events_in`](StreamReport::events_in). Compiled graphs with
    /// per-branch chains append each branch's stage nodes after the
    /// shared chain's, named `branchnode/stagename`.
    pub stages: Vec<NodeReport>,
    /// Per-sink counters: events/batches routed to each sink, frames it
    /// produced, and times the router found its queue full.
    pub sinks: Vec<NodeReport>,
    /// Peak events resident in the fan-in merge's carry buffers (its
    /// reorder depth), bounded by `sources × chunk`; 0 without fusion.
    pub merge_peak_buffered: usize,
    /// Events dropped by the fan-in layout for violating their source's
    /// geometry (0 without fusion).
    pub merge_dropped: u64,
    /// Times an idle live source exhausted its bounded grace and its
    /// lane stopped blocking the fan-in merge (stalls broken by the
    /// heartbeat watermark; 0 without fusion or for finite sources).
    pub merge_stalls_broken: u64,
    /// Events a heartbeat-overridden source delivered behind the merge
    /// frontier (emitted with timestamps clamped to the frontier, so
    /// the merged stream stays globally time-ordered).
    pub merge_late_events: u64,
    /// Reconfiguration history of an adaptive run (epochs sampled,
    /// stripe re-cuts with skew before/after, chunk-size changes).
    /// `None` when no controllers were configured.
    pub adaptive: Option<AdaptiveReport>,
    /// Event bytes physically copied between buffers during the run,
    /// summed over every node report (selection scatters, stage output
    /// materialization, whole-chunk clones). Broadcast fan-out is
    /// refcount-only and contributes nothing.
    pub bytes_moved: u64,
    /// Whole-batch deep copies during the run, summed over every node
    /// report. Zero on the stateless zero-copy paths — asserted by the
    /// chunk-semantics tests.
    pub chunks_cloned: u64,
    /// Batch buffers served from a chunk pool's free list during the
    /// run (no allocation): per-node pool hits summed with the fused
    /// source/merge pool's own counters.
    pub pool_hits: u64,
    /// Batch buffers allocated fresh because the pool had nothing to
    /// reuse. In steady state `pool_hits / (pool_hits + pool_misses)`
    /// approaches 1 — the allocation loop is closed.
    pub pool_misses: u64,
    /// Codec-plane worker threads (`--decode-threads`); 0 when ingest
    /// decoded inline (no plane configured).
    pub decode_workers: u64,
    /// Decode jobs executed on the codec plane.
    pub decode_jobs: u64,
    /// Peak depth of the codec plane's shared work queue: a sustained
    /// high-water mark means readers outpace the worker budget.
    pub decode_queue_depth: u64,
    /// Peak concurrently-busy codec workers: how much of the budget the
    /// run actually used.
    pub decode_worker_busy: u64,
    /// Peak out-of-order decoded pieces buffered in any single stream's
    /// sequence-keyed reassembly.
    pub decode_reassembly_lag: u64,
    /// Journal bytes held by disk-buffered edges at stream end (gauge,
    /// summed over edges; retained journals keep their bytes).
    pub buffer_bytes_on_disk: u64,
    /// Records whose in-memory copy was dropped by a disk-buffered edge
    /// (they drained from the journal instead).
    pub buffer_records_spilled: u64,
    /// Records read back from edge journals (spill drain + replay).
    pub buffer_records_replayed: u64,
    /// Records lost to CRC-corrupt journal frames and skipped.
    pub buffer_corrupt_records_skipped: u64,
    /// `true` if any edge still had spilled batches on disk when
    /// sampled last (should settle to `false` by stream end).
    pub buffer_spill_active: bool,
}

impl StreamReport {
    /// Events per second through the pipeline.
    pub fn throughput(&self) -> f64 {
        self.events_in as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drive `source → pipeline → sink` to completion.
///
/// Never materializes the stream: memory is bounded by the chunk size
/// times the channel capacity regardless of stream length. This is the
/// single-edge special case of [`topology::run_topology`].
pub fn run(
    source: &mut dyn EventSource,
    pipeline: &mut Pipeline,
    sink: &mut dyn EventSink,
    config: StreamConfig,
) -> Result<StreamReport> {
    let config = TopologyConfig::from(config);
    topology::run_topology(vec![source], pipeline, vec![sink], None, &config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::synthetic_events;

    fn drivers() -> [StreamConfig; 3] {
        let wide = StreamDriver::Coroutine { channel_capacity: 4 };
        [
            StreamConfig::default(),
            StreamConfig { driver: wide, ..Default::default() },
            StreamConfig::sync(),
        ]
    }

    #[test]
    fn all_drivers_count_identically() {
        let events = synthetic_events(5000, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        for config in drivers() {
            let mut source =
                MemorySource::new(events.clone(), Resolution::new(64, 64), config.chunk_size);
            let mut pipeline = Pipeline::new().then(PolarityFilter::keep(Polarity::On));
            let mut sink = NullSink::default();
            let report = run(&mut source, &mut pipeline, &mut sink, config).unwrap();
            assert_eq!(report.events_in, 5000, "{config:?}");
            assert_eq!(report.events_out, on, "{config:?}");
            assert!(report.batches >= 5000 / config.chunk_size as u64, "{config:?}");
            // Single-edge runs still report their (single) nodes.
            assert_eq!(report.sources.len(), 1, "{config:?}");
            assert_eq!(report.sources[0].events, 5000, "{config:?}");
            assert_eq!(report.sinks.len(), 1, "{config:?}");
            assert_eq!(report.sinks[0].events, on, "{config:?}");
        }
    }

    #[test]
    fn peak_in_flight_is_bounded_by_channel_times_chunk() {
        let events = synthetic_events(100_000, 128, 128);
        let config = StreamConfig {
            chunk_size: 512,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
        };
        let mut source = MemorySource::new(events, Resolution::DVS_128, config.chunk_size);
        let mut sink = NullSink::default();
        let report = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap();
        assert_eq!(report.events_in, 100_000);
        assert!(
            report.peak_in_flight <= config.chunk_size,
            "peak {} exceeds chunk {}",
            report.peak_in_flight,
            config.chunk_size
        );
        assert!(report.peak_in_flight > 0);
        assert_eq!(report.merge_peak_buffered, 0, "single edge must not buffer a merge");
    }

    #[test]
    fn sink_counts_frames() {
        let events = synthetic_events(2000, 64, 64);
        let mut source = MemorySource::new(events, Resolution::new(64, 64), 256);
        let mut sink = FrameSink::new(Resolution::new(64, 64), 1000);
        let report =
            run(&mut source, &mut Pipeline::new(), &mut sink, StreamConfig::default()).unwrap();
        assert!(report.frames > 0);
        assert_eq!(report.events_out, 2000);
    }

    #[test]
    fn empty_source_still_finishes_sink() {
        for config in drivers() {
            let mut source = MemorySource::new(Vec::new(), Resolution::new(4, 4), 16);
            let mut sink = NullSink::default();
            let report = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap();
            assert_eq!(report.events_in, 0);
            assert_eq!(report.batches, 0);
        }
    }

    #[test]
    fn source_error_propagates() {
        struct Failing(u32);
        impl EventSource for Failing {
            fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
                self.0 += 1;
                if self.0 < 3 {
                    Ok(Some(vec![Event::on(0, 0, self.0 as u64)]))
                } else {
                    anyhow::bail!("sensor unplugged")
                }
            }
            fn resolution(&self) -> Resolution {
                Resolution::new(4, 4)
            }
        }
        for config in drivers() {
            let mut source = Failing(0);
            let mut sink = NullSink::default();
            let err = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap_err();
            assert!(format!("{err:?}").contains("sensor unplugged"), "{config:?}");
        }
    }
}
