//! True incremental streaming: the `EventSource` → [`Pipeline`] →
//! `EventSink` layer.
//!
//! The paper's architecture streams events from inputs to outputs with
//! per-event coroutine handoff; this module is the library's uniform
//! interface for that flow. An [`EventSource`] *pulls* bounded batches
//! (chunked file decoders, UDP receivers, synthetic cameras, in-memory
//! slices), an [`EventSink`] consumes them and `finish()`es to flush,
//! and [`run`] drives the pair through the cooperative coroutine
//! runtime ([`crate::rt::LocalExecutor`] + a bounded
//! [`crate::rt::channel`]) so memory stays **O(chunk)** instead of
//! O(stream) and I/O overlaps compute. A `Sync` fallback driver exists
//! for baseline comparisons (the Fig. 1(A)-vs-(B) contrast at the
//! orchestration layer).
//!
//! The split mirrors vector's `FunctionTransform`/`TaskTransform`
//! idiom: per-event functions stay in [`crate::pipeline`], while
//! sources and sinks are scheduled by whatever driver fits the
//! deployment.

pub mod sinks;
pub mod sources;

use std::cell::{Cell, RefCell};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::aer::{Event, Resolution};
use crate::pipeline::Pipeline;
use crate::rt::channel::TrySendError;
use crate::rt::{channel, yield_now, LocalExecutor};

pub use sinks::{FileSink, FrameSink, NullSink, SinkSummary, StdoutSink, UdpSink, ViewSink};
pub use sources::{CameraSource, FileSource, MemorySource, SliceSource, UdpSource};

/// A pull-based, bounded-batch event producer.
///
/// Implementations must never materialize the whole stream: each
/// [`next_batch`](EventSource::next_batch) call returns at most a
/// chunk's worth of events.
pub trait EventSource: Send {
    /// Pull the next batch.
    ///
    /// * `Ok(Some(batch))` — more events; an **empty** batch means
    ///   "nothing available right now" (live sources between datagrams),
    ///   not end of stream — drivers yield and poll again.
    /// * `Ok(None)` — the stream is exhausted.
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>>;

    /// Best-effort sensor geometry. Sources that only learn geometry by
    /// observing events (headerless files, UDP) report a growing
    /// bounding box; read it after the stream for the final value.
    fn resolution(&self) -> Resolution;

    /// `false` when [`resolution`](EventSource::resolution) is only an
    /// observed lower bound that may still grow (live sources).
    /// Geometry-recording sinks spool and re-encode in that case.
    fn geometry_known(&self) -> bool {
        true
    }

    /// Human-readable description (logs, reports).
    fn describe(&self) -> String {
        "source".into()
    }
}

/// A batch consumer with an explicit end-of-stream flush.
pub trait EventSink: Send {
    /// Consume one batch (already pipeline-processed).
    fn consume(&mut self, batch: &[Event]) -> Result<()>;

    /// The driver's report of the *source* geometry, delivered once
    /// just before [`finish`](EventSink::finish). Geometry-recording
    /// sinks fed through a thinning pipeline use it so the recorded
    /// geometry covers the sensor, not just the surviving events
    /// (parity with the batch path). Default: ignored.
    fn observe_geometry(&mut self, _res: Resolution) {}

    /// End of stream: flush buffered state and report sink-side totals.
    /// Called exactly once, after the last `consume`.
    fn finish(&mut self) -> Result<SinkSummary>;

    /// Human-readable description (logs, reports).
    fn describe(&self) -> String {
        "sink".into()
    }
}

/// How [`run`] schedules the source and sink.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDriver {
    /// Producer and consumer coroutines on one cooperative executor,
    /// handing batches through a bounded async channel — the paper's
    /// Fig. 1(B) shape. `channel_capacity` is in *batches*; 1 is a
    /// rendezvous (strictest backpressure, lowest memory).
    Coroutine {
        /// Queue capacity in batches (min 1).
        channel_capacity: usize,
    },
    /// Plain pull-process-push loop on the calling thread (baseline).
    Sync,
}

/// Streaming run parameters.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Target events per batch for chunkable sources and the peak
    /// per-hop memory unit.
    pub chunk_size: usize,
    /// Scheduling strategy.
    pub driver: StreamDriver,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            chunk_size: 4096,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
        }
    }
}

impl StreamConfig {
    /// The synchronous baseline with the default chunk size.
    pub fn sync() -> Self {
        StreamConfig { driver: StreamDriver::Sync, ..Default::default() }
    }
}

/// Outcome of a streaming run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Events read from the source.
    pub events_in: u64,
    /// Events that survived the pipeline into the sink.
    pub events_out: u64,
    /// Frames produced (frame-binning sinks only).
    pub frames: u64,
    /// Batches pulled from the source.
    pub batches: u64,
    /// Peak events queued between producer and consumer at any instant
    /// (coroutine driver: channel occupancy; sync driver: the single
    /// resident batch). Bounded by
    /// `channel_capacity × max_batch_len` — the O(chunk) guarantee.
    pub peak_in_flight: usize,
    /// Times the producer found the channel full and suspended
    /// (coroutine driver only): a backpressure gauge.
    pub backpressure_waits: u64,
    /// Wall time.
    pub wall: Duration,
    /// Sensor geometry of the source (final value for growing sources).
    pub resolution: Resolution,
}

impl StreamReport {
    /// Events per second through the pipeline.
    pub fn throughput(&self) -> f64 {
        self.events_in as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drive `source → pipeline → sink` to completion.
///
/// Never materializes the stream: memory is bounded by the chunk size
/// times the channel capacity regardless of stream length.
pub fn run(
    source: &mut dyn EventSource,
    pipeline: &mut Pipeline,
    sink: &mut dyn EventSink,
    config: StreamConfig,
) -> Result<StreamReport> {
    match config.driver {
        StreamDriver::Sync => run_sync(source, pipeline, sink),
        StreamDriver::Coroutine { channel_capacity } => {
            run_coroutine(source, pipeline, sink, channel_capacity.max(1))
        }
    }
}

/// Baseline driver: one loop, no overlap.
fn run_sync(
    source: &mut dyn EventSource,
    pipeline: &mut Pipeline,
    sink: &mut dyn EventSink,
) -> Result<StreamReport> {
    let t0 = Instant::now();
    let mut events_in = 0u64;
    let mut events_out = 0u64;
    let mut batches = 0u64;
    let mut peak_in_flight = 0usize;
    while let Some(batch) = source.next_batch().context("stream source")? {
        if batch.is_empty() {
            continue; // live source idle; its poll timeout bounds the wait
        }
        events_in += batch.len() as u64;
        batches += 1;
        peak_in_flight = peak_in_flight.max(batch.len());
        let processed = pipeline.process(&batch);
        events_out += processed.len() as u64;
        sink.consume(&processed).context("stream sink")?;
    }
    sink.observe_geometry(source.resolution());
    let summary = sink.finish().context("stream sink finish")?;
    Ok(StreamReport {
        events_in,
        events_out,
        frames: summary.frames,
        batches,
        peak_in_flight,
        backpressure_waits: 0,
        wall: t0.elapsed(),
        resolution: source.resolution(),
    })
}

/// Coroutine driver: producer and consumer tasks on one cooperative
/// executor, batches handed through a bounded channel. The producer
/// suspends the moment the consumer is behind (`channel_capacity`
/// batches queued), which is the backpressure that keeps memory
/// O(chunk) for endless sources.
fn run_coroutine(
    source: &mut dyn EventSource,
    pipeline: &mut Pipeline,
    sink: &mut dyn EventSink,
    channel_capacity: usize,
) -> Result<StreamReport> {
    let t0 = Instant::now();
    let events_in = Cell::new(0u64);
    let events_out = Cell::new(0u64);
    let batches = Cell::new(0u64);
    let in_flight = Cell::new(0usize);
    let peak_in_flight = Cell::new(0usize);
    let backpressure_waits = Cell::new(0u64);
    let source_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);
    let sink_err: RefCell<Option<anyhow::Error>> = RefCell::new(None);

    {
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel::<Vec<Event>>(channel_capacity);

        // ---------------------------------------------------- producer
        {
            let (events_in, batches) = (&events_in, &batches);
            let (in_flight, peak_in_flight) = (&in_flight, &peak_in_flight);
            let backpressure_waits = &backpressure_waits;
            let source_err = &source_err;
            let source = &mut *source;
            ex.spawn(async move {
                loop {
                    let batch = match source.next_batch() {
                        Ok(Some(batch)) => batch,
                        Ok(None) => break,
                        Err(e) => {
                            *source_err.borrow_mut() = Some(e);
                            break;
                        }
                    };
                    if batch.is_empty() {
                        // Live source with nothing pending: hand control
                        // to the consumer instead of spinning.
                        yield_now().await;
                        continue;
                    }
                    let n = batch.len();
                    events_in.set(events_in.get() + n as u64);
                    batches.set(batches.get() + 1);
                    match tx.try_send(batch) {
                        Ok(()) => {}
                        Err(TrySendError::Closed(_)) => break, // consumer died
                        Err(TrySendError::Full(batch)) => {
                            backpressure_waits.set(backpressure_waits.get() + 1);
                            if tx.send(batch).await.is_err() {
                                break;
                            }
                        }
                    }
                    in_flight.set(in_flight.get() + n);
                    peak_in_flight.set(peak_in_flight.get().max(in_flight.get()));
                }
                // `tx` drops here, letting the consumer observe the close.
            });
        }

        // ---------------------------------------------------- consumer
        {
            let (events_out, in_flight) = (&events_out, &in_flight);
            let sink_err = &sink_err;
            let pipeline = &mut *pipeline;
            let sink = &mut *sink;
            ex.spawn(async move {
                while let Some(batch) = rx.recv().await {
                    in_flight.set(in_flight.get() - batch.len());
                    let processed = pipeline.process(&batch);
                    events_out.set(events_out.get() + processed.len() as u64);
                    if let Err(e) = sink.consume(&processed) {
                        *sink_err.borrow_mut() = Some(e);
                        break; // dropping `rx` fails producer sends fast
                    }
                }
            });
        }

        ex.run();
    }

    if let Some(e) = source_err.into_inner() {
        return Err(e.context("stream source"));
    }
    if let Some(e) = sink_err.into_inner() {
        return Err(e.context("stream sink"));
    }
    sink.observe_geometry(source.resolution());
    let summary = sink.finish().context("stream sink finish")?;
    Ok(StreamReport {
        events_in: events_in.get(),
        events_out: events_out.get(),
        frames: summary.frames,
        batches: batches.get(),
        peak_in_flight: peak_in_flight.get(),
        backpressure_waits: backpressure_waits.get(),
        wall: t0.elapsed(),
        resolution: source.resolution(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::PolarityFilter;
    use crate::testutil::synthetic_events;

    fn drivers() -> [StreamConfig; 3] {
        let wide = StreamDriver::Coroutine { channel_capacity: 4 };
        [
            StreamConfig::default(),
            StreamConfig { driver: wide, ..Default::default() },
            StreamConfig::sync(),
        ]
    }

    #[test]
    fn all_drivers_count_identically() {
        let events = synthetic_events(5000, 64, 64);
        let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
        for config in drivers() {
            let mut source =
                MemorySource::new(events.clone(), Resolution::new(64, 64), config.chunk_size);
            let mut pipeline = Pipeline::new().then(PolarityFilter::keep(Polarity::On));
            let mut sink = NullSink::default();
            let report = run(&mut source, &mut pipeline, &mut sink, config).unwrap();
            assert_eq!(report.events_in, 5000, "{config:?}");
            assert_eq!(report.events_out, on, "{config:?}");
            assert!(report.batches >= 5000 / config.chunk_size as u64, "{config:?}");
        }
    }

    #[test]
    fn peak_in_flight_is_bounded_by_channel_times_chunk() {
        let events = synthetic_events(100_000, 128, 128);
        let config = StreamConfig {
            chunk_size: 512,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
        };
        let mut source = MemorySource::new(events, Resolution::DVS_128, config.chunk_size);
        let mut sink = NullSink::default();
        let report = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap();
        assert_eq!(report.events_in, 100_000);
        assert!(
            report.peak_in_flight <= config.chunk_size,
            "peak {} exceeds chunk {}",
            report.peak_in_flight,
            config.chunk_size
        );
        assert!(report.peak_in_flight > 0);
    }

    #[test]
    fn sink_counts_frames() {
        let events = synthetic_events(2000, 64, 64);
        let mut source = MemorySource::new(events, Resolution::new(64, 64), 256);
        let mut sink = FrameSink::new(Resolution::new(64, 64), 1000);
        let report =
            run(&mut source, &mut Pipeline::new(), &mut sink, StreamConfig::default()).unwrap();
        assert!(report.frames > 0);
        assert_eq!(report.events_out, 2000);
    }

    #[test]
    fn empty_source_still_finishes_sink() {
        for config in drivers() {
            let mut source = MemorySource::new(Vec::new(), Resolution::new(4, 4), 16);
            let mut sink = NullSink::default();
            let report = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap();
            assert_eq!(report.events_in, 0);
            assert_eq!(report.batches, 0);
        }
    }

    #[test]
    fn source_error_propagates() {
        struct Failing(u32);
        impl EventSource for Failing {
            fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
                self.0 += 1;
                if self.0 < 3 {
                    Ok(Some(vec![Event::on(0, 0, self.0 as u64)]))
                } else {
                    anyhow::bail!("sensor unplugged")
                }
            }
            fn resolution(&self) -> Resolution {
                Resolution::new(4, 4)
            }
        }
        for config in drivers() {
            let mut source = Failing(0);
            let mut sink = NullSink::default();
            let err = run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap_err();
            assert!(format!("{err:?}").contains("sensor unplugged"), "{config:?}");
        }
    }
}
