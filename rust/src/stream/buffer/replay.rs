//! Re-serving a recorded buffer directory as an ordinary
//! [`EventSource`] — the read side of durable edges.
//!
//! A [`ReplaySource`] walks the segment chain written by
//! [`DiskBufferedSink`](super::DiskBufferedSink) (or any
//! [`SegmentWriter`](super::segment::SegmentWriter)) frame by frame,
//! skipping to a caller-chosen record offset first. Offsets count
//! records from the journal's start — the coordinate system
//! `acked.offset` uses — so `--from-offset $(acked)` resumes exactly
//! where a crashed consumer stopped (at-least-once: re-serving a little
//! is fine, losing is not). CRC-corrupt frames are counted and skipped;
//! the torn tail (already truncated by any writer re-open, but replay
//! must also survive a never-reopened directory) ends the stream
//! cleanly, never fabricating events.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::aer::{Event, Resolution};
use crate::metrics::LiveNode;
use crate::stream::sources::grow_resolution;
use crate::stream::{pool, EventSource};

use super::segment::{FrameRead, SegmentReader};

/// Pacing of a replayed stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplaySpeed {
    /// Honour recorded timestamps: sleep so event `t` is emitted about
    /// `t − t₀` after the first (training against wall-clock dynamics).
    Orig,
    /// As fast as the pipeline pulls (default; throughput runs).
    #[default]
    Max,
}

impl ReplaySpeed {
    /// Parse the CLI spelling (`orig` | `max`).
    pub fn parse(s: &str) -> Option<ReplaySpeed> {
        match s {
            "orig" => Some(ReplaySpeed::Orig),
            "max" => Some(ReplaySpeed::Max),
            _ => None,
        }
    }
}

/// Pull-based source over a recorded buffer directory. One journal
/// frame per [`next_batch`](EventSource::next_batch) call (frames are
/// the recorded batch boundaries, so replay reproduces the original
/// batching); batch buffers come from the shared pool when the topology
/// installs one.
pub struct ReplaySource {
    reader: SegmentReader,
    dir: PathBuf,
    /// Records still to skip before the first emission.
    skip: u64,
    /// Tail of the frame the skip point landed inside.
    carry: Vec<Event>,
    speed: ReplaySpeed,
    /// Wall-clock and stream-time origin, pinned at the first emission.
    origin: Option<(Instant, u64)>,
    observed_res: Resolution,
    pool: Option<Arc<pool::ChunkPool>>,
    node: Option<Arc<LiveNode>>,
    replayed: u64,
    corrupt_skipped: u64,
    done: bool,
}

impl ReplaySource {
    /// Replay `dir` from record `from_offset` (0 = the whole journal)
    /// at `speed`. Opening is cheap — segments are read lazily.
    pub fn open(dir: &Path, from_offset: u64, speed: ReplaySpeed) -> ReplaySource {
        // Start at the oldest segment present (a reclaimed journal may
        // not start at index 0); a missing/empty dir degrades to a
        // reader that yields a clean Eof — replaying nothing is not an
        // error.
        let reader =
            SegmentReader::open(dir).unwrap_or_else(|_| SegmentReader::open_at(dir, 0));
        ReplaySource {
            reader,
            dir: dir.to_path_buf(),
            skip: from_offset,
            carry: Vec::new(),
            speed,
            origin: None,
            observed_res: Resolution::new(1, 1),
            pool: None,
            node: None,
            replayed: 0,
            corrupt_skipped: 0,
            done: false,
        }
    }

    fn fresh_batch(&self, cap: usize) -> Vec<Event> {
        match &self.pool {
            Some(pool) => pool.get(cap),
            None => Vec::with_capacity(cap),
        }
    }

    /// Decode frames until the skip offset is consumed; the straddling
    /// frame's tail lands in `carry`.
    fn skip_to_offset(&mut self) -> Result<()> {
        let mut scratch: Vec<Event> = Vec::new();
        let mut passed = 0u64;
        while passed < self.skip {
            scratch.clear();
            match self.reader.next_frame(&mut scratch)? {
                FrameRead::Frame(n) => {
                    let n = n as u64;
                    if passed + n <= self.skip {
                        passed += n;
                        continue;
                    }
                    let keep = (self.skip - passed) as usize;
                    self.carry = scratch.split_off(keep);
                    passed = self.skip;
                }
                // Corrupt frames occupy offset space: the writer
                // committed those records even though they rotted.
                FrameRead::Corrupt(n) => {
                    self.corrupt_skipped += n;
                    passed += n;
                }
                FrameRead::Torn | FrameRead::Eof => {
                    self.done = true;
                    break;
                }
            }
        }
        self.skip = 0;
        Ok(())
    }

    /// Sleep until wall-clock has caught up with the batch's last
    /// timestamp (original-speed pacing).
    fn pace(&mut self, batch: &[Event]) {
        if self.speed != ReplaySpeed::Orig {
            return;
        }
        let Some(last) = batch.last() else { return };
        let (wall0, t0) = *self.origin.get_or_insert((Instant::now(), last.t));
        let stream_micros = last.t.saturating_sub(t0);
        let elapsed = wall0.elapsed().as_micros() as u64;
        if stream_micros > elapsed {
            std::thread::sleep(std::time::Duration::from_micros(stream_micros - elapsed));
        }
    }

    fn publish(&self) {
        if let Some(node) = &self.node {
            node.set_buffer_gauges(0, 0, self.replayed, self.corrupt_skipped, false);
        }
    }
}

impl EventSource for ReplaySource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if self.done {
            return Ok(None);
        }
        if self.skip > 0 {
            self.skip_to_offset()?;
            if self.done && self.carry.is_empty() {
                return Ok(None);
            }
        }
        let batch = if self.carry.is_empty() {
            let mut batch = self.fresh_batch(0);
            loop {
                match self.reader.next_frame(&mut batch)? {
                    FrameRead::Frame(_) => break batch,
                    FrameRead::Corrupt(n) => {
                        self.corrupt_skipped += n;
                        self.publish();
                        continue; // bit rot: skip, keep replaying
                    }
                    FrameRead::Torn | FrameRead::Eof => {
                        self.done = true;
                        self.publish();
                        return Ok(None);
                    }
                }
            }
        } else {
            let mut batch = self.fresh_batch(self.carry.len());
            batch.extend_from_slice(&self.carry);
            self.carry.clear();
            batch
        };
        self.replayed += batch.len() as u64;
        grow_resolution(&mut self.observed_res, &batch);
        self.pace(&batch);
        self.publish();
        Ok(Some(batch))
    }

    fn resolution(&self) -> Resolution {
        self.observed_res
    }

    /// The journal records events, not geometry: the resolution is an
    /// observed bounding box that grows as replay proceeds.
    fn geometry_known(&self) -> bool {
        false
    }

    fn set_buffer_pool(&mut self, pool: Arc<pool::ChunkPool>) {
        self.pool = Some(pool);
    }

    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        self.node = Some(node);
        self.publish();
    }

    fn describe(&self) -> String {
        format!("replay({})", self.dir.display())
    }
}

#[cfg(test)]
mod tests {
    use super::super::segment::SegmentWriter;
    use super::*;
    use crate::testutil::synthetic_events;

    fn record(dir: &Path, events: &[Event], per_frame: usize) {
        let (mut writer, _) = SegmentWriter::open(dir, 4096, false).unwrap();
        for batch in events.chunks(per_frame) {
            writer.append(batch).unwrap();
        }
        writer.sync().unwrap();
    }

    fn drain(src: &mut ReplaySource) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(batch) = src.next_batch().unwrap() {
            out.extend(batch);
        }
        out
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("aestream-replay-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn replays_whole_journal_byte_identically() {
        let dir = tmp_dir("whole");
        let events = synthetic_events(3000, 320, 240);
        record(&dir, &events, 128);
        let mut src = ReplaySource::open(&dir, 0, ReplaySpeed::Max);
        assert_eq!(drain(&mut src), events);
        assert!(!src.geometry_known());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replays_from_mid_stream_offset_including_mid_frame() {
        let dir = tmp_dir("offset");
        let events = synthetic_events(1000, 64, 64);
        record(&dir, &events, 100);
        // 250 lands mid-frame: the carry path must slice frame 3.
        for offset in [0u64, 100, 250, 999, 1000, 5000] {
            let mut src = ReplaySource::open(&dir, offset, ReplaySpeed::Max);
            let expect: Vec<Event> =
                events.iter().skip(offset as usize).copied().collect();
            assert_eq!(drain(&mut src), expect, "offset {offset}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_or_missing_dir_replays_nothing() {
        let dir = tmp_dir("missing");
        let mut src = ReplaySource::open(&dir, 0, ReplaySpeed::Max);
        assert_eq!(src.next_batch().unwrap(), None);
    }
}
