//! Durable spill-to-disk edge buffers (ROADMAP item 2).
//!
//! Every other edge in the engine is an in-memory ring, so one slow
//! sink or a burst beyond RAM means drops or stalls. This module makes
//! an edge *durable*: [`DiskBufferedSink`] wraps any
//! [`EventSink`](super::EventSink) behind a write-ahead journal of
//! CRC32-framed record batches ([`segment`]), a bounded in-memory
//! front, and a pair of named OS threads:
//!
//! ```text
//! feeder (driver) ──ring──▶ buf:w/<edge> ──tokens──▶ buf:r/<edge> ──▶ sink
//!                            │ journals every batch      │ drains FIFO
//!                            ▼                           ▼
//!                        segment-000000, segment-000001, …   acked.offset
//! ```
//!
//! The writer journals **every** batch to disk first (write-ahead: the
//! recording is complete and replayable, and delivery is at-least-once
//! across a crash), then enqueues a delivery token. While the bounded
//! front has room the token carries the in-memory chunk and the drainer
//! never touches the disk for it (the journal write is sequential and
//! the read is skipped — the fast path costs one framed append). When
//! the front is full the token drops the memory copy — the **spill** —
//! and the drainer reads the batch back from the journal when the sink
//! catches up. Order is a single FIFO token queue either way, so the
//! wrapped sink sees exactly the byte sequence a pure-memory edge would
//! have delivered.
//!
//! Cap semantics (`cap_bytes` bounds the journal): in pure-spill mode
//! (`retain_acked = false`) the writer reclaims fully-consumed sealed
//! segments to free space, waiting for the drainer when the journal is
//! full — and if nothing is left to reclaim (a single frame larger than
//! the remaining cap), it overshoots by that one frame rather than
//! deadlock. With retention (`retain_acked = true`, the default —
//! that's what makes the edge *replayable*) nothing ever frees, so a
//! full journal degrades to a bounded in-memory pass-through: batches
//! keep flowing with bounded memory and zero loss, they are just no
//! longer journaled (counted as backpressure on the edge).
//!
//! `acked.offset` tracks delivery: after a crash,
//! [`read_acked_offset`](segment::read_acked_offset) names the first
//! record that still needs re-serving and [`ReplaySource`] re-serves
//! the journal from any offset at original or max speed.

pub mod segment;

mod replay;

pub use replay::{ReplaySource, ReplaySpeed};
pub use segment::read_acked_offset;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context as _, Result};

use crate::aer::{Event, Resolution};
use crate::metrics::LiveNode;
use crate::rt::{block_on, sync_channel, SyncReceiver, SyncSender};

use super::chunk::EventChunk;
use super::{EventSink, SinkSummary};

use segment::{
    write_acked_offset, FrameRead, SegmentReader, SegmentWriter, DEFAULT_SEGMENT_BYTES,
    FRAME_HEADER_BYTES, RECORD_BYTES,
};

/// Batches buffered in the feeder→writer ring (mirrors the sink pumps'
/// queue): enough to decouple the driver from journal latency, small
/// enough to keep the edge's memory O(chunk).
const FEED_QUEUE_BATCHES: usize = 2;

/// Configuration of one disk-buffered edge (`buffer = disk{cap, dir}`
/// in a graph spec).
#[derive(Debug, Clone)]
pub struct DiskBufferConfig {
    /// Journal directory (created if missing; an existing journal is
    /// recovered — torn tail truncated — and appended after).
    pub dir: PathBuf,
    /// Journal size cap in bytes. See the module docs for what happens
    /// at the cap in each retention mode.
    pub cap_bytes: u64,
    /// Bounded in-memory front: how many batches may wait for the sink
    /// in RAM before their memory copy is dropped (spilled). ≥ 1.
    pub front_batches: usize,
    /// `true` (default): fsync after every appended frame — a committed
    /// batch survives power loss. `false`: fsync only at segment
    /// rotation and finish (faster; a crash may lose the OS-cached
    /// tail, recovery still truncates to the last committed frame).
    pub fsync_per_batch: bool,
    /// `true` (default): keep delivered segments on disk so the whole
    /// edge stays replayable. `false`: reclaim fully-delivered segments
    /// under cap pressure (pure spill-queue mode).
    pub retain_acked: bool,
    /// Segment rotation threshold in bytes (clamped to `cap_bytes / 4`
    /// so reclaim granularity can keep up with the cap).
    pub segment_bytes: u64,
}

impl DiskBufferConfig {
    /// Durable defaults: 8-batch front, per-frame fsync, retained
    /// journal, 8 MiB segments.
    pub fn new(dir: impl Into<PathBuf>, cap_bytes: u64) -> DiskBufferConfig {
        DiskBufferConfig {
            dir: dir.into(),
            cap_bytes,
            front_batches: 8,
            fsync_per_batch: true,
            retain_acked: true,
            segment_bytes: DEFAULT_SEGMENT_BYTES,
        }
    }
}

/// Counters shared by the feeder, writer, and drainer threads.
#[derive(Debug, Default)]
struct BufferStats {
    bytes_on_disk: AtomicU64,
    records_spilled: AtomicU64,
    records_replayed: AtomicU64,
    corrupt_records_skipped: AtomicU64,
    /// Spilled batches journaled but not yet drained (spill_active
    /// gauge).
    disk_pending: AtomicU64,
    /// High-water mark of batches held in the bounded memory front.
    peak_mem_batches: AtomicU64,
}

/// A point-in-time view of a buffered edge's counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct BufferSnapshot {
    /// Journal bytes currently on disk.
    pub bytes_on_disk: u64,
    /// Records whose memory copy was dropped (they drain from disk).
    pub records_spilled: u64,
    /// Records read back from the journal by the drainer.
    pub records_replayed: u64,
    /// Records lost to CRC-failed journal frames and skipped.
    pub corrupt_records_skipped: u64,
    /// Whether spilled batches are still waiting on disk.
    pub spill_active: bool,
    /// High-water mark of batches held in the bounded memory front —
    /// the buffered edge's memory bound (≤ `front_batches` by
    /// construction).
    pub peak_mem_batches: u64,
}

impl BufferStats {
    fn snapshot(&self) -> BufferSnapshot {
        BufferSnapshot {
            bytes_on_disk: self.bytes_on_disk.load(Ordering::Relaxed),
            records_spilled: self.records_spilled.load(Ordering::Relaxed),
            records_replayed: self.records_replayed.load(Ordering::Relaxed),
            corrupt_records_skipped: self.corrupt_records_skipped.load(Ordering::Relaxed),
            spill_active: self.disk_pending.load(Ordering::Relaxed) > 0,
            peak_mem_batches: self.peak_mem_batches.load(Ordering::Relaxed),
        }
    }
}

/// What the feeder hands the writer thread (mirrors `SinkMsg` on the
/// sink pumps; chunks cross by refcount, never by copy).
enum FeedMsg {
    Batch(EventChunk),
    Geometry(Resolution),
}

/// One FIFO delivery unit from writer to drainer. Order of tokens is
/// delivery order; a `Disk` token coalesces consecutive spilled batches
/// so the queue stays O(front) even when millions of batches are on
/// disk.
enum Token {
    /// Batch still in the memory front. `journaled` says whether a
    /// journal frame backs it (the drainer must hop its disk cursor
    /// past that frame); `false` only for cap-degraded pass-through.
    Mem { chunk: EventChunk, journaled: bool },
    /// This many consecutive batches whose memory copy was dropped:
    /// read each back from the journal.
    Disk { batches: u64 },
    Geometry(Resolution),
    /// Writer-side failure, delivered in order so the drainer stops at
    /// the same point the journal did.
    Fail(anyhow::Error),
}

/// Token queue + wakeups shared by writer and drainer.
#[derive(Default)]
struct QueueShared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct QueueState {
    tokens: VecDeque<Token>,
    /// Batches currently held in the memory front.
    mem_batches: usize,
    /// Journal frames fully processed by the drainer (read or hopped) —
    /// what the writer's cap reclaim keys on.
    consumed_frames: u64,
    done_writing: bool,
    drainer_dead: bool,
}

/// Clip a thread name to the 15-byte Linux limit at a char boundary
/// (longer names silently fail to apply).
fn thread_name(prefix: &str, label: &str) -> String {
    let mut name = format!("{prefix}{label}");
    let mut end = name.len().min(15);
    while !name.is_char_boundary(end) {
        end -= 1;
    }
    name.truncate(end);
    name
}

// --------------------------------------------------------------- writer

fn writer_loop(
    mut rx: SyncReceiver<FeedMsg>,
    mut seg: SegmentWriter,
    shared: &QueueShared,
    stats: &BufferStats,
    cfg: &DiskBufferConfig,
) {
    let result = (|| -> Result<()> {
        while let Some(msg) = block_on(rx.recv()) {
            let chunk = match msg {
                FeedMsg::Geometry(res) => {
                    let mut q = shared.q.lock().unwrap();
                    if q.drainer_dead {
                        return Ok(());
                    }
                    q.tokens.push_back(Token::Geometry(res));
                    shared.cv.notify_all();
                    continue;
                }
                FeedMsg::Batch(chunk) => chunk,
            };
            let frame_bytes = (FRAME_HEADER_BYTES + chunk.len() * RECORD_BYTES) as u64;
            if stats.bytes_on_disk.load(Ordering::Relaxed) + frame_bytes > cfg.cap_bytes {
                if cfg.retain_acked {
                    // Retention means nothing ever frees: degrade to a
                    // bounded in-memory pass-through. No loss, bounded
                    // memory — the batch just is not journaled.
                    let mut q = shared.q.lock().unwrap();
                    loop {
                        if q.drainer_dead {
                            return Ok(());
                        }
                        if q.mem_batches < cfg.front_batches {
                            break;
                        }
                        q = shared.cv.wait(q).unwrap();
                    }
                    q.mem_batches += 1;
                    stats.peak_mem_batches.fetch_max(q.mem_batches as u64, Ordering::Relaxed);
                    q.tokens.push_back(Token::Mem { chunk, journaled: false });
                    shared.cv.notify_all();
                    continue;
                }
                // Pure spill mode: fully-consumed sealed segments are
                // garbage — reclaim them, waiting for the drainer to
                // consume more when that is not yet enough.
                let mut q = shared.q.lock().unwrap();
                loop {
                    if q.drainer_dead {
                        return Ok(());
                    }
                    let freed = seg.reclaim(q.consumed_frames)?;
                    if freed > 0 {
                        stats.bytes_on_disk.fetch_sub(freed, Ordering::Relaxed);
                        shared.cv.notify_all();
                    }
                    if stats.bytes_on_disk.load(Ordering::Relaxed) + frame_bytes
                        <= cfg.cap_bytes
                    {
                        break;
                    }
                    if !seg.reclaimable() {
                        // Everything reclaimable is gone and this one
                        // frame still does not fit: overshoot the cap by
                        // one frame rather than deadlock.
                        break;
                    }
                    q = shared.cv.wait(q).unwrap();
                }
            }
            let bytes = seg.append(chunk.as_slice())?;
            stats.bytes_on_disk.fetch_add(bytes, Ordering::Relaxed);
            let mut q = shared.q.lock().unwrap();
            if q.drainer_dead {
                return Ok(());
            }
            if q.mem_batches < cfg.front_batches {
                // Fast path: the batch rides through memory; the disk
                // copy is write-ahead durability only.
                q.mem_batches += 1;
                stats.peak_mem_batches.fetch_max(q.mem_batches as u64, Ordering::Relaxed);
                q.tokens.push_back(Token::Mem { chunk, journaled: true });
            } else {
                // Spill: drop the RAM copy; the drainer reads it back.
                stats.records_spilled.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                stats.disk_pending.fetch_add(1, Ordering::Relaxed);
                match q.tokens.back_mut() {
                    Some(Token::Disk { batches }) => *batches += 1,
                    _ => q.tokens.push_back(Token::Disk { batches: 1 }),
                }
            }
            shared.cv.notify_all();
        }
        if !cfg.fsync_per_batch {
            seg.sync()?;
        }
        Ok(())
    })();
    let mut q = shared.q.lock().unwrap();
    if let Err(e) = result {
        q.tokens.push_back(Token::Fail(e));
    }
    q.done_writing = true;
    shared.cv.notify_all();
}

// -------------------------------------------------------------- drainer

#[allow(clippy::too_many_arguments)]
fn drainer_loop(
    mut sink: Box<dyn EventSink>,
    dir: &std::path::Path,
    start_index: u64,
    ack_base: u64,
    shared: &QueueShared,
    stats: &BufferStats,
) -> Result<SinkSummary> {
    let mut reader = SegmentReader::open_at(dir, start_index);
    let mut delivered = ack_base;
    let mut scratch: Vec<Event> = Vec::new();
    loop {
        let token = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if let Some(t) = q.tokens.pop_front() {
                    break Some(t);
                }
                if q.done_writing {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let Some(token) = token else { break };
        match token {
            Token::Geometry(res) => sink.observe_geometry(res),
            Token::Mem { chunk, journaled } => {
                if journaled {
                    // Hop the disk cursor past this batch's journal
                    // frame without reading it.
                    match reader.skip_frame().context("advancing disk journal cursor")? {
                        FrameRead::Frame(_) => {}
                        _ => bail!("disk buffer journal ended before a committed frame"),
                    }
                }
                sink.consume_chunk(&chunk)?;
                delivered += chunk.len() as u64;
                let mut q = shared.q.lock().unwrap();
                q.mem_batches -= 1;
                q.consumed_frames += u64::from(journaled);
                drop(q);
                shared.cv.notify_all();
            }
            Token::Disk { batches } => {
                for _ in 0..batches {
                    scratch.clear();
                    match reader.next_frame(&mut scratch).context("reading spilled batch")? {
                        FrameRead::Frame(n) => {
                            sink.consume(&scratch)?;
                            delivered += n as u64;
                            stats.records_replayed.fetch_add(n as u64, Ordering::Relaxed);
                        }
                        FrameRead::Corrupt(n) => {
                            // Bit rot between write and read-back: the
                            // frame is gone either way; count it and
                            // advance the ack past it so replay does not
                            // loop on it forever.
                            stats
                                .corrupt_records_skipped
                                .fetch_add(n, Ordering::Relaxed);
                            delivered += n;
                        }
                        FrameRead::Torn | FrameRead::Eof => {
                            bail!("disk buffer journal ended before a committed frame")
                        }
                    }
                    stats.disk_pending.fetch_sub(1, Ordering::Relaxed);
                    let mut q = shared.q.lock().unwrap();
                    q.consumed_frames += 1;
                    drop(q);
                    shared.cv.notify_all();
                }
            }
            Token::Fail(e) => return Err(e),
        }
        write_acked_offset(dir, delivered)?;
    }
    let summary = sink.finish().context("disk-buffered sink finish")?;
    write_acked_offset(dir, delivered)?;
    Ok(summary)
}

// ----------------------------------------------------------------- sink

/// Any [`EventSink`] behind a crash-safe disk journal with a bounded
/// memory front — see the module docs for the full data path. The
/// wrapper is itself an `EventSink`, so it slots into any topology
/// unchanged (graphs compile it in for edges with `buffer =
/// disk{cap, dir}`).
pub struct DiskBufferedSink {
    /// `None` once finished (the close signal is dropping the sender).
    tx: Option<SyncSender<FeedMsg>>,
    done: SyncReceiver<Result<SinkSummary>>,
    writer: Option<std::thread::JoinHandle<()>>,
    drainer: Option<std::thread::JoinHandle<()>>,
    stats: Arc<BufferStats>,
    node: Option<Arc<LiveNode>>,
    name: String,
    /// Full-ring suspensions of the feeder side (our half of the
    /// backpressure ledger).
    waits: u64,
}

impl DiskBufferedSink {
    /// Wrap `sink` behind the journal at `config.dir`. `label` names
    /// the edge (thread names `buf:w/<label>`, `buf:r/<label>`).
    /// Journal recovery (torn-tail truncation) happens here, on the
    /// caller's thread, so directory problems surface at compile time
    /// rather than mid-stream.
    pub fn spawn(
        sink: Box<dyn EventSink>,
        config: DiskBufferConfig,
        label: &str,
    ) -> Result<DiskBufferedSink> {
        if config.cap_bytes == 0 {
            bail!("disk buffer cap_bytes must be > 0");
        }
        if config.front_batches == 0 {
            bail!("disk buffer front_batches must be ≥ 1");
        }
        let mut config = config;
        // Reclaim granularity is whole segments: keep several per cap
        // so pure-spill mode can actually free space under pressure.
        config.segment_bytes =
            config.segment_bytes.clamp(1, (config.cap_bytes / 4).max(1));
        let name = sink.describe();
        let (seg, recovery) =
            SegmentWriter::open(&config.dir, config.segment_bytes, config.fsync_per_batch)?;
        let start_index = seg.start_index();
        let stats = Arc::new(BufferStats::default());
        stats.bytes_on_disk.store(recovery.committed_bytes, Ordering::Relaxed);
        let shared = Arc::new(QueueShared::default());
        let (tx, rx) = sync_channel::<FeedMsg>(FEED_QUEUE_BATCHES);
        let (mut done_tx, done) = sync_channel::<Result<SinkSummary>>(1);

        let writer = {
            let (shared, stats, cfg) = (Arc::clone(&shared), Arc::clone(&stats), config.clone());
            std::thread::Builder::new()
                .name(thread_name("buf:w/", label))
                .spawn(move || writer_loop(rx, seg, &shared, &stats, &cfg))
                .expect("spawn buffer writer thread")
        };
        let drainer = {
            let (shared, stats) = (Arc::clone(&shared), Arc::clone(&stats));
            let dir = config.dir.clone();
            let ack_base = recovery.committed_records;
            std::thread::Builder::new()
                .name(thread_name("buf:r/", label))
                .spawn(move || {
                    let result =
                        drainer_loop(sink, &dir, start_index, ack_base, &shared, &stats);
                    if result.is_err() {
                        let mut q = shared.q.lock().unwrap();
                        q.drainer_dead = true;
                        drop(q);
                        shared.cv.notify_all();
                    }
                    let _ = block_on(done_tx.send(result));
                })
                .expect("spawn buffer drainer thread")
        };
        Ok(DiskBufferedSink {
            tx: Some(tx),
            done,
            writer: Some(writer),
            drainer: Some(drainer),
            stats,
            node: None,
            name,
            waits: 0,
        })
    }

    /// A point-in-time view of the edge's counters (the bounded-front
    /// assertion in tier-1 tests reads `peak_mem_batches` here).
    pub fn stats(&self) -> BufferSnapshot {
        self.stats.snapshot()
    }

    fn publish(&self) {
        if let Some(node) = &self.node {
            let s = self.stats.snapshot();
            node.set_buffer_gauges(
                s.bytes_on_disk,
                s.records_spilled,
                s.records_replayed,
                s.corrupt_records_skipped,
                s.spill_active,
            );
        }
    }

    /// Push one message into the feed ring, suspending on a full ring
    /// and surfacing a dead pipeline's error immediately.
    fn send_to_writer(&mut self, msg: FeedMsg) -> Result<()> {
        let Some(tx) = self.tx.as_mut() else {
            bail!("disk-buffered sink {:?} already finished", self.name);
        };
        match tx.try_send(msg) {
            Ok(()) => Ok(()),
            Err(msg) => {
                // Ring full (backpressure) or writer gone: the blocking
                // send distinguishes them.
                self.waits += 1;
                if block_on(tx.send(msg)).is_ok() {
                    return Ok(());
                }
                match self.join() {
                    Ok(_) => {
                        bail!("buffer threads for {:?} exited early", self.name)
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }

    /// Close the feed ring, collect the drainer's result, join both
    /// threads. Idempotent via `tx`/handles being `Option`s.
    fn join(&mut self) -> Result<SinkSummary> {
        drop(self.tx.take()); // close: writer drains, drainer finishes
        let result = block_on(self.done.recv());
        for handle in [self.writer.take(), self.drainer.take()].into_iter().flatten() {
            if handle.join().is_err() {
                bail!("buffer thread for {:?} panicked", self.name);
            }
        }
        let mut summary = result
            .with_context(|| format!("buffer drainer for {:?} vanished", self.name))??;
        summary.backpressure_waits += self.waits;
        self.publish();
        Ok(summary)
    }
}

impl EventSink for DiskBufferedSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        // Borrowed-slice entry point: the copy is unavoidable (counted).
        self.consume_chunk(&EventChunk::from_slice(batch))
    }

    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        if chunk.is_empty() {
            return Ok(());
        }
        self.send_to_writer(FeedMsg::Batch(chunk.clone()))?; // refcount bump
        self.publish();
        Ok(())
    }

    fn observe_geometry(&mut self, res: Resolution) {
        if let Some(tx) = self.tx.as_mut() {
            // Best-effort: a dead pipeline's error surfaces at finish.
            if tx.try_send(FeedMsg::Geometry(res)).is_err() {
                let _ = block_on(tx.send(FeedMsg::Geometry(res)));
            }
        }
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        self.join()
    }

    fn set_live_node(&mut self, node: Arc<LiveNode>) {
        self.node = Some(node);
        self.publish();
    }

    fn describe(&self) -> String {
        format!("diskbuf({})", self.name)
    }
}

impl Drop for DiskBufferedSink {
    fn drop(&mut self) {
        // Error paths skip finish(): close the ring and join so no
        // buf:* thread outlives the topology (best effort).
        drop(self.tx.take());
        for handle in [self.writer.take(), self.drainer.take()].into_iter().flatten() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::CaptureSink;
    use crate::testutil::synthetic_events;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("aestream-buf-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    /// Byte-identity vs a pure-memory edge, with a front small enough
    /// that most batches spill.
    #[test]
    fn buffered_edge_is_byte_identical_and_spills() {
        let dir = tmp_dir("identity");
        let events = synthetic_events(5000, 320, 240);
        let (capture, captured) = CaptureSink::new();
        let mut config = DiskBufferConfig::new(&dir, 64 * 1024 * 1024);
        config.front_batches = 1;
        config.fsync_per_batch = false;
        let mut sink = DiskBufferedSink::spawn(Box::new(capture), config, "t").unwrap();
        for batch in events.chunks(100) {
            sink.consume(batch).unwrap();
        }
        let summary = sink.finish().unwrap();
        assert_eq!(summary.dropped, 0);
        let got = captured.lock().unwrap().clone();
        assert_eq!(got, events, "buffered edge must preserve byte identity");
        let stats = sink.stats();
        assert!(stats.peak_mem_batches <= 1, "front bound violated: {stats:?}");
        assert_eq!(stats.corrupt_records_skipped, 0);
        assert!(!stats.spill_active, "drained journal must clear spill_active");
        assert_eq!(read_acked_offset(&dir), 5000);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Pure spill mode under a tight cap: the writer reclaims consumed
    /// segments instead of growing the journal without bound.
    #[test]
    fn pure_spill_mode_reclaims_under_cap() {
        let dir = tmp_dir("reclaim");
        let events = synthetic_events(20_000, 128, 128);
        let (capture, captured) = CaptureSink::new();
        // 20k events × 16 B ≈ 320 KiB of payload through a 64 KiB cap.
        let mut config = DiskBufferConfig::new(&dir, 64 * 1024);
        config.front_batches = 2;
        config.fsync_per_batch = false;
        config.retain_acked = false;
        let mut sink = DiskBufferedSink::spawn(Box::new(capture), config, "r").unwrap();
        for batch in events.chunks(500) {
            sink.consume(batch).unwrap();
        }
        sink.finish().unwrap();
        assert_eq!(captured.lock().unwrap().clone(), events);
        let stats = sink.stats();
        // One frame of slack over the cap is the documented overshoot.
        assert!(
            stats.bytes_on_disk <= 64 * 1024 + (FRAME_HEADER_BYTES + 500 * RECORD_BYTES) as u64,
            "journal exceeded its cap: {stats:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
