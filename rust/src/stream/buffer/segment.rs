//! Crash-safe segmented frame storage: the disk layer under
//! [`DiskBufferedSink`](super::DiskBufferedSink) and
//! [`ReplaySource`](super::ReplaySource).
//!
//! A buffer directory holds an append-only chain of `segment-NNNNNN`
//! files. Each segment is a sequence of **frames**:
//!
//! ```text
//! [u32 LE record count][u32 LE CRC32 of payload][payload]
//! payload = count × 16-byte spool records (t u64 LE, x u16 LE,
//!           y u16 LE, p u8, 3 zero pad — the FileSink spool layout)
//! ```
//!
//! Every frame is written with one `write_all` and (per the fsync
//! policy) one `sync_data`, so after a crash the journal is a prefix of
//! fully-committed frames followed by at most one torn tail. Recovery
//! on open scans each segment by header hopscotch, truncates the torn
//! tail back to the last committed frame boundary, and reports the
//! committed totals. Truncation can never fabricate events: a cut
//! inside a payload reads as "payload extends past EOF" (torn), never
//! as a CRC-valid frame. A *complete* frame whose checksum fails is bit
//! rot, not a torn tail — readers skip it and count its records instead
//! of stopping.
//!
//! `acked.offset` in the same directory records how many records have
//! been delivered downstream (atomic tmp+rename), giving at-least-once
//! restart: replay the journal from [`read_acked_offset`] after a
//! crash.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufReader, Read, Seek, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use crate::aer::{Event, Polarity};

/// Bytes per spool record (matches `stream::sinks`' spool layout).
pub const RECORD_BYTES: usize = 16;

/// Bytes per frame header (record count + payload CRC32).
pub const FRAME_HEADER_BYTES: usize = 8;

/// Sanity cap on a frame's record count: a header claiming more is
/// treated as corruption (stop, don't allocate gigabytes).
pub const MAX_FRAME_RECORDS: u32 = 1 << 22;

/// Default segment rotation threshold.
pub const DEFAULT_SEGMENT_BYTES: u64 = 8 * 1024 * 1024;

const ACKED_FILE: &str = "acked.offset";

// ------------------------------------------------------------------ crc

/// CRC32 (IEEE 802.3, reflected poly 0xEDB88320) lookup table, built at
/// compile time — `aer::checksum` is the paper's coordinate-sum
/// workload, not a real checksum, so the framing brings its own.
const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            j += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC32 of `data` (IEEE, as used by gzip/zip/PNG).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ u32::from(b)) & 0xFF) as usize];
    }
    !crc
}

// -------------------------------------------------------------- records

/// Append one event as a 16-byte spool record.
pub(crate) fn encode_record(ev: &Event, out: &mut Vec<u8>) {
    out.extend_from_slice(&ev.t.to_le_bytes());
    out.extend_from_slice(&ev.x.to_le_bytes());
    out.extend_from_slice(&ev.y.to_le_bytes());
    out.push(u8::from(ev.p.is_on()));
    out.extend_from_slice(&[0u8; 3]);
}

/// Decode one 16-byte spool record (lossless inverse of
/// [`encode_record`]).
pub(crate) fn decode_record(rec: &[u8]) -> Event {
    Event {
        t: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
        x: u16::from_le_bytes(rec[8..10].try_into().unwrap()),
        y: u16::from_le_bytes(rec[10..12].try_into().unwrap()),
        p: Polarity::from_bool(rec[12] != 0),
    }
}

// --------------------------------------------------------------- frames

/// Serialize one batch as a framed blob into `out` (cleared first).
pub fn encode_frame(events: &[Event], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(FRAME_HEADER_BYTES + events.len() * RECORD_BYTES);
    out.extend_from_slice(&(events.len() as u32).to_le_bytes());
    out.extend_from_slice(&[0u8; 4]); // CRC placeholder
    for ev in events {
        encode_record(ev, out);
    }
    let crc = crc32(&out[FRAME_HEADER_BYTES..]);
    out[4..8].copy_from_slice(&crc.to_le_bytes());
}

/// Frame one batch onto any writer, reusing `scratch` for the encode.
/// Returns the frame's on-disk size in bytes.
pub fn write_frame<W: Write>(
    w: &mut W,
    events: &[Event],
    scratch: &mut Vec<u8>,
) -> std::io::Result<u64> {
    encode_frame(events, scratch);
    w.write_all(scratch)?;
    Ok(scratch.len() as u64)
}

/// Outcome of pulling one frame off a journal.
#[derive(Debug)]
pub enum FrameRead {
    /// A committed, checksum-valid frame of this many records
    /// (appended to the caller's buffer).
    Frame(usize),
    /// A complete frame whose payload failed its CRC (bit rot): the
    /// cursor advanced past it, nothing was decoded; this many records
    /// were lost.
    Corrupt(u64),
    /// The stream ends inside a frame header or payload — the torn
    /// tail of a crashed writer. Nothing before it is affected.
    Torn,
    /// Clean end of stream at a frame boundary.
    Eof,
}

/// Read exactly `buf.len()` bytes unless the stream ends first; returns
/// how many bytes actually landed (distinguishing clean EOF at 0 from a
/// torn partial read).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..])? {
            0 => break,
            n => got += n,
        }
    }
    Ok(got)
}

/// Pull one frame off `r`, appending its events to `out` on success.
/// `payload` is a reusable scratch buffer.
pub fn read_frame<R: Read>(
    r: &mut R,
    payload: &mut Vec<u8>,
    out: &mut Vec<Event>,
) -> std::io::Result<FrameRead> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    let got = read_full(r, &mut header)?;
    if got == 0 {
        return Ok(FrameRead::Eof);
    }
    if got < FRAME_HEADER_BYTES {
        return Ok(FrameRead::Torn);
    }
    let count = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if count > MAX_FRAME_RECORDS {
        // An insane header is indistinguishable from garbage: stop
        // rather than seek into the void.
        return Ok(FrameRead::Torn);
    }
    let payload_len = count as usize * RECORD_BYTES;
    payload.clear();
    payload.resize(payload_len, 0);
    if read_full(r, payload)? < payload_len {
        return Ok(FrameRead::Torn);
    }
    if crc32(payload) != crc {
        return Ok(FrameRead::Corrupt(u64::from(count)));
    }
    out.reserve(count as usize);
    for rec in payload.chunks_exact(RECORD_BYTES) {
        out.push(decode_record(rec));
    }
    Ok(FrameRead::Frame(count as usize))
}

// ------------------------------------------------------------- segments

fn segment_path(dir: &Path, index: u64) -> PathBuf {
    dir.join(format!("segment-{index:06}"))
}

/// Sorted indices of the segment files present in `dir`.
fn list_segments(dir: &Path) -> Result<Vec<u64>> {
    let mut indices = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("listing buffer dir {}", dir.display()))?
    {
        let entry = entry?;
        if let Some(rest) =
            entry.file_name().to_str().and_then(|n| n.strip_prefix("segment-").map(String::from))
        {
            if let Ok(index) = rest.parse::<u64>() {
                indices.push(index);
            }
        }
    }
    indices.sort_unstable();
    Ok(indices)
}

/// What one segment's committed prefix holds.
struct SegmentScan {
    frames: u64,
    records: u64,
    /// Byte offset of the last committed frame boundary.
    valid_end: u64,
    file_len: u64,
}

/// Scan a segment by header hopscotch (no payload reads, no CRC): a
/// frame is *committed* iff its header and full payload fit inside the
/// file. CRC-corrupt frames still count as committed — readers skip
/// them at read time.
fn scan_segment(path: &Path) -> Result<SegmentScan> {
    let file =
        File::open(path).with_context(|| format!("opening segment {}", path.display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut scan = SegmentScan { frames: 0, records: 0, valid_end: 0, file_len };
    let mut pos = 0u64;
    loop {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        let got = read_full(&mut r, &mut header)?;
        if got < FRAME_HEADER_BYTES {
            break; // clean end (0) or torn header (partial)
        }
        let count = u32::from_le_bytes(header[0..4].try_into().unwrap());
        if count > MAX_FRAME_RECORDS {
            break; // insane header: treat as the torn tail
        }
        let payload_len = count as u64 * RECORD_BYTES as u64;
        if pos + FRAME_HEADER_BYTES as u64 + payload_len > file_len {
            break; // payload extends past EOF: torn tail
        }
        r.seek_relative(payload_len as i64)?;
        pos += FRAME_HEADER_BYTES as u64 + payload_len;
        scan.frames += 1;
        scan.records += u64::from(count);
        scan.valid_end = pos;
    }
    Ok(scan)
}

/// What [`SegmentWriter::open`] found (and fixed) in an existing
/// buffer directory.
#[derive(Debug, Default, Clone, Copy)]
pub struct Recovery {
    /// Committed frames across all pre-existing segments.
    pub committed_frames: u64,
    /// Committed records across all pre-existing segments.
    pub committed_records: u64,
    /// Bytes of committed journal on disk after recovery.
    pub committed_bytes: u64,
    /// Torn-tail bytes truncated away.
    pub truncated_bytes: u64,
}

/// A rotated-out (or pre-existing) segment the writer may reclaim once
/// its frames are consumed.
struct SealedSegment {
    index: u64,
    /// Cumulative this-run frame count at this segment's end (0 for
    /// segments inherited from a previous run: reclaimable first).
    end_frame: u64,
    bytes: u64,
}

/// Append side of a buffer directory: rotating segment files of framed
/// batches, torn-tail recovery on open, optional fsync per frame.
pub struct SegmentWriter {
    dir: PathBuf,
    file: File,
    index: u64,
    first_index: u64,
    /// Frames appended by *this* writer (recovery frames excluded).
    frames: u64,
    written: u64,
    target: u64,
    fsync: bool,
    scratch: Vec<u8>,
    sealed: VecDeque<SealedSegment>,
}

impl SegmentWriter {
    /// Open `dir` for appending: create it if missing, truncate any
    /// torn tail in existing segments back to the last committed frame,
    /// and start a fresh segment after the newest existing one.
    pub fn open(dir: &Path, target: u64, fsync: bool) -> Result<(SegmentWriter, Recovery)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating buffer dir {}", dir.display()))?;
        let indices = list_segments(dir)?;
        let mut recovery = Recovery::default();
        let mut sealed = VecDeque::new();
        for &i in &indices {
            let path = segment_path(dir, i);
            let scan = scan_segment(&path)?;
            if scan.valid_end < scan.file_len {
                // Torn tail (crash mid-frame): truncate back to the
                // last committed boundary so the chain stays parseable.
                let f = std::fs::OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .with_context(|| format!("truncating {}", path.display()))?;
                f.set_len(scan.valid_end)?;
                recovery.truncated_bytes += scan.file_len - scan.valid_end;
            }
            recovery.committed_frames += scan.frames;
            recovery.committed_records += scan.records;
            recovery.committed_bytes += scan.valid_end;
            sealed.push_back(SealedSegment { index: i, end_frame: 0, bytes: scan.valid_end });
        }
        let index = indices.last().map_or(0, |last| last + 1);
        let path = segment_path(dir, index);
        let file = File::create(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        Ok((
            SegmentWriter {
                dir: dir.to_path_buf(),
                file,
                index,
                first_index: index,
                frames: 0,
                written: 0,
                target: target.max(FRAME_HEADER_BYTES as u64 + RECORD_BYTES as u64),
                fsync,
                scratch: Vec::new(),
                sealed,
            },
            recovery,
        ))
    }

    /// Index of the first segment this writer appends to (where a
    /// paired reader starts).
    pub fn start_index(&self) -> u64 {
        self.first_index
    }

    /// Frames appended by this writer so far.
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Append one batch as a committed frame; returns its on-disk size.
    /// Frames never split across segments: rotation happens between
    /// frames once the current segment passes its target size.
    pub fn append(&mut self, events: &[Event]) -> Result<u64> {
        if self.written >= self.target {
            self.rotate()?;
        }
        let bytes = write_frame(&mut self.file, events, &mut self.scratch)
            .with_context(|| format!("appending to segment {}", self.index))?;
        if self.fsync {
            self.file.sync_data().context("fsync of buffer segment")?;
        }
        self.written += bytes;
        self.frames += 1;
        Ok(bytes)
    }

    fn rotate(&mut self) -> Result<()> {
        if !self.fsync {
            // Rotation is the durability boundary when per-frame fsync
            // is off: settle the sealed segment once.
            self.file.sync_data().context("fsync of sealed segment")?;
        }
        self.sealed.push_back(SealedSegment {
            index: self.index,
            end_frame: self.frames,
            bytes: self.written,
        });
        self.index += 1;
        let path = segment_path(&self.dir, self.index);
        self.file = File::create(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        self.written = 0;
        Ok(())
    }

    /// Flush the current segment to stable storage (clean shutdown when
    /// per-frame fsync is off).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data().context("fsync of buffer segment")
    }

    /// Delete sealed segments whose every frame is already consumed
    /// (pure-spill mode's cap reclaim). Returns the bytes freed. The
    /// paired reader never revisits a fully-consumed segment, and an
    /// open file handle survives the unlink, so this is safe while the
    /// drainer holds the file.
    pub(crate) fn reclaim(&mut self, consumed_frames: u64) -> Result<u64> {
        let mut freed = 0;
        while self.sealed.front().is_some_and(|s| s.end_frame <= consumed_frames) {
            let seg = self.sealed.pop_front().expect("checked front");
            std::fs::remove_file(segment_path(&self.dir, seg.index)).ok();
            freed += seg.bytes;
        }
        Ok(freed)
    }

    /// Whether any sealed segment could still be reclaimed by more
    /// consumption (if not, waiting for the drainer frees nothing).
    pub(crate) fn reclaimable(&self) -> bool {
        !self.sealed.is_empty()
    }
}

/// Read side of a buffer directory: pulls committed frames across the
/// segment chain, skipping CRC-corrupt frames (counted) and stopping at
/// the torn tail or journal end.
pub struct SegmentReader {
    dir: PathBuf,
    index: u64,
    file: Option<BufReader<File>>,
    payload: Vec<u8>,
}

impl SegmentReader {
    /// Open `dir` starting at its oldest segment (replay).
    pub fn open(dir: &Path) -> Result<SegmentReader> {
        let start = list_segments(dir)?.first().copied().unwrap_or(0);
        Ok(SegmentReader::open_at(dir, start))
    }

    /// Open `dir` starting at segment `index` (a [`SegmentWriter`]
    /// pairs its drainer with [`SegmentWriter::start_index`]). The
    /// segment file may not exist yet; it is opened lazily.
    pub fn open_at(dir: &Path, index: u64) -> SegmentReader {
        SegmentReader { dir: dir.to_path_buf(), index, file: None, payload: Vec::new() }
    }

    fn ensure_file(&mut self) -> Result<bool> {
        if self.file.is_some() {
            return Ok(true);
        }
        let path = segment_path(&self.dir, self.index);
        match File::open(&path) {
            Ok(f) => {
                self.file = Some(BufReader::new(f));
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e).with_context(|| format!("opening segment {}", path.display())),
        }
    }

    fn advance(&mut self) -> Result<bool> {
        let next = segment_path(&self.dir, self.index + 1);
        match File::open(&next) {
            Ok(f) => {
                self.index += 1;
                self.file = Some(BufReader::new(f));
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e).with_context(|| format!("opening segment {}", next.display())),
        }
    }

    /// Pull the next committed frame, appending its events to `out`.
    /// `Eof` here means the whole chain is exhausted (segment
    /// boundaries are crossed transparently).
    pub fn next_frame(&mut self, out: &mut Vec<Event>) -> Result<FrameRead> {
        loop {
            if !self.ensure_file()? {
                return Ok(FrameRead::Eof);
            }
            let r = self.file.as_mut().expect("ensured file");
            match read_frame(r, &mut self.payload, out)? {
                FrameRead::Eof => {
                    if !self.advance()? {
                        return Ok(FrameRead::Eof);
                    }
                }
                other => return Ok(other),
            }
        }
    }

    /// Advance past the next frame without decoding it, returning its
    /// record count — the drainer's cursor hop for batches it already
    /// delivered from memory. No CRC check: the payload was never read.
    pub fn skip_frame(&mut self) -> Result<FrameRead> {
        loop {
            if !self.ensure_file()? {
                return Ok(FrameRead::Eof);
            }
            let r = self.file.as_mut().expect("ensured file");
            let mut header = [0u8; FRAME_HEADER_BYTES];
            let got = read_full(r, &mut header)?;
            if got == 0 {
                if !self.advance()? {
                    return Ok(FrameRead::Eof);
                }
                continue;
            }
            if got < FRAME_HEADER_BYTES {
                return Ok(FrameRead::Torn);
            }
            let count = u32::from_le_bytes(header[0..4].try_into().unwrap());
            if count > MAX_FRAME_RECORDS {
                return Ok(FrameRead::Torn);
            }
            r.seek_relative(count as i64 * RECORD_BYTES as i64)?;
            return Ok(FrameRead::Frame(count as usize));
        }
    }
}

// --------------------------------------------------------- acked offset

/// Records delivered downstream from this buffer directory, as last
/// durably acknowledged. 0 when no ack has ever been written.
pub fn read_acked_offset(dir: &Path) -> u64 {
    match std::fs::read(dir.join(ACKED_FILE)) {
        Ok(bytes) if bytes.len() >= 8 => {
            u64::from_le_bytes(bytes[0..8].try_into().expect("checked length"))
        }
        _ => 0,
    }
}

/// Durably record that `records` records have been delivered
/// downstream (atomic tmp+rename, so a crash leaves either the old or
/// the new value, never a torn one).
pub fn write_acked_offset(dir: &Path, records: u64) -> Result<()> {
    let tmp = dir.join("acked.offset.tmp");
    std::fs::write(&tmp, records.to_le_bytes())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, dir.join(ACKED_FILE)).context("publishing acked offset")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("aestream-seg-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // The IEEE check value: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_and_record_identity() {
        let events = synthetic_events(257, 640, 480);
        let mut blob = Vec::new();
        encode_frame(&events, &mut blob);
        assert_eq!(blob.len(), FRAME_HEADER_BYTES + events.len() * RECORD_BYTES);
        let mut cursor = std::io::Cursor::new(&blob);
        let (mut payload, mut out) = (Vec::new(), Vec::new());
        match read_frame(&mut cursor, &mut payload, &mut out).unwrap() {
            FrameRead::Frame(n) => assert_eq!(n, events.len()),
            other => panic!("expected frame, got {other:?}"),
        }
        assert_eq!(out, events);
    }

    #[test]
    fn corrupt_payload_is_skipped_not_decoded() {
        let events = synthetic_events(100, 64, 64);
        let mut blob = Vec::new();
        encode_frame(&events, &mut blob);
        blob[FRAME_HEADER_BYTES + 5] ^= 0xFF; // flip a payload bit
        let mut cursor = std::io::Cursor::new(&blob);
        let (mut payload, mut out) = (Vec::new(), Vec::new());
        match read_frame(&mut cursor, &mut payload, &mut out).unwrap() {
            FrameRead::Corrupt(n) => assert_eq!(n, 100),
            other => panic!("expected corrupt, got {other:?}"),
        }
        assert!(out.is_empty());
    }

    #[test]
    fn writer_rotates_and_reader_crosses_segments() {
        let dir = tmp_dir("rotate");
        let events = synthetic_events(1000, 128, 128);
        {
            // Tiny target: every batch rotates into its own segment.
            let (mut w, rec) = SegmentWriter::open(&dir, 64, false).unwrap();
            assert_eq!(rec.committed_frames, 0);
            for batch in events.chunks(100) {
                w.append(batch).unwrap();
            }
            w.sync().unwrap();
        }
        let mut r = SegmentReader::open(&dir).unwrap();
        let mut out = Vec::new();
        loop {
            match r.next_frame(&mut out).unwrap() {
                FrameRead::Frame(_) => {}
                FrameRead::Eof => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(out, events);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_recovers_torn_tail_and_appends_cleanly() {
        let dir = tmp_dir("reopen");
        let events = synthetic_events(300, 64, 64);
        {
            let (mut w, _) = SegmentWriter::open(&dir, DEFAULT_SEGMENT_BYTES, false).unwrap();
            for batch in events.chunks(100) {
                w.append(batch).unwrap();
            }
            w.sync().unwrap();
        }
        // Tear the tail mid-payload.
        let seg = segment_path(&dir, 0);
        let len = std::fs::metadata(&seg).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
        f.set_len(len - 7).unwrap();
        drop(f);
        let (mut w, rec) = SegmentWriter::open(&dir, DEFAULT_SEGMENT_BYTES, false).unwrap();
        assert_eq!(rec.committed_frames, 2);
        assert_eq!(rec.committed_records, 200);
        assert_eq!(rec.truncated_bytes, (FRAME_HEADER_BYTES + 100 * RECORD_BYTES) as u64 - 7);
        assert_eq!(w.start_index(), 1);
        w.append(&events[200..]).unwrap();
        w.sync().unwrap();
        let mut r = SegmentReader::open(&dir).unwrap();
        let mut out = Vec::new();
        while let FrameRead::Frame(_) = r.next_frame(&mut out).unwrap() {}
        assert_eq!(out, events); // first 200 committed + 100 re-appended
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn acked_offset_roundtrip() {
        let dir = tmp_dir("acked");
        assert_eq!(read_acked_offset(&dir), 0);
        write_acked_offset(&dir, 12345).unwrap();
        assert_eq!(read_acked_offset(&dir), 12345);
        write_acked_offset(&dir, 99999).unwrap();
        assert_eq!(read_acked_offset(&dir), 99999);
        std::fs::remove_dir_all(&dir).ok();
    }
}
