//! [`EventSink`] implementations: file writers, UDP sender, stdout,
//! null, frame binning, and the terminal viewer.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::aer::{Event, Resolution};
use crate::formats::streaming::StreamingEncoder;
use crate::formats::Format;
use crate::net::UdpEventSender;
use crate::pipeline::framer::Framer;
use crate::pipeline::viewer;

use super::{EventChunk, EventSink};

/// Sink-side totals reported by [`EventSink::finish`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SinkSummary {
    /// Frames produced (frame-binning sinks; 0 elsewhere).
    pub frames: u64,
    /// Times a feeder found a [`ThreadedSink`]'s pump ring full and had
    /// to suspend (the wrapped sink is the bottleneck; 0 for inline
    /// sinks). Counted on the feeding side — the pump thread cannot see
    /// these — and folded into the sink's node report at finish.
    pub backpressure_waits: u64,
    /// Events the sink itself discarded (out-of-plane events at a
    /// device session, capacity overflows). Folded into the sink's
    /// [`NodeReport::dropped`](crate::metrics::NodeReport::dropped).
    pub dropped: u64,
}

/// Count-only sink (benchmarks, dry runs).
#[derive(Debug, Default)]
pub struct NullSink {
    /// Events consumed.
    pub events: u64,
}

impl EventSink for NullSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        self.events += batch.len() as u64;
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        "null".into()
    }
}

/// Sink that records every delivered event, in order, into a shared
/// buffer readable after the run — the byte-identity witness for the
/// graph-equivalence tests and the capture half of
/// `examples/graph_topology.rs`. Memory is O(stream): testing only,
/// never production topologies.
///
/// Batches are retained as refcounted [`EventChunk`]s on the hot path —
/// no lock and no copy per batch (a zero-copy broadcast delivery is a
/// refcount bump here too, so the sink cannot mask copy-path
/// regressions it exists to witness). The shared `Mutex` buffer is
/// only locked once, when the run flushes at [`finish`](EventSink::finish)
/// (or at drop, for error paths that skip finish).
pub struct CaptureSink {
    events: std::sync::Arc<std::sync::Mutex<Vec<Event>>>,
    chunks: Vec<EventChunk>,
}

impl CaptureSink {
    /// The sink plus the shared handle its events land in.
    #[allow(clippy::type_complexity)]
    pub fn new() -> (CaptureSink, std::sync::Arc<std::sync::Mutex<Vec<Event>>>) {
        let events = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        (CaptureSink { events: events.clone(), chunks: Vec::new() }, events)
    }

    /// Move everything captured so far into the shared buffer. Draining
    /// makes repeated flushes (finish then drop) naturally idempotent.
    fn flush(&mut self) {
        if self.chunks.is_empty() {
            return;
        }
        let mut out = self.events.lock().unwrap();
        for chunk in self.chunks.drain(..) {
            out.extend_from_slice(chunk.as_slice());
        }
    }
}

impl EventSink for CaptureSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        // Borrowed-slice entry point: a copy is unavoidable (and
        // counted, via `from_slice`). Chunk deliveries take the free
        // path below.
        self.chunks.push(EventChunk::from_slice(batch));
        Ok(())
    }

    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        self.chunks.push(chunk.clone()); // refcount bump only
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        self.flush();
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        "capture".into()
    }
}

impl Drop for CaptureSink {
    fn drop(&mut self) {
        self.flush(); // error paths skip finish(); don't lose the witness
    }
}

/// Incremental event-file writer in any [`Format`].
///
/// Two modes: when the source geometry is known up front
/// ([`create`](FileSink::create)), batches encode straight to the
/// target file. When it is not — live sources, where the header's
/// geometry would otherwise be stamped before the events defining it
/// arrive — [`create_observing`](FileSink::create_observing) spools
/// lossless fixed-width records to a temporary file and re-encodes at
/// `finish()` with the exact observed bounding box (O(chunk) memory,
/// one extra pass of disk I/O), matching the old batch path's geometry.
pub struct FileSink {
    path: PathBuf,
    mode: FileSinkMode,
}

enum FileSinkMode {
    Direct {
        writer: std::io::BufWriter<std::fs::File>,
        encoder: StreamingEncoder,
    },
    Spooled {
        format: Format,
        tmp_path: PathBuf,
        writer: std::io::BufWriter<std::fs::File>,
        observed: Resolution,
        scratch: Vec<u8>,
    },
}

/// Remove a stale `<path>.spool` left behind by a crashed observing
/// run targeting the same output file.
fn remove_orphan_spool(path: &Path) {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".spool");
    std::fs::remove_file(PathBuf::from(tmp).as_path()).ok();
}

impl FileSink {
    /// Create/truncate `path`, writing a stream for geometry `res`.
    pub fn create(path: &Path, format: Format, res: Resolution) -> Result<Self> {
        remove_orphan_spool(path);
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(FileSink {
            path: path.to_path_buf(),
            mode: FileSinkMode::Direct {
                writer: std::io::BufWriter::new(file),
                encoder: StreamingEncoder::new(format, res)?,
            },
        })
    }

    /// Create/truncate `path` for a source whose geometry is only
    /// learned by observation: the header is written at `finish()` with
    /// the exact bounding box of everything consumed.
    pub fn create_observing(path: &Path, format: Format) -> Result<Self> {
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".spool");
        let tmp_path = PathBuf::from(tmp);
        let file = std::fs::File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        Ok(FileSink {
            path: path.to_path_buf(),
            mode: FileSinkMode::Spooled {
                format,
                writer: std::io::BufWriter::new(file),
                observed: Resolution::new(1, 1),
                tmp_path,
                scratch: Vec::new(),
            },
        })
    }

    fn format(&self) -> Format {
        match &self.mode {
            FileSinkMode::Direct { encoder, .. } => encoder.format(),
            FileSinkMode::Spooled { format, .. } => *format,
        }
    }
}

impl Drop for FileSink {
    fn drop(&mut self) {
        // Error paths skip finish(); don't leave a (possibly large)
        // spool file behind. After a successful finish this is a no-op.
        if let FileSinkMode::Spooled { tmp_path, .. } = &self.mode {
            std::fs::remove_file(tmp_path.as_path()).ok();
        }
    }
}

impl EventSink for FileSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        match &mut self.mode {
            FileSinkMode::Direct { writer, encoder } => encoder
                .write_batch(batch, writer)
                .with_context(|| format!("writing {}", self.path.display())),
            FileSinkMode::Spooled { writer, observed, scratch, .. } => {
                super::sources::grow_resolution(observed, batch);
                super::buffer::segment::write_frame(writer, batch, scratch)
                    .map(|_| ())
                    .with_context(|| format!("spooling for {}", self.path.display()))
            }
        }
    }

    fn observe_geometry(&mut self, res: Resolution) {
        // Cover the full source geometry, not just the events that
        // survived the pipeline into this file (batch-path parity).
        if let FileSinkMode::Spooled { observed, .. } = &mut self.mode {
            observed.width = observed.width.max(res.width);
            observed.height = observed.height.max(res.height);
        }
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        match &mut self.mode {
            FileSinkMode::Direct { writer, encoder } => {
                encoder.finish(writer)?;
                writer
                    .flush()
                    .with_context(|| format!("flushing {}", self.path.display()))?;
            }
            FileSinkMode::Spooled { format, tmp_path, writer, observed, .. } => {
                writer
                    .flush()
                    .with_context(|| format!("flushing {}", tmp_path.display()))?;
                // Second pass: re-encode the spool with the now-exact
                // geometry, still one frame at a time. The spool lives
                // entirely within this process, so a torn or corrupt
                // frame here is a real disk error, not a crash to
                // recover from — bail instead of truncating.
                use super::buffer::segment::{read_frame, FrameRead};
                let mut spool = std::io::BufReader::new(
                    std::fs::File::open(&tmp_path)
                        .with_context(|| format!("reopening {}", tmp_path.display()))?,
                );
                let file = std::fs::File::create(&self.path)
                    .with_context(|| format!("creating {}", self.path.display()))?;
                let mut out = std::io::BufWriter::new(file);
                let mut enc = StreamingEncoder::new(*format, *observed)?;
                let mut payload = Vec::new();
                let mut batch = Vec::new();
                loop {
                    batch.clear();
                    match read_frame(&mut spool, &mut payload, &mut batch)
                        .with_context(|| format!("reading {}", tmp_path.display()))?
                    {
                        FrameRead::Frame(_) => enc.write_batch(&batch, &mut out)?,
                        FrameRead::Eof => break,
                        FrameRead::Torn => {
                            anyhow::bail!(
                                "spool {} ends mid-frame: disk error or external \
                                 truncation",
                                tmp_path.display()
                            );
                        }
                        FrameRead::Corrupt(lost) => {
                            anyhow::bail!(
                                "spool {} has a corrupt frame ({lost} records): \
                                 disk error or external modification",
                                tmp_path.display()
                            );
                        }
                    }
                }
                enc.finish(&mut out)?;
                out.flush().with_context(|| format!("flushing {}", self.path.display()))?;
                std::fs::remove_file(tmp_path.as_path()).ok();
            }
        }
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        format!("file({}, {})", self.path.display(), self.format())
    }
}

/// SPIF datagrams to a UDP peer.
pub struct UdpSink {
    tx: UdpEventSender,
}

impl UdpSink {
    /// Aim at `addr` (e.g. `"10.0.0.1:3333"`).
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(UdpSink { tx: UdpEventSender::connect(addr)? })
    }

    /// Events sent so far.
    pub fn events_sent(&self) -> u64 {
        self.tx.events_sent
    }
}

impl EventSink for UdpSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        self.tx.send(batch)
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        "udp".into()
    }
}

/// `x,y,p,t` lines to standard output (shell pipelines, Fig. 2B).
pub struct StdoutSink {
    out: std::io::BufWriter<std::io::Stdout>,
}

impl Default for StdoutSink {
    fn default() -> Self {
        StdoutSink { out: std::io::BufWriter::new(std::io::stdout()) }
    }
}

impl StdoutSink {
    /// New stdout sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EventSink for StdoutSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        for ev in batch {
            writeln!(self.out, "{},{},{},{}", ev.x, ev.y, u8::from(ev.p.is_on()), ev.t)?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        self.out.flush()?;
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        "stdout".into()
    }
}

/// Bin events into fixed windows and count frames (the "GPU direction"
/// without a device; the full device path lives in
/// [`crate::coordinator::scenarios`]).
pub struct FrameSink {
    framer: Framer,
    window_us: u64,
    frames: u64,
    /// Events skipped because their coordinates are unrepresentable as
    /// a geometry (x or y == `u16::MAX`).
    pub oob_dropped: u64,
}

impl FrameSink {
    /// Bin into `window_us` windows for geometry `res`.
    pub fn new(res: Resolution, window_us: u64) -> Self {
        FrameSink { framer: Framer::new(res, window_us), window_us, frames: 0, oob_dropped: 0 }
    }

    /// Frames completed so far.
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Grow the binning geometry when a source only learns its extent
    /// by observation (UDP, headerless files). The in-progress frame is
    /// carried over ([`Framer::rebind`]), so windows and counts stay
    /// exactly what a whole-stream binning would produce.
    fn ensure_geometry(&mut self, batch: &[Event]) {
        if let Some(need) = grown_geometry(self.framer.resolution(), batch) {
            self.framer.rebind(need);
        }
    }
}

/// `Some(grown)` iff `batch` contains events outside `current` (shared
/// by the frame-binning sinks; see also
/// [`super::sources::grow_resolution`] for the source-side tracker).
fn grown_geometry(current: Resolution, batch: &[Event]) -> Option<Resolution> {
    let mut need = current;
    super::sources::grow_resolution(&mut need, batch);
    (need != current).then_some(need)
}

impl EventSink for FrameSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        self.ensure_geometry(batch);
        let res = self.framer.resolution();
        for ev in batch {
            // Only unrepresentable coordinates (x or y == u16::MAX,
            // where width/height would need 65536) fall outside after
            // growth; count them instead of indexing out of bounds.
            if !res.contains(ev) {
                self.oob_dropped += 1;
                continue;
            }
            self.frames += self.framer.push(ev).len() as u64;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        self.frames += u64::from(self.framer.finish().is_some());
        Ok(SinkSummary { frames: self.frames, ..Default::default() })
    }

    fn describe(&self) -> String {
        format!("frames({} µs)", self.window_us)
    }
}

/// Terminal density-art viewer: renders the first `max_frames`
/// completed windows as they stream (the batch path rendered evenly
/// spaced frames; a live stream has no total to space against).
pub struct ViewSink {
    framer: Framer,
    window_us: u64,
    max_frames: usize,
    rendered: usize,
    frames: u64,
}

impl ViewSink {
    /// Render up to `max_frames` windows of `window_us` each.
    pub fn new(res: Resolution, window_us: u64, max_frames: usize) -> Self {
        ViewSink {
            framer: Framer::new(res, window_us),
            window_us,
            max_frames,
            rendered: 0,
            frames: 0,
        }
    }

    fn show(&mut self, frame: &crate::pipeline::framer::Frame) {
        if self.rendered >= self.max_frames {
            return;
        }
        self.rendered += 1;
        println!(
            "── window [{} µs, {} µs) — {} events ──",
            frame.t_start, frame.t_end, frame.event_count
        );
        print!("{}", viewer::render_frame(frame, 69, 26));
    }
}

impl EventSink for ViewSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        // Same growth rule as FrameSink: live sources only learn their
        // geometry by observation; the in-progress window is carried.
        if let Some(need) = grown_geometry(self.framer.resolution(), batch) {
            self.framer.rebind(need);
        }
        let res = self.framer.resolution();
        for ev in batch {
            if !res.contains(ev) {
                continue; // unrepresentable coordinate (u16::MAX)
            }
            for frame in self.framer.push(ev) {
                self.frames += 1;
                self.show(&frame);
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        if let Some(frame) = self.framer.finish() {
            self.frames += 1;
            self.show(&frame);
        }
        Ok(SinkSummary { frames: self.frames, ..Default::default() })
    }

    fn describe(&self) -> String {
        format!("view({} µs, ≤{} frames)", self.window_us, self.max_frames)
    }
}

// ------------------------------------------------------------ threaded

/// What flows through a sink pump's ring: batches plus the one
/// out-of-band geometry notification the driver sends before finish.
/// Batches cross the thread boundary as refcounted chunks, so handing
/// one to the pump is a pointer move, not a copy.
enum SinkMsg {
    Batch(EventChunk),
    Geometry(Resolution),
}

/// Batches buffered in a sink pump's ring (mirrors the source pumps'
/// `PUMP_QUEUE_BATCHES`): enough to decouple the router from a
/// momentarily slow sink, small enough to keep memory O(chunk).
const SINK_QUEUE_BATCHES: usize = 2;

/// A sink pinned behind its own OS thread (`--sink-threads`), the
/// fan-out mirror of [`ThreadMode::PerSourceThread`](super::ThreadMode):
/// the wrapped sink's blocking I/O (file writes, UDP sends) runs on the
/// pump thread, and the router only ever touches the bounded
/// [`crate::rt::sync_channel`] ring. A slow sink therefore
/// backpressures through its queue — counted in
/// [`SinkSummary::backpressure_waits`] — instead of stalling the
/// fan-out router (and transitively every sibling sink) inline.
pub struct ThreadedSink {
    /// `None` once finished (the close signal is dropping the sender).
    tx: Option<crate::rt::SyncSender<SinkMsg>>,
    /// The pump's final word: the inner sink's summary or its error.
    done: crate::rt::SyncReceiver<Result<SinkSummary>>,
    handle: Option<std::thread::JoinHandle<()>>,
    name: String,
    /// Full-ring suspensions of the router side (our half of the
    /// backpressure ledger; the pump cannot see them).
    waits: u64,
}

impl ThreadedSink {
    /// Move `sink` onto its own pump thread. The wrapper is itself an
    /// [`EventSink`], so it slots into any topology unchanged.
    pub fn spawn(mut sink: Box<dyn EventSink>) -> ThreadedSink {
        use crate::rt::{block_on, sync_channel};
        let name = sink.describe();
        // OS thread name: `sink:<describe>`, clipped to the 15-byte
        // Linux limit at a char boundary (longer names silently fail).
        let mut thread_name = format!("sink:{name}");
        let mut end = thread_name.len().min(15);
        while !thread_name.is_char_boundary(end) {
            end -= 1;
        }
        thread_name.truncate(end);
        let (tx, mut rx) = sync_channel::<SinkMsg>(SINK_QUEUE_BATCHES);
        let (mut done_tx, done) = sync_channel::<Result<SinkSummary>>(1);
        let builder = std::thread::Builder::new().name(thread_name);
        let handle = builder.spawn(move || {
            let result = (|| -> Result<SinkSummary> {
                while let Some(msg) = block_on(rx.recv()) {
                    match msg {
                        SinkMsg::Batch(batch) => sink.consume_chunk(&batch)?,
                        SinkMsg::Geometry(res) => sink.observe_geometry(res),
                    }
                }
                sink.finish()
            })();
            // The router learns of a sink error at its next send (ring
            // closed); the error itself surfaces from `finish`.
            let _ = block_on(done_tx.send(result));
        });
        let handle = handle.expect("spawn sink pump thread");
        ThreadedSink { tx: Some(tx), done, handle: Some(handle), name, waits: 0 }
    }

    /// Drain the pump: close the ring, collect the inner sink's result,
    /// join the thread. Idempotent via `tx`/`handle` being `Option`s.
    fn join(&mut self) -> Result<SinkSummary> {
        use crate::rt::block_on;
        drop(self.tx.take()); // close: the pump finishes its sink and exits
        let result = block_on(self.done.recv());
        if let Some(handle) = self.handle.take() {
            if handle.join().is_err() {
                anyhow::bail!("sink pump for {:?} panicked", self.name);
            }
        }
        let mut summary = result
            .with_context(|| format!("sink pump for {:?} vanished", self.name))??;
        summary.backpressure_waits += self.waits;
        Ok(summary)
    }
}

impl ThreadedSink {
    /// Push one message into the pump ring, suspending on a full ring
    /// and surfacing a dead pump's error immediately.
    fn send_to_pump(&mut self, msg: SinkMsg) -> Result<()> {
        let Some(tx) = self.tx.as_mut() else {
            anyhow::bail!("sink {:?} already finished", self.name);
        };
        match tx.try_send(msg) {
            Ok(()) => Ok(()),
            Err(msg) => {
                // Ring full (backpressure) or pump gone: the blocking
                // send distinguishes them.
                self.waits += 1;
                if crate::rt::block_on(tx.send(msg)).is_ok() {
                    return Ok(());
                }
                // Pump exited early — only happens on a sink error:
                // surface it now rather than at finish.
                match self.join() {
                    Ok(_) => anyhow::bail!("sink pump for {:?} exited early", self.name),
                    Err(e) => Err(e),
                }
            }
        }
    }
}

impl EventSink for ThreadedSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        // Borrowed-slice entry point: the copy is unavoidable (counted).
        self.send_to_pump(SinkMsg::Batch(EventChunk::from_slice(batch)))
    }

    fn consume_chunk(&mut self, chunk: &EventChunk) -> Result<()> {
        self.send_to_pump(SinkMsg::Batch(chunk.clone())) // refcount bump
    }

    fn observe_geometry(&mut self, res: Resolution) {
        if let Some(tx) = self.tx.as_mut() {
            // Best-effort: a dead pump's error surfaces at finish.
            if tx.try_send(SinkMsg::Geometry(res)).is_err() {
                let _ = crate::rt::block_on(tx.send(SinkMsg::Geometry(res)));
            }
        }
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        self.join()
    }

    fn describe(&self) -> String {
        format!("thread({})", self.name)
    }
}

impl Drop for ThreadedSink {
    fn drop(&mut self) {
        // Error paths skip finish(): close the ring and join so the
        // pump never outlives the topology (best effort).
        drop(self.tx.take());
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn null_sink_counts() {
        let events = synthetic_events(123, 16, 16);
        let mut sink = NullSink::default();
        sink.consume(&events).unwrap();
        sink.consume(&events).unwrap();
        assert_eq!(sink.events, 246);
        assert_eq!(sink.finish().unwrap().frames, 0);
    }

    #[test]
    fn frame_sink_matches_batch_framer() {
        let events = synthetic_events(5000, 32, 32);
        let expected =
            Framer::frames_of(Resolution::new(32, 32), 700, &events).len() as u64;
        let mut sink = FrameSink::new(Resolution::new(32, 32), 700);
        for batch in events.chunks(137) {
            sink.consume(batch).unwrap();
        }
        assert_eq!(sink.finish().unwrap().frames, expected);
    }

    #[test]
    fn frame_sink_grows_geometry_instead_of_panicking() {
        let mut sink = FrameSink::new(Resolution::new(4, 4), 1000);
        sink.consume(&[Event::on(2, 2, 10)]).unwrap();
        // Outside the initial 4×4 geometry: must bin, not panic — and
        // both events share one window, so exactly one frame results.
        sink.consume(&[Event::on(100, 80, 20)]).unwrap();
        assert_eq!(sink.finish().unwrap().frames, 1);
    }

    #[test]
    fn view_sink_grows_geometry_instead_of_panicking() {
        // A live (UDP-like) source starts at the 1×1 placeholder
        // geometry; the viewer must grow, not index out of bounds.
        let mut sink = ViewSink::new(Resolution::new(1, 1), 1000, 0);
        sink.consume(&[Event::on(0, 0, 10)]).unwrap();
        sink.consume(&[Event::on(120, 90, 20)]).unwrap();
        assert_eq!(sink.finish().unwrap().frames, 1);
    }

    #[test]
    fn observing_file_sink_stamps_exact_bounding_geometry() {
        // The UDP→file path: geometry unknown at creation, learned by
        // observation, header must record the exact bounding box.
        let dir = std::env::temp_dir()
            .join(format!("aestream-spool-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("observed.aedat");
        let events = synthetic_events(700, 346, 260);
        let expected_res = crate::formats::bounding_resolution(&events);
        let mut sink = FileSink::create_observing(&path, Format::Aedat).unwrap();
        for batch in events.chunks(100) {
            sink.consume(batch).unwrap();
        }
        sink.finish().unwrap();
        let (decoded, res, fmt) = crate::formats::read_events_auto(&path).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, expected_res);
        assert_eq!(fmt, Format::Aedat);
        // The spool file is cleaned up.
        assert!(!dir.join("observed.aedat.spool").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A slow sink behind its own pump thread: every event arrives, the
    /// summary flows back, and the router-side waits surface in it.
    #[test]
    fn threaded_sink_delivers_everything_and_counts_waits() {
        struct Slow {
            events: u64,
            geometry: Option<Resolution>,
        }
        impl EventSink for Slow {
            fn consume(&mut self, batch: &[Event]) -> Result<()> {
                std::thread::sleep(std::time::Duration::from_micros(200));
                self.events += batch.len() as u64;
                Ok(())
            }
            fn observe_geometry(&mut self, res: Resolution) {
                self.geometry = Some(res);
            }
            fn finish(&mut self) -> Result<SinkSummary> {
                assert_eq!(self.geometry, Some(Resolution::new(32, 32)));
                Ok(SinkSummary { frames: self.events, ..Default::default() })
            }
            fn describe(&self) -> String {
                "slow".into()
            }
        }
        let mut sink =
            ThreadedSink::spawn(Box::new(Slow { events: 0, geometry: None }));
        assert_eq!(sink.describe(), "thread(slow)");
        let events = synthetic_events(50, 32, 32);
        for batch in events.chunks(5) {
            sink.consume(batch).unwrap(); // outruns the 200 µs sink: ring fills
        }
        sink.observe_geometry(Resolution::new(32, 32));
        let summary = sink.finish().unwrap();
        // Smuggled the count through `frames`: all 50 events arrived,
        // in order, after the geometry notification.
        assert_eq!(summary.frames, 50);
        assert!(summary.backpressure_waits > 0, "a 200µs/batch sink must backpressure");
        assert!(sink.consume(&events).is_err(), "finished sink fails loudly");
    }

    #[test]
    fn threaded_sink_surfaces_inner_errors() {
        struct Failing(u32);
        impl EventSink for Failing {
            fn consume(&mut self, _batch: &[Event]) -> Result<()> {
                self.0 += 1;
                if self.0 >= 2 {
                    anyhow::bail!("disk full");
                }
                Ok(())
            }
            fn finish(&mut self) -> Result<SinkSummary> {
                Ok(SinkSummary::default())
            }
        }
        let mut sink = ThreadedSink::spawn(Box::new(Failing(0)));
        let events = synthetic_events(10, 8, 8);
        // The pump fails on its second batch; the error must reach the
        // caller on a subsequent consume or at finish (never silently).
        let mut failed = false;
        for batch in events.chunks(2) {
            if sink.consume(batch).is_err() {
                failed = true;
                break;
            }
        }
        if !failed {
            assert!(sink.finish().is_err(), "the sink error must surface somewhere");
        }
    }

    #[test]
    fn file_sink_roundtrips_through_batch_reader() {
        let dir = std::env::temp_dir()
            .join(format!("aestream-sink-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.aeraw");
        let events = synthetic_events(900, 128, 128);
        let mut sink = FileSink::create(&path, Format::Raw, Resolution::DVS_128).unwrap();
        for batch in events.chunks(250) {
            sink.consume(batch).unwrap();
        }
        sink.finish().unwrap();
        let (decoded, res, fmt) = crate::formats::read_events_auto(&path).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::DVS_128);
        assert_eq!(fmt, Format::Raw);
        std::fs::remove_dir_all(&dir).ok();
    }
}
