//! [`EventSource`] implementations: memory slices, chunked file
//! decoders, UDP receivers, and the synthetic camera.

use std::collections::VecDeque;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::aer::{Event, Resolution};
use crate::camera::{CameraConfig, SyntheticCamera};
use crate::formats::streaming::StreamingDecoder;
use crate::formats::{detect_format, Format};
use crate::net::UdpEventReceiver;

use super::codec_plane::{CodecPlane, DecodeStream, MAX_BACKLOG};
use super::pool::ChunkPool;
use super::EventSource;

/// Grow `res` to cover every event of `batch` — the incremental form of
/// [`crate::formats`]'s bounding-box fallback, shared with the
/// frame-binning sinks.
pub(super) fn grow_resolution(res: &mut Resolution, batch: &[Event]) {
    for ev in batch {
        // Saturating: a coordinate of u16::MAX is not representable as
        // a width/height (it would need 65536); geometry-bounded sinks
        // skip such events rather than index out of bounds.
        res.width = res.width.max(ev.x.saturating_add(1));
        res.height = res.height.max(ev.y.saturating_add(1));
    }
}

/// In-memory events served in fixed chunks (tests, benches, replays).
pub struct MemorySource {
    events: Vec<Event>,
    pos: usize,
    chunk: usize,
    res: Resolution,
    /// Recycled batch buffers, adopted from the driving topology.
    pool: Option<Arc<ChunkPool>>,
}

impl MemorySource {
    /// Serve `events` in batches of at most `chunk`.
    pub fn new(events: Vec<Event>, res: Resolution, chunk: usize) -> Self {
        MemorySource { events, pos: 0, chunk: chunk.max(1), res, pool: None }
    }
}

impl EventSource for MemorySource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if self.pos >= self.events.len() {
            return Ok(None);
        }
        let end = (self.pos + self.chunk).min(self.events.len());
        let mut batch = match &self.pool {
            Some(pool) => pool.get(end - self.pos),
            None => Vec::with_capacity(end - self.pos),
        };
        batch.extend_from_slice(&self.events[self.pos..end]);
        self.pos = end;
        Ok(Some(batch))
    }

    fn resolution(&self) -> Resolution {
        self.res
    }

    fn set_chunk_hint(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
    }

    fn set_buffer_pool(&mut self, pool: Arc<ChunkPool>) {
        self.pool = Some(pool);
    }

    fn describe(&self) -> String {
        format!("memory({} events)", self.events.len())
    }
}

/// Borrowed-slice source: chunks a recording without copying it (the
/// Fig. 4 scenario replays and benches stream RAM-cached recordings).
pub struct SliceSource<'a> {
    events: &'a [Event],
    pos: usize,
    chunk: usize,
    /// Bounding box, computed lazily on first request: scenario replays
    /// never ask for it, so they skip the O(n) scan.
    res: std::cell::Cell<Option<Resolution>>,
}

impl<'a> SliceSource<'a> {
    /// Serve `events` in batches of at most `chunk`; geometry is the
    /// recording's bounding box (computed on demand).
    pub fn new(events: &'a [Event], chunk: usize) -> Self {
        SliceSource { events, pos: 0, chunk: chunk.max(1), res: std::cell::Cell::new(None) }
    }
}

impl EventSource for SliceSource<'_> {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if self.pos >= self.events.len() {
            return Ok(None);
        }
        let end = (self.pos + self.chunk).min(self.events.len());
        let batch = self.events[self.pos..end].to_vec();
        self.pos = end;
        Ok(Some(batch))
    }

    fn resolution(&self) -> Resolution {
        match self.res.get() {
            Some(res) => res,
            None => {
                let res = crate::formats::bounding_resolution(self.events);
                self.res.set(Some(res));
                res
            }
        }
    }

    fn set_chunk_hint(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
    }

    fn describe(&self) -> String {
        format!("slice({} events)", self.events.len())
    }
}

/// Chunked file reader: bytes stream through the incremental
/// per-format decoder, so memory stays O(read buffer + chunk) no matter
/// the file size — the batch `read_events_auto` path materializes the
/// whole recording instead.
pub struct FileSource {
    path: PathBuf,
    reader: std::io::BufReader<std::fs::File>,
    decoder: StreamingDecoder,
    /// Decoded events not yet handed out (decoding a read buffer can
    /// yield more than one chunk's worth).
    ready: VecDeque<Event>,
    chunk: usize,
    read_buf: Vec<u8>,
    /// Scratch for one fill's worth of decoded events, drained into
    /// `ready` — reused so steady-state fills allocate nothing.
    scratch: Vec<Event>,
    /// Decode stream on the shared codec plane, when one is attached:
    /// reads submit bytes here instead of feeding `decoder` inline.
    pstream: Option<DecodeStream>,
    /// A batch has been handed out; a late plane attach can no longer
    /// restart the stream and is ignored.
    consumed: bool,
    eof: bool,
    /// Bounding-box fallback for formats without recorded geometry.
    observed_res: Resolution,
    /// Operator-declared geometry (headerless recordings joining fused
    /// topologies). Authoritative when set: out-of-claim events are
    /// dropped and counted, exactly like [`UdpSource::with_geometry`].
    claimed: Option<Resolution>,
    /// Events dropped for falling outside the claimed geometry.
    out_of_claim: u64,
    /// Recycled batch buffers, adopted from the driving topology.
    pool: Option<Arc<ChunkPool>>,
}

impl FileSource {
    /// Bytes per read syscall.
    const READ_SIZE: usize = 64 * 1024;

    /// Bytes per read syscall when a codec plane is attached: larger
    /// reads fan out across several ~64 KiB decode pieces, so one
    /// syscall keeps multiple workers busy.
    const PLANE_READ_SIZE: usize = 256 * 1024;

    /// Open a file, sniffing the format from leading bytes first and
    /// the extension second (same policy as `read_events_auto`).
    pub fn open(path: &Path, chunk: usize) -> Result<Self> {
        use std::io::BufRead;

        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut reader = std::io::BufReader::with_capacity(Self::READ_SIZE, file);
        let probe = reader.fill_buf().context("probing format")?;
        let sniffed = detect_format(&probe[..probe.len().min(64)]);
        let by_ext =
            path.extension().and_then(|e| e.to_str()).and_then(Format::from_extension);
        let format = match sniffed.or(by_ext) {
            Some(f) => f,
            None => bail!("cannot determine event format of {}", path.display()),
        };
        let mut source = FileSource {
            path: path.to_path_buf(),
            reader,
            decoder: StreamingDecoder::new(format),
            ready: VecDeque::new(),
            chunk: chunk.max(1),
            read_buf: vec![0u8; Self::READ_SIZE],
            scratch: Vec::new(),
            pstream: None,
            consumed: false,
            eof: false,
            observed_res: Resolution::new(1, 1),
            claimed: None,
            out_of_claim: 0,
            pool: None,
        };
        source.prime()?;
        Ok(source)
    }

    /// Declare the recording's geometry up front. Headerless formats
    /// (`.txt`, spooled raw captures) only learn their extent by
    /// observation, which bars them from fused topologies (canvas
    /// offsets need real sizes before the first batch); a declared
    /// geometry makes them exact. The claim is authoritative: events
    /// outside it are dropped and counted ([`EventSource::dropped`]),
    /// the same contract as [`UdpSource::with_geometry`]. A recorded
    /// header, when present, still wins over the claim.
    pub fn with_geometry(mut self, res: Resolution) -> Self {
        self.claimed = Some(res);
        // Claims don't rewind: anything primed before the declaration
        // is filtered on the way out in next_batch.
        self
    }

    /// The detected format.
    pub fn format(&self) -> Format {
        self.decoder.format()
    }

    /// Geometry from the recorded header, whichever side decoded it.
    fn header_res(&self) -> Option<Resolution> {
        match &self.pstream {
            Some(stream) => stream.resolution(),
            None => self.decoder.resolution(),
        }
    }

    /// Read ahead until the header yields the recorded geometry (or the
    /// body starts / EOF for headerless streams), so geometry-consuming
    /// sinks can be built before the first batch. Bounded: stops as
    /// soon as any event decodes.
    fn prime(&mut self) -> Result<()> {
        while self.header_res().is_none() && self.ready.is_empty() && !self.eof {
            self.fill_once()?;
        }
        Ok(())
    }

    /// One read syscall's worth of progress: pull bytes, run them
    /// through the decoder (or finish it at EOF), queue the events.
    /// Decoding happens inline, or on the codec plane when one is
    /// attached — in which case this thread only reads, submits, and
    /// collects whatever has finished (blocking only when the decode
    /// backlog hits its bound).
    fn fill_once(&mut self) -> Result<()> {
        let n = self
            .reader
            .read(&mut self.read_buf)
            .with_context(|| format!("reading {}", self.path.display()))?;
        self.scratch.clear();
        let path = &self.path;
        let ctx = || format!("decoding {}", path.display());
        if let Some(stream) = self.pstream.as_mut() {
            if n == 0 {
                self.eof = true;
                stream.finish().with_context(ctx)?;
                while !stream.done() {
                    stream.poll_wait(&mut self.scratch).with_context(ctx)?;
                }
            } else {
                stream.submit(&self.read_buf[..n]).with_context(ctx)?;
                if stream.backlog() > MAX_BACKLOG {
                    stream.poll_wait(&mut self.scratch).with_context(ctx)?;
                } else {
                    stream.poll(&mut self.scratch).with_context(ctx)?;
                }
            }
        } else if n == 0 {
            self.eof = true;
            self.decoder.finish(&mut self.scratch).with_context(ctx)?;
        } else {
            self.decoder.feed(&self.read_buf[..n], &mut self.scratch).with_context(ctx)?;
        }
        grow_resolution(&mut self.observed_res, &self.scratch);
        self.ready.extend(self.scratch.drain(..));
        Ok(())
    }
}

impl EventSource for FileSource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        // Loop past fully-filtered chunks: a file always makes
        // progress, and returning an empty batch would read as "live
        // source idle" upstream, costing escalating driver sleeps (and
        // stalling sibling merge lanes) per filtered chunk.
        loop {
            while self.ready.len() < self.chunk && !self.eof {
                self.fill_once()?;
            }
            if self.ready.is_empty() {
                return Ok(None);
            }
            let take = self.chunk.min(self.ready.len());
            let mut batch = match &self.pool {
                Some(pool) => pool.get(take),
                None => Vec::with_capacity(take),
            };
            batch.extend(self.ready.drain(..take));
            self.consumed = true;
            if self.header_res().is_none() {
                if let Some(claim) = self.claimed {
                    // The declared geometry is authoritative for
                    // headerless recordings (layouts were cut from
                    // it): out-of-claim events are dropped and
                    // counted, never smuggled onto a fused canvas.
                    let before = batch.len();
                    batch.retain(|ev| claim.contains(ev));
                    self.out_of_claim += (before - batch.len()) as u64;
                }
            }
            if !batch.is_empty() {
                return Ok(Some(batch));
            }
        }
    }

    fn resolution(&self) -> Resolution {
        // Recorded header first, operator claim second, observation last.
        self.header_res().or(self.claimed).unwrap_or(self.observed_res)
    }

    fn geometry_known(&self) -> bool {
        // Exact iff the header recorded it or the operator declared it;
        // otherwise only the events seen so far bound it.
        self.header_res().is_some() || self.claimed.is_some()
    }

    fn dropped(&self) -> u64 {
        self.out_of_claim
    }

    fn set_chunk_hint(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
    }

    fn set_buffer_pool(&mut self, pool: Arc<ChunkPool>) {
        self.pool = Some(pool);
    }

    fn set_codec_plane(&mut self, plane: Arc<CodecPlane>) {
        use std::io::{Seek, SeekFrom};

        // Attach happens at topology setup, before any batch is handed
        // out; the stream restarts from byte 0 through the plane so the
        // header and the bytes primed inline aren't decoded twice. A
        // late attach (or an unseekable input) keeps inline decode.
        if self.consumed || self.reader.seek(SeekFrom::Start(0)).is_err() {
            return;
        }
        let format = self.format();
        self.decoder = StreamingDecoder::new(format);
        self.ready.clear();
        self.eof = false;
        self.read_buf.resize(Self::PLANE_READ_SIZE, 0);
        self.pstream = Some(plane.open_stream(format));
        // Re-prime so geometry-consuming callers still see the header;
        // a decode error here re-surfaces on the first next_batch.
        let _ = self.prime();
    }

    fn describe(&self) -> String {
        format!("file({}, {})", self.path.display(), self.format())
    }
}

/// Live SPIF/UDP receiver with a bounded idle shutdown.
///
/// Each poll blocks at most the socket's poll timeout (sized well below
/// `idle_timeout`), so "no data yet" costs a cheap bounded wait instead
/// of a hot spin, and the source ends once `idle_timeout` passes with
/// no datagrams.
pub struct UdpSource {
    rx: UdpEventReceiver,
    idle_timeout: Duration,
    last_data: Instant,
    observed_res: Resolution,
    /// `true` when the operator declared the sensor geometry up front
    /// (`--geometry`), making it exact instead of merely observed.
    claimed: bool,
    /// Events dropped for falling outside a claimed geometry.
    out_of_claim: u64,
}

impl UdpSource {
    /// Bind to `addr` and stream until `idle_timeout` passes quietly.
    pub fn bind(addr: &str, idle_timeout: Duration) -> Result<Self> {
        let mut rx =
            UdpEventReceiver::bind(addr).with_context(|| format!("binding {addr}"))?;
        // Poll in slices of the idle budget: waits stay responsive for
        // short timeouts and cheap (few wakeups) for long ones.
        let poll = (idle_timeout / 8).clamp(Duration::from_millis(1), Duration::from_millis(50));
        rx.set_poll_timeout(poll)?;
        Ok(UdpSource {
            rx,
            idle_timeout,
            last_data: Instant::now(),
            observed_res: Resolution::new(1, 1),
            claimed: false,
            out_of_claim: 0,
        })
    }

    /// Wrap an already-bound receiver (tests use port 0).
    pub fn from_receiver(rx: UdpEventReceiver, idle_timeout: Duration) -> Self {
        UdpSource {
            rx,
            idle_timeout,
            last_data: Instant::now(),
            observed_res: Resolution::new(1, 1),
            claimed: false,
            out_of_claim: 0,
        }
    }

    /// Declare the sensor geometry up front (SPIF deployments configure
    /// it per sensor). The source then reports
    /// [`geometry_known`](EventSource::geometry_known), which lets it
    /// join fused topologies (layout offsets need real extents) and
    /// lets file sinks skip the observe-and-respool pass. The claim is
    /// authoritative: events outside it are dropped and counted (same
    /// contract as a fused layout placement), so headers written from
    /// the claim stay exact.
    pub fn with_geometry(mut self, res: Resolution) -> Self {
        self.observed_res = res;
        self.claimed = true;
        self
    }

    /// Events received so far.
    pub fn events_received(&self) -> u64 {
        self.rx.events_received
    }

    /// Events dropped for falling outside a claimed geometry.
    pub fn out_of_claim(&self) -> u64 {
        self.out_of_claim
    }
}

impl EventSource for UdpSource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        match self.rx.recv_batch()? {
            Some(mut batch) => {
                self.last_data = Instant::now();
                if self.claimed {
                    // The claim is authoritative (headers/layouts were
                    // cut from it): out-of-claim events are dropped and
                    // counted, never silently recorded past the header.
                    let before = batch.len();
                    batch.retain(|ev| self.observed_res.contains(ev));
                    self.out_of_claim += (before - batch.len()) as u64;
                } else {
                    grow_resolution(&mut self.observed_res, &batch);
                }
                Ok(Some(batch))
            }
            None if self.last_data.elapsed() > self.idle_timeout => Ok(None),
            // The poll timeout already bounded this wait; an empty batch
            // tells the driver "still live, nothing yet".
            None => Ok(Some(Vec::new())),
        }
    }

    fn resolution(&self) -> Resolution {
        self.observed_res
    }

    fn geometry_known(&self) -> bool {
        // Live wire: geometry is only ever observed unless the operator
        // claimed it explicitly.
        self.claimed
    }

    fn is_live(&self) -> bool {
        // Empty batches mean "the wire is quiet", not "starved": this
        // source may heartbeat in a fan-in merge.
        true
    }

    fn dropped(&self) -> u64 {
        self.out_of_claim
    }

    fn describe(&self) -> String {
        "udp".into()
    }
}

/// Synthetic camera as a live source: one scene step per batch.
pub struct CameraSource {
    camera: SyntheticCamera,
    end_us: u64,
}

impl CameraSource {
    /// Stream `duration_us` of simulated time from `config`.
    pub fn new(config: CameraConfig, duration_us: u64) -> Self {
        CameraSource { camera: SyntheticCamera::new(config), end_us: duration_us }
    }
}

impl EventSource for CameraSource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if self.camera.now_us() >= self.end_us {
            return Ok(None);
        }
        // A quiet frame yields an empty batch; simulated time still
        // advances, so the stream always terminates.
        Ok(Some(self.camera.step()))
    }

    fn resolution(&self) -> Resolution {
        self.camera.resolution()
    }

    fn describe(&self) -> String {
        format!("synthetic({} µs)", self.end_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::EventCodec;
    use crate::stream::codec_plane::{CodecPlane, CodecPlaneConfig};
    use crate::testutil::synthetic_events;

    fn write_trace(tag: &str, format: Format, events: &[Event], res: Resolution) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("aestream-src-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(tag);
        let mut bytes = Vec::new();
        format.codec().encode(events, res, &mut bytes).unwrap();
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn steady_state_file_fills_hit_the_pool() {
        // Regression: fill_once used to allocate a fresh Vec per read
        // syscall; with the scratch buffer and a chunk pool, a warmed
        // file replay must run allocation-free (pool misses stay flat).
        let events = synthetic_events(20_000, 128, 128);
        let path = write_trace("steady.aeraw", Format::Raw, &events, Resolution::DVS_128);
        let mut src = FileSource::open(&path, 1024).unwrap();
        let pool = Arc::new(ChunkPool::new());
        src.set_buffer_pool(Arc::clone(&pool));
        // Warm-up: the first batches miss while the free list builds.
        for _ in 0..2 {
            let batch = src.next_batch().unwrap().expect("warm-up batch");
            pool.recycle_vec(batch);
        }
        let warmed = pool.counters();
        let mut total = 2 * 1024;
        while let Some(batch) = src.next_batch().unwrap() {
            total += batch.len();
            pool.recycle_vec(batch);
        }
        assert_eq!(total, events.len());
        let steady = pool.counters().delta(&warmed);
        assert_eq!(steady.misses, 0, "steady-state fills must reuse pooled buffers");
        assert!(steady.hits > 0);
    }

    #[test]
    fn file_source_through_the_plane_matches_inline_decode() {
        let events = synthetic_events(30_000, 346, 260);
        for format in [Format::Evt2, Format::Raw, Format::Aedat] {
            let tag = format!("plane.{format}");
            let path = write_trace(&tag, format, &events, Resolution::DAVIS_346);
            let mut inline = FileSource::open(&path, 2048).unwrap();
            let mut planed = FileSource::open(&path, 2048).unwrap();
            let plane = CodecPlane::new(CodecPlaneConfig::with_workers(3));
            planed.set_codec_plane(Arc::clone(&plane));
            assert_eq!(planed.resolution(), inline.resolution(), "{format}");
            assert_eq!(planed.geometry_known(), inline.geometry_known(), "{format}");
            let mut a = Vec::new();
            while let Some(batch) = inline.next_batch().unwrap() {
                a.extend(batch);
            }
            let mut b = Vec::new();
            while let Some(batch) = planed.next_batch().unwrap() {
                b.extend(batch);
            }
            assert_eq!(a, b, "{format}");
            assert_eq!(a, events, "{format}");
        }
    }

    #[test]
    fn memory_source_chunks_exactly() {
        let events = synthetic_events(1000, 64, 64);
        let mut src = MemorySource::new(events.clone(), Resolution::new(64, 64), 300);
        let mut got = Vec::new();
        let mut sizes = Vec::new();
        while let Some(batch) = src.next_batch().unwrap() {
            sizes.push(batch.len());
            got.extend(batch);
        }
        assert_eq!(got, events);
        assert_eq!(sizes, [300, 300, 300, 100]);
    }

    #[test]
    fn camera_source_terminates_and_reports_geometry() {
        let mut src = CameraSource::new(CameraConfig::default(), 20_000);
        assert_eq!(src.resolution(), Resolution::DAVIS_346);
        let mut total = 0usize;
        let mut batches = 0u32;
        while let Some(batch) = src.next_batch().unwrap() {
            total += batch.len();
            batches += 1;
        }
        assert!(total > 0);
        assert_eq!(batches, 20); // 1000 µs frame interval over 20 ms
    }
}
