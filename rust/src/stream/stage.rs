//! Stages as topology nodes: the sharded stage-graph executor.
//!
//! PR 2 left every event of a topology funnelling through one serial
//! [`Pipeline`] between fan-in and fan-out. This module turns each
//! pipeline stage into a first-class node, compiled from its declared
//! [`TransformClass`]:
//!
//! * **Stateless / Stateful** stages run as N *shard* workers. Events
//!   are routed by pixel stripe (the same vertical-stripe cut as
//!   [`super::RoutePolicy::Stripes`]); a stateful stage's per-pixel
//!   state is safe because a pixel's events always land in the same
//!   stripe, and neighbourhood reads (`halo > 0`, e.g. the denoise
//!   filter's 8-neighbourhood) are satisfied by **ghost events** —
//!   copies of boundary events delivered to the adjacent shard to
//!   update its state, with their outputs discarded.
//! * **Barrier** stages (and `@serial`-pinned ones) run on a single
//!   node.
//!
//! Each event entering a sharded node carries its batch sequence
//! number; the shard outputs are re-merged by that key (via the shared
//! [`super::merge`] core), so the graph's output is **byte-identical**
//! to the serial pipeline — same events, same order, same payloads —
//! which the `stage_graph` property tests assert for every registered
//! op at shard counts 1–4.
//!
//! Shard workers either run inline on the driving thread (the
//! deterministic, zero-thread debug shape) or one OS thread each,
//! fed through the lock-free [`crate::rt::sync_channel`] ring in
//! batch-sized scatter/gather rounds — no per-event locks, and
//! bounded memory (≤ one batch in flight per shard).

use anyhow::{bail, Result};

use crate::aer::{Event, Resolution};
use crate::metrics::NodeReport;
use crate::pipeline::{EventTransform, Pipeline, PipelineSpec};
use crate::rt::{block_on, sync_channel, SyncReceiver, SyncSender};

use super::merge::merge_ordered;

/// An event travelling through a sharded node: batch sequence number
/// (the re-merge key), payload, and whether it is a ghost copy (state
/// update only — output discarded).
type ShardItem = (u64, Event, bool);
/// A shard's processed sub-batch, still sequence-tagged.
type ShardOut = Vec<(u64, Event)>;

/// Batches in flight per shard worker ring (scatter/gather keeps at
/// most one round outstanding; 2 decouples the hand-off edges).
const SHARD_QUEUE_BATCHES: usize = 2;

/// Stripe width for cutting a `width`-pixel canvas into `m` shards —
/// shared with the fan-out stripes router so "stripe i" means the same
/// pixels on every layer.
pub(crate) fn stripe_cut(width: u16, m: usize) -> usize {
    (width as usize).div_ceil(m.max(1)).max(1)
}

/// Which stripe pixel column `x` belongs to (the last stripe absorbs
/// any overhang, exactly like the stripes route policy).
pub(crate) fn stripe_index(x: u16, stripe: usize, m: usize) -> usize {
    (x as usize / stripe).min(m - 1)
}

// ----------------------------------------------------------- processor

/// Anything that can stand between a topology's fan-in and fan-out and
/// process event batches: the serial [`Pipeline`] or a compiled
/// [`StageGraph`]. The topology driver is generic over this, so the
/// serial and sharded paths share every driver line.
pub trait BatchProcessor: Send {
    /// Process one batch, returning the surviving events in order.
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>>;

    /// Tear down any execution resources (join shard worker threads).
    /// Called once, after the last batch.
    fn finish_stages(&mut self) -> Result<()> {
        Ok(())
    }

    /// Per-stage-node counters for [`super::StreamReport::stages`].
    fn stage_reports(&self) -> Vec<NodeReport> {
        Vec::new()
    }

    /// Human-readable description.
    fn describe(&self) -> String;
}

impl BatchProcessor for Pipeline {
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>> {
        Ok(self.process(batch))
    }

    fn describe(&self) -> String {
        Pipeline::describe(self)
    }
}

// --------------------------------------------------------------- graph

/// How [`StageGraph::compile`] spreads shardable stages.
#[derive(Debug, Clone, Copy)]
pub struct StageOptions {
    /// Shard workers per shardable stage (1 = everything serial).
    pub shards: usize,
    /// Pin each shard worker to its own OS thread (fed through the
    /// lock-free ring) instead of running them inline.
    pub shard_threads: bool,
}

impl Default for StageOptions {
    fn default() -> Self {
        StageOptions { shards: 1, shard_threads: false }
    }
}

/// One shard worker pinned to an OS thread.
struct ShardWorker {
    tx: SyncSender<Vec<ShardItem>>,
    rx: SyncReceiver<ShardOut>,
    handle: std::thread::JoinHandle<()>,
}

/// Execution mode of a sharded node's workers.
enum ShardMode {
    /// Worker state lives on the driving thread; shards run one after
    /// another (deterministic, thread-free — the cooperative shape).
    Inline(Vec<Box<dyn EventTransform>>),
    /// One OS thread per shard, scatter/gather per batch.
    Threads(Vec<ShardWorker>),
}

/// Per-node execution strategy.
enum NodeExec {
    /// Single node (barrier class, pinned stage, or shards = 1).
    Serial(Box<dyn EventTransform>),
    /// N stripe-sharded workers with ghost-event halo exchange and a
    /// sequence-keyed re-merge.
    Sharded { stripe: usize, halo: u16, mode: ShardMode, shard_events: Vec<u64> },
}

/// One stage node plus its counters.
struct StageNode {
    name: String,
    events_in: u64,
    events_out: u64,
    batches: u64,
    backpressure_waits: u64,
    exec: NodeExec,
}

/// A compiled chain of stage nodes — the sharded generalization of the
/// "one shared pipeline" edge. Build one with [`StageGraph::compile`]
/// and hand it to [`super::run_topology`] in place of a [`Pipeline`].
pub struct StageGraph {
    nodes: Vec<StageNode>,
    /// Set by [`BatchProcessor::finish_stages`]: threaded shard workers
    /// are gone, so further batches must fail loudly, not drop events.
    finished: bool,
}

impl StageGraph {
    /// Compile `spec` for a canvas of `res` under `opts`.
    ///
    /// The shard count is clamped per stage so a stripe is always wider
    /// than the stage's halo (ghosts only ever cross into the adjacent
    /// stripe); stages that cannot satisfy that (or are barriers or
    /// pinned) fall back to a single serial node.
    pub fn compile(spec: &PipelineSpec, res: Resolution, opts: &StageOptions) -> StageGraph {
        let nodes = spec
            .stages()
            .iter()
            .map(|stage| {
                let class = stage.class();
                let mut shards = opts.shards.max(1);
                if !class.shardable() || stage.is_pinned() {
                    shards = 1;
                }
                let halo = class.halo();
                while shards > 1 && stripe_cut(res.width, shards) <= halo as usize {
                    shards -= 1;
                }
                let exec = if shards == 1 {
                    NodeExec::Serial(stage.build(res))
                } else {
                    let stripe = stripe_cut(res.width, shards);
                    let workers: Vec<Box<dyn EventTransform>> =
                        (0..shards).map(|_| stage.build(res)).collect();
                    let mode = if opts.shard_threads {
                        ShardMode::Threads(spawn_workers(workers))
                    } else {
                        ShardMode::Inline(workers)
                    };
                    NodeExec::Sharded { stripe, halo, mode, shard_events: vec![0; shards] }
                };
                StageNode {
                    name: stage.name().to_string(),
                    events_in: 0,
                    events_out: 0,
                    batches: 0,
                    backpressure_waits: 0,
                    exec,
                }
            })
            .collect();
        StageGraph { nodes, finished: false }
    }

    /// Number of stage nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// `true` for the identity graph.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Shard worker count of node `i` (1 for serial nodes).
    pub fn node_shards(&self, i: usize) -> usize {
        match &self.nodes[i].exec {
            NodeExec::Serial(_) => 1,
            NodeExec::Sharded { shard_events, .. } => shard_events.len(),
        }
    }
}

/// Spawn one OS thread per shard worker. Each worker loops
/// recv-apply-send until its input ring closes; a dead main side
/// (receiver dropped) ends it via the failed send.
fn spawn_workers(stages: Vec<Box<dyn EventTransform>>) -> Vec<ShardWorker> {
    stages
        .into_iter()
        .map(|mut stage| {
            let (tx, mut worker_rx) = sync_channel::<Vec<ShardItem>>(SHARD_QUEUE_BATCHES);
            let (mut worker_tx, rx) = sync_channel::<ShardOut>(SHARD_QUEUE_BATCHES);
            let handle = std::thread::spawn(move || {
                while let Some(batch) = block_on(worker_rx.recv()) {
                    let out = apply_shard(stage.as_mut(), batch);
                    if block_on(worker_tx.send(out)).is_err() {
                        break;
                    }
                }
            });
            ShardWorker { tx, rx, handle }
        })
        .collect()
}

/// Run one shard's sub-batch through its stage instance: ghosts update
/// state but never emit; home events that survive keep their sequence
/// tag for the re-merge.
fn apply_shard(stage: &mut dyn EventTransform, batch: Vec<ShardItem>) -> ShardOut {
    let mut out = Vec::with_capacity(batch.len());
    for (seq, ev, ghost) in batch {
        match stage.apply(ev) {
            Some(next) if !ghost => out.push((seq, next)),
            _ => {}
        }
    }
    out
}

/// Route one batch across `m` stripes: every event goes to its home
/// stripe; events within `halo` pixels of a stripe boundary are
/// additionally ghosted to the adjacent stripe. Returns per-shard
/// inputs plus per-shard home-event counts.
fn route_stripes(
    batch: &[Event],
    stripe: usize,
    m: usize,
    halo: u16,
) -> (Vec<Vec<ShardItem>>, Vec<u64>) {
    let mut parts: Vec<Vec<ShardItem>> = (0..m).map(|_| Vec::new()).collect();
    let mut homes = vec![0u64; m];
    let halo = halo as usize;
    for (seq, &ev) in batch.iter().enumerate() {
        let s = stripe_index(ev.x, stripe, m);
        parts[s].push((seq as u64, ev, false));
        homes[s] += 1;
        if halo > 0 {
            let x = ev.x as usize;
            if s > 0 && x < s * stripe + halo {
                parts[s - 1].push((seq as u64, ev, true));
            }
            if s + 1 < m && x + halo >= (s + 1) * stripe {
                parts[s + 1].push((seq as u64, ev, true));
            }
        }
    }
    (parts, homes)
}

impl StageNode {
    fn process(&mut self, batch: &[Event]) -> Result<Vec<Event>> {
        self.events_in += batch.len() as u64;
        self.batches += 1;
        let out = match &mut self.exec {
            NodeExec::Serial(stage) => {
                let mut out = Vec::with_capacity(batch.len());
                for &ev in batch {
                    if let Some(next) = stage.apply(ev) {
                        out.push(next);
                    }
                }
                out
            }
            NodeExec::Sharded { stripe, halo, mode, shard_events } => {
                let m = shard_events.len();
                let (parts, homes) = route_stripes(batch, *stripe, m, *halo);
                for (count, home) in shard_events.iter_mut().zip(&homes) {
                    *count += home;
                }
                let outs: Vec<ShardOut> = match mode {
                    ShardMode::Inline(stages) => stages
                        .iter_mut()
                        .zip(parts)
                        .map(|(stage, part)| apply_shard(stage.as_mut(), part))
                        .collect(),
                    ShardMode::Threads(workers) => {
                        // Scatter to every worker (even empty parts keep
                        // the gather in lockstep), then gather exactly
                        // one output per worker.
                        for (worker, part) in workers.iter_mut().zip(parts) {
                            match worker.tx.try_send(part) {
                                Ok(()) => {}
                                Err(part) => {
                                    self.backpressure_waits += 1;
                                    if block_on(worker.tx.send(part)).is_err() {
                                        bail!("shard worker for {:?} terminated", self.name);
                                    }
                                }
                            }
                        }
                        let mut outs = Vec::with_capacity(m);
                        for worker in workers.iter_mut() {
                            match block_on(worker.rx.recv()) {
                                Some(out) => outs.push(out),
                                None => {
                                    bail!("shard worker for {:?} terminated", self.name)
                                }
                            }
                        }
                        outs
                    }
                };
                merge_ordered(outs, |item| item.0).into_iter().map(|(_, ev)| ev).collect()
            }
        };
        self.events_out += out.len() as u64;
        Ok(out)
    }

    fn shutdown(&mut self) -> Result<()> {
        if let NodeExec::Sharded { mode: ShardMode::Threads(workers), .. } = &mut self.exec {
            for worker in workers.drain(..) {
                // Dropping both ring ends unblocks a worker parked on
                // either edge before the join.
                let ShardWorker { tx, rx, handle } = worker;
                drop(tx);
                drop(rx);
                if handle.join().is_err() {
                    bail!("shard worker for {:?} panicked", self.name);
                }
            }
        }
        Ok(())
    }
}

impl BatchProcessor for StageGraph {
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>> {
        if self.finished {
            // Threaded shard workers were joined; running on would
            // silently emit nothing. Make the misuse loud instead.
            bail!("stage graph already finished; compile a fresh one per run");
        }
        // The first node consumes the borrowed batch directly; each
        // node materializes one output Vec (the per-node counters and
        // shard hand-offs need owned batches — the cost of stages
        // being individually observable nodes).
        let mut nodes = self.nodes.iter_mut();
        let Some(first) = nodes.next() else {
            return Ok(batch.to_vec()); // identity graph
        };
        let mut current = first.process(batch)?;
        for node in nodes {
            if current.is_empty() {
                // No events ⇒ no state updates anywhere downstream.
                break;
            }
            current = node.process(&current)?;
        }
        Ok(current)
    }

    fn finish_stages(&mut self) -> Result<()> {
        self.finished = true;
        for node in &mut self.nodes {
            node.shutdown()?;
        }
        Ok(())
    }

    fn stage_reports(&self) -> Vec<NodeReport> {
        self.nodes
            .iter()
            .map(|node| NodeReport {
                name: node.name.clone(),
                events: node.events_in,
                batches: node.batches,
                backpressure_waits: node.backpressure_waits,
                dropped: node.events_in - node.events_out,
                frames: 0,
                shard_events: match &node.exec {
                    NodeExec::Serial(_) => Vec::new(),
                    NodeExec::Sharded { shard_events, .. } => shard_events.clone(),
                },
            })
            .collect()
    }

    fn describe(&self) -> String {
        if self.nodes.is_empty() {
            return "identity".into();
        }
        self.nodes
            .iter()
            .map(|node| match &node.exec {
                NodeExec::Serial(_) => node.name.clone(),
                NodeExec::Sharded { mode, shard_events, .. } => {
                    let threads = matches!(mode, ShardMode::Threads(_));
                    format!(
                        "{}[×{}{}]",
                        node.name,
                        shard_events.len(),
                        if threads { " threads" } else { "" }
                    )
                }
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

impl Drop for StageGraph {
    fn drop(&mut self) {
        // Best effort: an explicit finish_stages already drained these.
        for node in &mut self.nodes {
            let _ = node.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::{BackgroundActivityFilter, PolarityFilter, RefractoryFilter};
    use crate::pipeline::StageSpec;
    use crate::testutil::synthetic_events_seeded;

    fn spec_polarity_denoise() -> PipelineSpec {
        PipelineSpec::new()
            .then(StageSpec::new(|_| PolarityFilter::keep(Polarity::On)))
            .then(StageSpec::new(|res: Resolution| BackgroundActivityFilter::new(res, 1000)))
    }

    #[test]
    fn stripe_cut_matches_route_policy_math() {
        assert_eq!(stripe_cut(90, 3), 30);
        assert_eq!(stripe_cut(91, 3), 31);
        assert_eq!(stripe_cut(1, 4), 1);
        assert_eq!(stripe_index(89, 30, 3), 2);
        assert_eq!(stripe_index(95, 30, 3), 2, "overhang clamps to last stripe");
    }

    #[test]
    fn ghost_routing_covers_boundaries_both_ways() {
        let events = vec![Event::on(31, 0, 1), Event::on(32, 0, 2), Event::on(5, 0, 3)];
        let (parts, homes) = route_stripes(&events, 32, 2, 1);
        // x=31: home shard 0, ghost to shard 1 (within halo of boundary).
        // x=32: home shard 1, ghost to shard 0.
        // x=5: home shard 0 only.
        assert_eq!(homes, vec![2, 1]);
        assert_eq!(parts[0].len(), 3);
        assert_eq!(parts[1].len(), 2);
        assert!(parts[1].iter().any(|&(seq, _, ghost)| seq == 0 && ghost));
        assert!(parts[0].iter().any(|&(seq, _, ghost)| seq == 1 && ghost));
        assert!(parts[0].iter().all(|&(seq, _, ghost)| !(seq == 2 && ghost)));
    }

    #[test]
    fn sharded_graph_matches_serial_pipeline_exactly() {
        let res = Resolution::new(64, 48);
        let events = synthetic_events_seeded(4000, 64, 48, 9);
        let spec = spec_polarity_denoise();
        let expected = spec.build_pipeline(res).process(&events);
        for shards in [1usize, 2, 3, 4] {
            for threads in [false, true] {
                let opts = StageOptions { shards, shard_threads: threads };
                let mut graph = StageGraph::compile(&spec, res, &opts);
                let mut got = Vec::new();
                for chunk in events.chunks(257) {
                    got.extend(graph.process_batch(chunk).unwrap());
                }
                graph.finish_stages().unwrap();
                assert_eq!(
                    got, expected,
                    "shards={shards} threads={threads}: sharded ≠ serial"
                );
            }
        }
    }

    #[test]
    fn barrier_and_pinned_stages_stay_serial() {
        struct Opaque;
        impl EventTransform for Opaque {
            fn apply(&mut self, ev: Event) -> Option<Event> {
                Some(ev)
            }
            fn describe(&self) -> String {
                "opaque".into()
            }
        }
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|_| Opaque))
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 100)).pinned())
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 100)));
        let graph = StageGraph::compile(
            &spec,
            Resolution::new(64, 64),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(graph.node_shards(0), 1, "barrier class");
        assert_eq!(graph.node_shards(1), 1, "pinned stage");
        assert_eq!(graph.node_shards(2), 4, "shardable stage");
        assert!(graph.describe().contains("refractory(100µs)[×4]"));
    }

    #[test]
    fn narrow_canvas_clamps_shards_below_halo() {
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| BackgroundActivityFilter::new(res, 500)));
        // 4-wide canvas, halo 1: 4 shards would give 1-px stripes ≤ halo;
        // 3 shards cut 2-px stripes, the widest count that clears it.
        let graph = StageGraph::compile(
            &spec,
            Resolution::new(4, 4),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(graph.node_shards(0), 3, "stripes must stay wider than the halo");
        // A 1-px canvas can never satisfy halo 1: fully serial.
        let serial = StageGraph::compile(
            &spec,
            Resolution::new(1, 1),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(serial.node_shards(0), 1);
    }

    #[test]
    fn stage_reports_chain_and_sum() {
        let res = Resolution::new(64, 48);
        let events = synthetic_events_seeded(3000, 64, 48, 11);
        let spec = spec_polarity_denoise();
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 3, shard_threads: false });
        let mut out_total = 0u64;
        for chunk in events.chunks(500) {
            out_total += graph.process_batch(chunk).unwrap().len() as u64;
        }
        let reports = graph.stage_reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].events, 3000, "first stage sees every event");
        assert_eq!(
            reports[1].events,
            reports[0].events - reports[0].dropped,
            "stage n+1 input = stage n output"
        );
        assert_eq!(reports[1].events - reports[1].dropped, out_total);
        let sharded: u64 = reports[1].shard_events.iter().sum();
        assert_eq!(sharded, reports[1].events, "home events sum to node input");
        assert!(reports[1].shard_skew() >= 1.0);
    }

    #[test]
    fn finished_graph_rejects_further_batches() {
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 50)));
        let mut graph = StageGraph::compile(
            &spec,
            Resolution::new(64, 64),
            &StageOptions { shards: 2, shard_threads: true },
        );
        let events = synthetic_events_seeded(50, 64, 64, 3);
        graph.process_batch(&events).unwrap();
        graph.finish_stages().unwrap();
        let err = graph.process_batch(&events).unwrap_err();
        assert!(format!("{err}").contains("finished"), "must fail loudly, not drop");
    }

    #[test]
    fn worker_threads_join_cleanly_even_without_finish() {
        let res = Resolution::new(64, 64);
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 50)));
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 2, shard_threads: true });
        let events = synthetic_events_seeded(100, 64, 64, 1);
        graph.process_batch(&events).unwrap();
        drop(graph); // Drop must join workers without deadlock.
    }
}
