//! Stages as topology nodes: the sharded stage-graph executor.
//!
//! PR 2 left every event of a topology funnelling through one serial
//! [`Pipeline`] between fan-in and fan-out. This module turns each
//! pipeline stage into a first-class node, compiled from its declared
//! [`TransformClass`]:
//!
//! * **Stateless / Stateful** stages run as N *shard* workers. Events
//!   are routed by pixel stripe (the same vertical-stripe cut as
//!   [`super::RoutePolicy::Stripes`]); a stateful stage's per-pixel
//!   state is safe because a pixel's events always land in the same
//!   stripe, and neighbourhood reads (`halo > 0`, e.g. the denoise
//!   filter's 8-neighbourhood) are satisfied by **ghost events** —
//!   copies of boundary events delivered to the adjacent shard to
//!   update its state, with their outputs discarded.
//! * **Barrier** stages (and `@serial`-pinned ones) run on a single
//!   node.
//!
//! Each event entering a sharded node carries its batch sequence
//! number; the shard outputs are re-merged by that key (via the shared
//! [`super::merge`] core), so the graph's output is **byte-identical**
//! to the serial pipeline — same events, same order, same payloads —
//! which the `stage_graph` property tests assert for every registered
//! op at shard counts 1–4.
//!
//! Shard workers either run inline on the driving thread (the
//! deterministic, zero-thread debug shape) or one OS thread each,
//! fed through the lock-free [`crate::rt::sync_channel`] ring in
//! batch-sized scatter/gather rounds — no per-event locks, and
//! bounded memory (≤ one batch in flight per shard).

use std::sync::Arc;

use anyhow::{bail, Context as _, Result};

use crate::aer::{Event, Resolution};
use crate::metrics::{LiveNode, NodeReport};
use crate::pipeline::{EventTransform, Pipeline, PipelineSpec};
use crate::rt::{block_on, sync_channel, SyncReceiver, SyncSender};

use super::adapt::{Reconfigure, StageTelemetry};
use super::merge::merge_ordered;
use super::pool::ChunkPool;

/// An event travelling through a sharded node: batch sequence number
/// (the re-merge key), payload, and whether it is a ghost copy (state
/// update only — output discarded).
type ShardItem = (u64, Event, bool);
/// A shard's processed sub-batch, still sequence-tagged.
type ShardOut = Vec<(u64, Event)>;

/// Batches in flight per shard worker ring (scatter/gather keeps at
/// most one round outstanding; 2 decouples the hand-off edges).
const SHARD_QUEUE_BATCHES: usize = 2;

/// Stripe width for cutting a `width`-pixel canvas into `m` shards —
/// shared with the fan-out stripes router so "stripe i" means the same
/// pixels on every layer.
pub(crate) fn stripe_cut(width: u16, m: usize) -> usize {
    (width as usize).div_ceil(m.max(1)).max(1)
}

/// Which stripe pixel column `x` belongs to (the last stripe absorbs
/// any overhang, exactly like the stripes route policy).
pub(crate) fn stripe_index(x: u16, stripe: usize, m: usize) -> usize {
    (x as usize / stripe).min(m - 1)
}

/// A stripe partition of the canvas width: ascending stripe *end*
/// columns (exclusive), one per shard, the last equal to the canvas
/// width. [`uniform`](StripeCut::uniform) reproduces the classic
/// even cut; adaptive re-cuts install arbitrary boundaries via
/// [`from_bounds`](StripeCut::from_bounds) (validated so ghost routing
/// to adjacent stripes still covers every halo neighbourhood).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripeCut {
    bounds: Vec<u16>,
}

impl StripeCut {
    /// The even cut: `m` stripes of `ceil(width / m)` columns, the last
    /// absorbing the remainder (identical pixel assignment to the
    /// historical `stripe_index` math, trailing stripes may be empty on
    /// narrow canvases).
    pub fn uniform(width: u16, m: usize) -> StripeCut {
        let m = m.max(1);
        let stripe = stripe_cut(width, m);
        StripeCut {
            bounds: (1..=m).map(|i| (i * stripe).min(width as usize) as u16).collect(),
        }
    }

    /// Validate explicit boundaries for a `width`-column canvas and a
    /// stage of the given `halo`: ascending, ending at `width`, every
    /// stripe at least `max(halo, 1)` columns wide (adjacent-stripe
    /// ghosts can then never fall short of a neighbourhood).
    pub fn from_bounds(bounds: Vec<u16>, width: u16, halo: u16) -> Result<StripeCut> {
        if bounds.is_empty() {
            bail!("stripe cut needs at least one stripe");
        }
        if *bounds.last().expect("nonempty") != width {
            bail!(
                "stripe cut must end at the canvas width {width}, got {:?}",
                bounds
            );
        }
        let min_width = halo.max(1);
        let mut lo = 0u16;
        for &hi in &bounds {
            if hi <= lo || hi - lo < min_width {
                bail!(
                    "stripe [{lo},{hi}) narrower than the minimum width \
                     {min_width} (halo {halo}) in {bounds:?}"
                );
            }
            lo = hi;
        }
        Ok(StripeCut { bounds })
    }

    /// Number of stripes.
    pub fn shards(&self) -> usize {
        self.bounds.len()
    }

    /// The stripe end columns.
    pub fn bounds(&self) -> &[u16] {
        &self.bounds
    }

    /// Canvas width (the last boundary).
    pub fn width(&self) -> u16 {
        *self.bounds.last().expect("cut is never empty")
    }

    /// First column of stripe `s`.
    pub fn lo(&self, s: usize) -> u16 {
        if s == 0 {
            0
        } else {
            self.bounds[s - 1]
        }
    }

    /// One past the last column of stripe `s`.
    pub fn hi(&self, s: usize) -> u16 {
        self.bounds[s]
    }

    /// Home stripe of column `x` (columns past the canvas clamp to the
    /// last stripe, like the uniform cut always did).
    pub fn index(&self, x: u16) -> usize {
        self.bounds
            .partition_point(|&b| b <= x)
            .min(self.bounds.len() - 1)
    }
}

// ----------------------------------------------------------- processor

/// Anything that can stand between a topology's fan-in and fan-out and
/// process event batches: the serial [`Pipeline`] or a compiled
/// [`StageGraph`]. The topology driver is generic over this, so the
/// serial and sharded paths share every driver line.
pub trait BatchProcessor: Send {
    /// Process one batch, returning the surviving events in order.
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>>;

    /// `true` when the processor is the identity (no stages): the
    /// driver then routes the incoming chunk through untouched instead
    /// of materializing an output buffer per batch. Conservative
    /// default: a processor that does not say is assumed to transform.
    fn is_identity(&self) -> bool {
        false
    }

    /// Tear down any execution resources (join shard worker threads).
    /// Called once, after the last batch.
    fn finish_stages(&mut self) -> Result<()> {
        Ok(())
    }

    /// Per-stage-node counters for [`super::StreamReport::stages`].
    fn stage_reports(&self) -> Vec<NodeReport> {
        Vec::new()
    }

    /// Live telemetry handles, one per stage node, for the adaptive
    /// epoch sampler (empty when the processor exposes no plane — the
    /// serial [`Pipeline`]).
    fn telemetry(&self) -> Vec<StageTelemetry> {
        Vec::new()
    }

    /// Apply one epoch-barrier reconfiguration. The driver guarantees
    /// no batch is in flight. Chunk-size changes are edge-level and
    /// accepted by default; stripe re-cuts must be implemented by the
    /// processor (the [`StageGraph`] does) and fail loudly elsewhere.
    fn reconfigure(&mut self, change: &Reconfigure) -> Result<()> {
        match change {
            Reconfigure::ChunkSize(_) => Ok(()),
            // Per-client windows are applied on the serving plane by the
            // adaptive loop itself; stages have nothing to do.
            Reconfigure::ClientWindow { .. } => Ok(()),
            Reconfigure::RecutStripes { .. } => {
                bail!("{} does not support stripe re-cuts", self.describe())
            }
        }
    }

    /// Human-readable description.
    fn describe(&self) -> String;
}

impl BatchProcessor for Pipeline {
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>> {
        Ok(self.process(batch))
    }

    fn is_identity(&self) -> bool {
        self.is_empty()
    }

    fn describe(&self) -> String {
        Pipeline::describe(self)
    }
}

// --------------------------------------------------------------- graph

/// How [`StageGraph::compile`] spreads shardable stages.
#[derive(Debug, Clone, Copy)]
pub struct StageOptions {
    /// Shard workers per shardable stage (1 = everything serial).
    pub shards: usize,
    /// Pin each shard worker to its own OS thread (fed through the
    /// lock-free ring) instead of running them inline.
    pub shard_threads: bool,
}

impl Default for StageOptions {
    fn default() -> Self {
        StageOptions { shards: 1, shard_threads: false }
    }
}

/// One shard worker pinned to an OS thread. `reclaim` hands the worker's
/// stage instance (with its state) back to the driving thread when the
/// input ring closes — how a re-cut recovers per-shard state from live
/// threads.
struct ShardWorker {
    tx: SyncSender<Vec<ShardItem>>,
    rx: SyncReceiver<ShardOut>,
    reclaim: SyncReceiver<Box<dyn EventTransform>>,
    handle: std::thread::JoinHandle<()>,
}

/// Execution mode of a sharded node's workers.
enum ShardMode {
    /// Worker state lives on the driving thread; shards run one after
    /// another (deterministic, thread-free — the cooperative shape).
    Inline(Vec<Box<dyn EventTransform>>),
    /// One OS thread per shard, scatter/gather per batch.
    Threads(Vec<ShardWorker>),
}

/// Per-node execution strategy.
enum NodeExec {
    /// Single node (barrier class, pinned stage, or shards = 1).
    Serial(Box<dyn EventTransform>),
    /// N stripe-sharded workers with ghost-event halo exchange and a
    /// sequence-keyed re-merge. The cut is replaceable at an epoch
    /// barrier ([`StageGraph::reconfigure`]).
    Sharded { cut: StripeCut, halo: u16, mode: ShardMode },
}

/// One stage node plus its live counter cell (shared with the adaptive
/// sampler through [`BatchProcessor::telemetry`]).
struct StageNode {
    node: Arc<LiveNode>,
    exec: NodeExec,
}

/// A compiled chain of stage nodes — the sharded generalization of the
/// "one shared pipeline" edge. Build one with [`StageGraph::compile`]
/// and hand it to [`super::run_topology`] in place of a [`Pipeline`].
pub struct StageGraph {
    nodes: Vec<StageNode>,
    /// Recycles the per-node output buffers: each batch hand-off
    /// between chained nodes returns the superseded `Vec` here instead
    /// of freeing it, so a steady-state chain allocates nothing.
    pool: Arc<ChunkPool>,
    /// Set by [`BatchProcessor::finish_stages`]: threaded shard workers
    /// are gone, so further batches must fail loudly, not drop events.
    finished: bool,
}

impl StageGraph {
    /// Compile `spec` for a canvas of `res` under `opts`.
    ///
    /// The shard count is clamped per stage so a stripe is always wider
    /// than the stage's halo (ghosts only ever cross into the adjacent
    /// stripe); stages that cannot satisfy that (or are barriers or
    /// pinned) fall back to a single serial node.
    pub fn compile(spec: &PipelineSpec, res: Resolution, opts: &StageOptions) -> StageGraph {
        Self::compile_prefixed(spec, res, opts, "")
    }

    /// [`compile`](Self::compile) with every stage node's report name
    /// prefixed — how [`super::graph`] keeps per-branch stage reports
    /// attributable ("branchname/stagename") when several compiled
    /// chains land in one [`StreamReport`](super::StreamReport).
    pub(crate) fn compile_prefixed(
        spec: &PipelineSpec,
        res: Resolution,
        opts: &StageOptions,
        prefix: &str,
    ) -> StageGraph {
        let nodes = spec
            .stages()
            .iter()
            .map(|stage| {
                let class = stage.class();
                let mut shards = opts.shards.max(1);
                if !class.shardable() || stage.is_pinned() {
                    shards = 1;
                }
                let halo = class.halo();
                while shards > 1 && stripe_cut(res.width, shards) <= halo as usize {
                    shards -= 1;
                }
                let name = if prefix.is_empty() {
                    stage.name().to_string()
                } else {
                    format!("{prefix}{}", stage.name())
                };
                let node = Arc::new(LiveNode::new(name));
                let exec = if shards == 1 {
                    NodeExec::Serial(stage.build(res))
                } else {
                    let cut = StripeCut::uniform(res.width, shards);
                    let workers: Vec<Box<dyn EventTransform>> =
                        (0..shards).map(|_| stage.build(res)).collect();
                    let mode = if opts.shard_threads {
                        ShardMode::Threads(spawn_workers(node.name(), workers))
                    } else {
                        ShardMode::Inline(workers)
                    };
                    node.reset_shards(shards);
                    NodeExec::Sharded { cut, halo, mode }
                };
                StageNode { node, exec }
            })
            .collect();
        StageGraph { nodes, pool: Arc::new(ChunkPool::new()), finished: false }
    }

    /// The identity graph (no stage nodes) — the seed for
    /// [`append`](Self::append)-built chains.
    pub(crate) fn empty() -> StageGraph {
        StageGraph { nodes: Vec::new(), pool: Arc::new(ChunkPool::new()), finished: false }
    }

    /// Move `other`'s stage nodes onto the end of this chain. The graph
    /// compiler concatenates separately-compiled trunk segments this
    /// way, so each segment keeps its own shard options.
    pub(crate) fn append(&mut self, mut other: StageGraph) {
        self.nodes.append(&mut other.nodes);
    }

    /// Number of stage nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// `true` for the identity graph.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Shard worker count of node `i` (1 for serial nodes).
    pub fn node_shards(&self, i: usize) -> usize {
        match &self.nodes[i].exec {
            NodeExec::Serial(_) => 1,
            NodeExec::Sharded { cut, .. } => cut.shards(),
        }
    }

    /// Current stripe end columns of node `i` (empty for serial nodes).
    pub fn node_bounds(&self, i: usize) -> Vec<u16> {
        match &self.nodes[i].exec {
            NodeExec::Serial(_) => Vec::new(),
            NodeExec::Sharded { cut, .. } => cut.bounds().to_vec(),
        }
    }
}

/// OS thread name for shard `i` of `stage`: `shard:<stage>:<i>`,
/// clipped to the 15-byte Linux thread-name limit (longer names fail
/// to apply silently) at a char boundary.
fn shard_thread_name(stage: &str, i: usize) -> String {
    let mut name = format!("shard:{stage}:{i}");
    let mut end = name.len().min(15);
    while !name.is_char_boundary(end) {
        end -= 1;
    }
    name.truncate(end);
    name
}

/// Spawn one OS thread per shard worker (named `shard:<stage>:<i>` so
/// `top -H` / debuggers attribute load to the right node). Each worker
/// loops recv-apply-send until its input ring closes; a dead main side
/// (receiver dropped) ends it via the failed send. On exit the worker
/// offers its stage instance back through the reclaim ring so an epoch
/// re-cut can move its state (plain shutdown just drops the offer).
fn spawn_workers(label: &str, stages: Vec<Box<dyn EventTransform>>) -> Vec<ShardWorker> {
    stages
        .into_iter()
        .enumerate()
        .map(|(i, mut stage)| {
            let (tx, mut worker_rx) = sync_channel::<Vec<ShardItem>>(SHARD_QUEUE_BATCHES);
            let (mut worker_tx, rx) = sync_channel::<ShardOut>(SHARD_QUEUE_BATCHES);
            let (mut reclaim_tx, reclaim) = sync_channel::<Box<dyn EventTransform>>(1);
            let handle = std::thread::Builder::new()
                .name(shard_thread_name(label, i))
                .spawn(move || {
                    while let Some(batch) = block_on(worker_rx.recv()) {
                        let out = apply_shard(stage.as_mut(), batch);
                        if block_on(worker_tx.send(out)).is_err() {
                            break;
                        }
                    }
                    let _ = block_on(reclaim_tx.send(stage));
                })
                .expect("spawn shard worker thread");
            ShardWorker { tx, rx, reclaim, handle }
        })
        .collect()
}

/// Run one shard's sub-batch through its stage instance: ghosts update
/// state but never emit; home events that survive keep their sequence
/// tag for the re-merge.
fn apply_shard(stage: &mut dyn EventTransform, batch: Vec<ShardItem>) -> ShardOut {
    let mut out = Vec::with_capacity(batch.len());
    for (seq, ev, ghost) in batch {
        match stage.apply(ev) {
            Some(next) if !ghost => out.push((seq, next)),
            _ => {}
        }
    }
    out
}

/// Route one batch across the cut's stripes: every event goes to its
/// home stripe; events within `halo` pixels of a stripe boundary are
/// additionally ghosted to the adjacent stripe. Returns per-shard
/// inputs plus per-shard home-event counts.
///
/// Single-pass partition in the counting sense: one scan sizes every
/// shard exactly (home + ghost), so the fill scan appends into
/// right-sized buffers — no push-growth reallocations mid-batch, which
/// on the hot path showed up as the dominant allocator traffic.
fn route_stripes(
    batch: &[Event],
    cut: &StripeCut,
    halo: u16,
) -> (Vec<Vec<ShardItem>>, Vec<u64>) {
    let m = cut.shards();
    let halo = halo as usize;
    // Pass 1: exact per-shard counts (home and ghost together).
    let mut counts = vec![0usize; m];
    let mut homes = vec![0u64; m];
    for &ev in batch {
        let s = cut.index(ev.x);
        counts[s] += 1;
        homes[s] += 1;
        if halo > 0 {
            let x = ev.x as usize;
            if s > 0 && x < cut.lo(s) as usize + halo {
                counts[s - 1] += 1;
            }
            if s + 1 < m && x + halo >= cut.hi(s) as usize {
                counts[s + 1] += 1;
            }
        }
    }
    // Pass 2: fill the exactly-sized shard inputs.
    let mut parts: Vec<Vec<ShardItem>> =
        counts.into_iter().map(Vec::with_capacity).collect();
    for (seq, &ev) in batch.iter().enumerate() {
        let s = cut.index(ev.x);
        parts[s].push((seq as u64, ev, false));
        if halo > 0 {
            let x = ev.x as usize;
            if s > 0 && x < cut.lo(s) as usize + halo {
                parts[s - 1].push((seq as u64, ev, true));
            }
            if s + 1 < m && x + halo >= cut.hi(s) as usize {
                parts[s + 1].push((seq as u64, ev, true));
            }
        }
    }
    (parts, homes)
}

impl StageNode {
    fn process(&mut self, batch: &[Event], pool: &ChunkPool) -> Result<Vec<Event>> {
        self.node.add_events(batch.len() as u64);
        self.node.add_batch();
        let name = self.node.name();
        let out = match &mut self.exec {
            NodeExec::Serial(stage) => {
                let mut out = pool.get_counted(batch.len(), &self.node);
                for &ev in batch {
                    if let Some(next) = stage.apply(ev) {
                        out.push(next);
                    }
                }
                out
            }
            NodeExec::Sharded { cut, halo, mode } => {
                let m = cut.shards();
                let (parts, homes) = route_stripes(batch, cut, *halo);
                self.node.record_shards(&homes);
                let outs: Vec<ShardOut> = match mode {
                    ShardMode::Inline(stages) => stages
                        .iter_mut()
                        .zip(parts)
                        .map(|(stage, part)| apply_shard(stage.as_mut(), part))
                        .collect(),
                    ShardMode::Threads(workers) => {
                        // Scatter to every worker (even empty parts keep
                        // the gather in lockstep), then gather exactly
                        // one output per worker.
                        for (worker, part) in workers.iter_mut().zip(parts) {
                            match worker.tx.try_send(part) {
                                Ok(()) => {}
                                Err(part) => {
                                    self.node.add_backpressure_wait();
                                    if block_on(worker.tx.send(part)).is_err() {
                                        bail!("shard worker for {name:?} terminated");
                                    }
                                }
                            }
                        }
                        let mut outs = Vec::with_capacity(m);
                        for worker in workers.iter_mut() {
                            match block_on(worker.rx.recv()) {
                                Some(out) => outs.push(out),
                                None => {
                                    bail!("shard worker for {name:?} terminated")
                                }
                            }
                        }
                        outs
                    }
                };
                merge_ordered(outs, |item| item.0).into_iter().map(|(_, ev)| ev).collect()
            }
        };
        self.node.add_dropped(batch.len() as u64 - out.len() as u64);
        Ok(out)
    }

    fn shutdown(&mut self) -> Result<()> {
        if let NodeExec::Sharded { mode: ShardMode::Threads(workers), .. } = &mut self.exec {
            for worker in workers.drain(..) {
                // Dropping all ring ends unblocks a worker parked on
                // any edge before the join (the unread reclaim offer
                // fails fast and is discarded).
                let ShardWorker { tx, rx, reclaim, handle } = worker;
                drop(tx);
                drop(rx);
                drop(reclaim);
                if handle.join().is_err() {
                    bail!("shard worker for {:?} panicked", self.node.name());
                }
            }
        }
        Ok(())
    }

    /// Apply a validated stripe re-cut at an epoch barrier: drain the
    /// workers (threaded shards are already in per-batch lockstep, so
    /// closing their input ring drains them), reclaim the stage
    /// instances, hand per-column state from each column's old owner to
    /// its new one (plus the halo fringe each new stripe reads), then
    /// resume under the new cut. Output stays byte-identical to serial
    /// because every column's state is exact in its home shard and
    /// moves with it.
    fn recut(&mut self, new_cut: StripeCut) -> Result<()> {
        let name = self.node.name().to_string();
        let NodeExec::Sharded { cut, halo, mode } = &mut self.exec else {
            bail!("stage {name:?} is not sharded; nothing to re-cut");
        };
        if new_cut.shards() != cut.shards() {
            bail!(
                "re-cut of {name:?} must keep the shard count {} (got {})",
                cut.shards(),
                new_cut.shards()
            );
        }
        if new_cut.width() != cut.width() {
            bail!(
                "re-cut of {name:?} must keep the canvas width {} (got {})",
                cut.width(),
                new_cut.width()
            );
        }
        // Reclaim every stage instance (and its state).
        let mut stages: Vec<Box<dyn EventTransform>> = match mode {
            ShardMode::Inline(stages) => std::mem::take(stages),
            ShardMode::Threads(workers) => {
                let mut out = Vec::with_capacity(workers.len());
                for worker in workers.drain(..) {
                    let ShardWorker { tx, rx, mut reclaim, handle } = worker;
                    drop(tx); // closes the input ring: the worker exits its loop
                    let stage = block_on(reclaim.recv());
                    drop(rx);
                    if handle.join().is_err() || stage.is_none() {
                        bail!("shard worker for {name:?} died before the re-cut");
                    }
                    out.push(stage.expect("checked above"));
                }
                out
            }
        };
        // Phase 1 — export: for each new stripe, the columns it will
        // read (its stripe plus the halo fringe), segmented by which
        // old shard owns them exactly (the home owner's state for its
        // own columns is always exact).
        let m = new_cut.shards();
        let width = cut.width();
        let fringe = *halo;
        let mut imports: Vec<Vec<(u16, u16, Vec<u64>)>> = Vec::with_capacity(m);
        for j in 0..m {
            let lo = new_cut.lo(j).saturating_sub(fringe);
            let hi = new_cut.hi(j).saturating_add(fringe).min(width);
            let mut segs = Vec::new();
            let mut c = lo;
            while c < hi {
                let owner = cut.index(c);
                let end = cut.hi(owner).min(hi);
                segs.push((c, end, stages[owner].export_rows(c, end)));
                c = end;
            }
            imports.push(segs);
        }
        // Phase 2 — import into the new owners (only after every export
        // is taken, so no instance reads post-import state).
        for (j, segs) in imports.into_iter().enumerate() {
            for (x0, x1, rows) in segs {
                stages[j].import_rows(x0, x1, &rows);
            }
        }
        *cut = new_cut;
        match mode {
            ShardMode::Inline(slot) => *slot = stages,
            ShardMode::Threads(workers) => *workers = spawn_workers(&name, stages),
        }
        // The histogram restarts under the new cut so skew (and the
        // next epoch's sample) describes current boundaries only.
        self.node.reset_shards(m);
        Ok(())
    }
}

impl BatchProcessor for StageGraph {
    fn process_batch(&mut self, batch: &[Event]) -> Result<Vec<Event>> {
        if self.finished {
            // Threaded shard workers were joined; running on would
            // silently emit nothing. Make the misuse loud instead.
            bail!("stage graph already finished; compile a fresh one per run");
        }
        // The first node consumes the borrowed batch directly; each
        // node materializes one output Vec (the per-node counters and
        // shard hand-offs need owned batches — the cost of stages
        // being individually observable nodes).
        let pool = Arc::clone(&self.pool);
        let mut nodes = self.nodes.iter_mut();
        let Some(first) = nodes.next() else {
            return Ok(batch.to_vec()); // identity graph
        };
        let mut current = first.process(batch, &pool)?;
        for node in nodes {
            if current.is_empty() {
                // No events ⇒ no state updates anywhere downstream.
                break;
            }
            let next = node.process(&current, &pool)?;
            pool.recycle_vec(current);
            current = next;
        }
        Ok(current)
    }

    fn is_identity(&self) -> bool {
        self.nodes.is_empty()
    }

    fn finish_stages(&mut self) -> Result<()> {
        self.finished = true;
        for node in &mut self.nodes {
            node.shutdown()?;
        }
        Ok(())
    }

    fn stage_reports(&self) -> Vec<NodeReport> {
        // Reconstructed from a final sample of the live plane: the same
        // cells the adaptive sampler reads mid-run, so end-of-run and
        // mid-run views can never disagree about what a counter means.
        self.nodes.iter().map(|node| node.node.sample()).collect()
    }

    fn telemetry(&self) -> Vec<StageTelemetry> {
        self.nodes
            .iter()
            .map(|node| StageTelemetry {
                node: node.node.clone(),
                bounds: match &node.exec {
                    NodeExec::Serial(_) => Vec::new(),
                    NodeExec::Sharded { cut, .. } => cut.bounds().to_vec(),
                },
                halo: match &node.exec {
                    NodeExec::Serial(_) => 0,
                    NodeExec::Sharded { halo, .. } => *halo,
                },
            })
            .collect()
    }

    fn reconfigure(&mut self, change: &Reconfigure) -> Result<()> {
        match change {
            // Chunking is decided upstream of the graph; nothing to do.
            Reconfigure::ChunkSize(_) => Ok(()),
            // Per-client windows live on the serving plane, not here.
            Reconfigure::ClientWindow { .. } => Ok(()),
            Reconfigure::RecutStripes { stage, bounds } => {
                if self.finished {
                    bail!("stage graph already finished; cannot re-cut");
                }
                let Some(node) = self.nodes.get_mut(*stage) else {
                    bail!("re-cut targets stage {stage}, graph has {}", self.nodes.len())
                };
                let NodeExec::Sharded { cut, halo, .. } = &node.exec else {
                    bail!("re-cut targets serial stage {:?}", node.node.name())
                };
                let new_cut =
                    StripeCut::from_bounds(bounds.clone(), cut.width(), *halo)
                        .context("invalid re-cut bounds")?;
                node.recut(new_cut)
            }
        }
    }

    fn describe(&self) -> String {
        if self.nodes.is_empty() {
            return "identity".into();
        }
        self.nodes
            .iter()
            .map(|node| match &node.exec {
                NodeExec::Serial(_) => node.node.name().to_string(),
                NodeExec::Sharded { mode, cut, .. } => {
                    let threads = matches!(mode, ShardMode::Threads(_));
                    format!(
                        "{}[×{}{}]",
                        node.node.name(),
                        cut.shards(),
                        if threads { " threads" } else { "" }
                    )
                }
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

impl Drop for StageGraph {
    fn drop(&mut self) {
        // Best effort: an explicit finish_stages already drained these.
        for node in &mut self.nodes {
            let _ = node.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Polarity;
    use crate::pipeline::ops::{BackgroundActivityFilter, PolarityFilter, RefractoryFilter};
    use crate::pipeline::StageSpec;
    use crate::testutil::synthetic_events_seeded;

    fn spec_polarity_denoise() -> PipelineSpec {
        PipelineSpec::new()
            .then(StageSpec::new(|_| PolarityFilter::keep(Polarity::On)))
            .then(StageSpec::new(|res: Resolution| BackgroundActivityFilter::new(res, 1000)))
    }

    #[test]
    fn stripe_cut_matches_route_policy_math() {
        assert_eq!(stripe_cut(90, 3), 30);
        assert_eq!(stripe_cut(91, 3), 31);
        assert_eq!(stripe_cut(1, 4), 1);
        assert_eq!(stripe_index(89, 30, 3), 2);
        assert_eq!(stripe_index(95, 30, 3), 2, "overhang clamps to last stripe");
    }

    #[test]
    fn stripe_cut_indexing_and_validation() {
        let cut = StripeCut::uniform(90, 3);
        assert_eq!(cut.bounds(), &[30, 60, 90]);
        assert_eq!(cut.index(29), 0);
        assert_eq!(cut.index(30), 1);
        assert_eq!(cut.index(95), 2, "overhang clamps to the last stripe");
        // Uniform agrees with the historical stripe math everywhere.
        for x in 0..128u16 {
            assert_eq!(cut.index(x), stripe_index(x, 30, 3), "x={x}");
        }
        let uneven = StripeCut::from_bounds(vec![10, 15, 90], 90, 1).unwrap();
        assert_eq!(uneven.lo(1), 10);
        assert_eq!(uneven.hi(1), 15);
        assert_eq!(uneven.index(9), 0);
        assert_eq!(uneven.index(10), 1);
        assert_eq!(uneven.index(14), 1);
        assert_eq!(uneven.index(89), 2);
        // Rejections: wrong terminal width, non-ascending, sub-halo.
        assert!(StripeCut::from_bounds(vec![10, 80], 90, 0).is_err());
        assert!(StripeCut::from_bounds(vec![40, 30, 90], 90, 0).is_err());
        assert!(StripeCut::from_bounds(vec![1, 90], 90, 2).is_err(), "1px < halo 2");
        assert!(StripeCut::from_bounds(Vec::new(), 90, 0).is_err());
    }

    #[test]
    fn ghost_routing_covers_boundaries_both_ways() {
        let events = vec![Event::on(31, 0, 1), Event::on(32, 0, 2), Event::on(5, 0, 3)];
        let (parts, homes) = route_stripes(&events, &StripeCut::uniform(64, 2), 1);
        // x=31: home shard 0, ghost to shard 1 (within halo of boundary).
        // x=32: home shard 1, ghost to shard 0.
        // x=5: home shard 0 only.
        assert_eq!(homes, vec![2, 1]);
        assert_eq!(parts[0].len(), 3);
        assert_eq!(parts[1].len(), 2);
        assert!(parts[1].iter().any(|&(seq, _, ghost)| seq == 0 && ghost));
        assert!(parts[0].iter().any(|&(seq, _, ghost)| seq == 1 && ghost));
        assert!(parts[0].iter().all(|&(seq, _, ghost)| !(seq == 2 && ghost)));
    }

    #[test]
    fn sharded_graph_matches_serial_pipeline_exactly() {
        let res = Resolution::new(64, 48);
        let events = synthetic_events_seeded(4000, 64, 48, 9);
        let spec = spec_polarity_denoise();
        let expected = spec.build_pipeline(res).process(&events);
        for shards in [1usize, 2, 3, 4] {
            for threads in [false, true] {
                let opts = StageOptions { shards, shard_threads: threads };
                let mut graph = StageGraph::compile(&spec, res, &opts);
                let mut got = Vec::new();
                for chunk in events.chunks(257) {
                    got.extend(graph.process_batch(chunk).unwrap());
                }
                graph.finish_stages().unwrap();
                assert_eq!(
                    got, expected,
                    "shards={shards} threads={threads}: sharded ≠ serial"
                );
            }
        }
    }

    #[test]
    fn barrier_and_pinned_stages_stay_serial() {
        struct Opaque;
        impl EventTransform for Opaque {
            fn apply(&mut self, ev: Event) -> Option<Event> {
                Some(ev)
            }
            fn describe(&self) -> String {
                "opaque".into()
            }
        }
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|_| Opaque))
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 100)).pinned())
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 100)));
        let graph = StageGraph::compile(
            &spec,
            Resolution::new(64, 64),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(graph.node_shards(0), 1, "barrier class");
        assert_eq!(graph.node_shards(1), 1, "pinned stage");
        assert_eq!(graph.node_shards(2), 4, "shardable stage");
        assert!(graph.describe().contains("refractory(100µs)[×4]"));
    }

    #[test]
    fn narrow_canvas_clamps_shards_below_halo() {
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| BackgroundActivityFilter::new(res, 500)));
        // 4-wide canvas, halo 1: 4 shards would give 1-px stripes ≤ halo;
        // 3 shards cut 2-px stripes, the widest count that clears it.
        let graph = StageGraph::compile(
            &spec,
            Resolution::new(4, 4),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(graph.node_shards(0), 3, "stripes must stay wider than the halo");
        // A 1-px canvas can never satisfy halo 1: fully serial.
        let serial = StageGraph::compile(
            &spec,
            Resolution::new(1, 1),
            &StageOptions { shards: 4, shard_threads: false },
        );
        assert_eq!(serial.node_shards(0), 1);
    }

    #[test]
    fn stage_reports_chain_and_sum() {
        let res = Resolution::new(64, 48);
        let events = synthetic_events_seeded(3000, 64, 48, 11);
        let spec = spec_polarity_denoise();
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 3, shard_threads: false });
        let mut out_total = 0u64;
        for chunk in events.chunks(500) {
            out_total += graph.process_batch(chunk).unwrap().len() as u64;
        }
        let reports = graph.stage_reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].events, 3000, "first stage sees every event");
        assert_eq!(
            reports[1].events,
            reports[0].events - reports[0].dropped,
            "stage n+1 input = stage n output"
        );
        assert_eq!(reports[1].events - reports[1].dropped, out_total);
        let sharded: u64 = reports[1].shard_events.iter().sum();
        assert_eq!(sharded, reports[1].events, "home events sum to node input");
        assert!(reports[1].shard_skew() >= 1.0);
    }

    #[test]
    fn finished_graph_rejects_further_batches() {
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 50)));
        let mut graph = StageGraph::compile(
            &spec,
            Resolution::new(64, 64),
            &StageOptions { shards: 2, shard_threads: true },
        );
        let events = synthetic_events_seeded(50, 64, 64, 3);
        graph.process_batch(&events).unwrap();
        graph.finish_stages().unwrap();
        let err = graph.process_batch(&events).unwrap_err();
        assert!(format!("{err}").contains("finished"), "must fail loudly, not drop");
    }

    #[test]
    fn worker_threads_join_cleanly_even_without_finish() {
        let res = Resolution::new(64, 64);
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 50)));
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 2, shard_threads: true });
        let events = synthetic_events_seeded(100, 64, 64, 1);
        graph.process_batch(&events).unwrap();
        drop(graph); // Drop must join workers without deadlock.
    }

    /// A mid-stream re-cut (state handed across the new boundaries via
    /// export_rows/import_rows) must leave the output byte-identical to
    /// the serial pipeline — for the halo-free stateful op, the
    /// halo-carrying one, and threaded workers.
    #[test]
    fn recut_mid_stream_stays_byte_identical_to_serial() {
        let res = Resolution::new(64, 48);
        let events = synthetic_events_seeded(6000, 64, 48, 21);
        let spec = spec_polarity_denoise();
        let expected = spec.build_pipeline(res).process(&events);
        for threads in [false, true] {
            let opts = StageOptions { shards: 2, shard_threads: threads };
            let mut graph = StageGraph::compile(&spec, res, &opts);
            let mut got = Vec::new();
            for (i, chunk) in events.chunks(251).enumerate() {
                got.extend(graph.process_batch(chunk).unwrap());
                // Re-cut the sharded denoise stage (index 1) to a new
                // boundary after every few batches, ping-ponging so
                // columns change owner repeatedly.
                if i % 3 == 2 {
                    let bound = if (i / 3) % 2 == 0 { 20 } else { 44 };
                    graph
                        .reconfigure(&Reconfigure::RecutStripes {
                            stage: 1,
                            bounds: vec![bound, 64],
                        })
                        .unwrap();
                }
            }
            graph.finish_stages().unwrap();
            assert_eq!(got, expected, "threads={threads}: re-cut output diverged");
        }
    }

    #[test]
    fn recut_resets_the_shard_histogram_to_the_new_cut() {
        let res = Resolution::new(64, 64);
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| RefractoryFilter::new(res, 1)));
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 2, shard_threads: false });
        let events = synthetic_events_seeded(1000, 64, 64, 8);
        graph.process_batch(&events).unwrap();
        assert_eq!(graph.stage_reports()[0].shard_events.iter().sum::<u64>(), 1000);
        graph
            .reconfigure(&Reconfigure::RecutStripes { stage: 0, bounds: vec![10, 64] })
            .unwrap();
        let after_recut = graph.stage_reports()[0].clone();
        assert_eq!(after_recut.shard_events, vec![0, 0], "histogram restarts");
        assert_eq!(after_recut.events, 1000, "cumulative totals survive");
        assert_eq!(after_recut.shard_skew(), 1.0, "all-zero histogram sits on the floor");
        graph.process_batch(&events).unwrap();
        let report = graph.stage_reports()[0].clone();
        assert_eq!(
            report.shard_events.iter().sum::<u64>(),
            1000,
            "histogram counts only traffic under the current cut"
        );
        // The telemetry plane exposes the new boundaries.
        assert_eq!(graph.node_bounds(0), vec![10, 64]);
        assert_eq!(graph.telemetry()[0].bounds, vec![10, 64]);
    }

    #[test]
    fn recut_rejects_invalid_targets() {
        let res = Resolution::new(64, 64);
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|_| PolarityFilter::keep(Polarity::On)).pinned())
            .then(StageSpec::new(|res: Resolution| BackgroundActivityFilter::new(res, 500)));
        let mut graph =
            StageGraph::compile(&spec, res, &StageOptions { shards: 3, shard_threads: false });
        let recut = |stage, bounds: Vec<u16>| Reconfigure::RecutStripes { stage, bounds };
        // Serial (pinned) stage, unknown stage, wrong shard count,
        // wrong terminal width, sub-halo stripe: all loud errors.
        assert!(graph.reconfigure(&recut(0, vec![32, 64])).is_err());
        assert!(graph.reconfigure(&recut(9, vec![32, 64])).is_err());
        assert!(graph.reconfigure(&recut(1, vec![32, 64])).is_err(), "3 shards, 2 bounds");
        assert!(graph.reconfigure(&recut(1, vec![10, 20, 60])).is_err(), "width 64");
        assert!(graph.reconfigure(&recut(1, vec![10, 10, 64])).is_err(), "empty stripe");
        // A valid re-cut still applies, and chunk changes are accepted
        // as a no-op at this layer.
        assert!(graph.reconfigure(&recut(1, vec![10, 20, 64])).is_ok());
        assert!(graph.reconfigure(&Reconfigure::ChunkSize(512)).is_ok());
        graph.finish_stages().unwrap();
        assert!(graph.reconfigure(&recut(1, vec![12, 24, 64])).is_err(), "finished");
    }

    /// The serial [`Pipeline`] processor accepts chunk changes (edge
    /// concern) but fails loudly on re-cuts it cannot honour.
    #[test]
    fn plain_pipeline_rejects_recuts() {
        let mut p = Pipeline::new();
        assert!(BatchProcessor::reconfigure(&mut p, &Reconfigure::ChunkSize(64)).is_ok());
        let recut = Reconfigure::RecutStripes { stage: 0, bounds: vec![32, 64] };
        assert!(BatchProcessor::reconfigure(&mut p, &recut).is_err());
        assert!(BatchProcessor::telemetry(&p).is_empty());
    }
}
