//! Epoch-based adaptive reconfiguration: controllers over the live
//! telemetry plane.
//!
//! A fixed stripe cut and a fixed chunk size are chosen before the
//! first event flows — but real event streams are spatially and
//! temporally bursty, so a hotspot saturates one shard while its
//! siblings idle (`shard_skew` measures exactly this; until now nothing
//! acted on it). This module closes the loop:
//!
//! * every *epoch* (a configurable number of processed batches) the
//!   topology driver samples the [`crate::metrics::LiveNode`] plane
//!   into an [`EpochSample`];
//! * each configured [`Controller`] inspects the sample and may issue
//!   [`Reconfigure`] actions — re-cut a sharded stage's stripe
//!   boundaries, or re-tune the edge chunk size;
//! * the driver applies them at the epoch barrier (between batches, so
//!   nothing is in flight), with
//!   [`StageGraph`](super::StageGraph) handing per-column state to the
//!   new owner shards via
//!   [`EventTransform::export_rows`](crate::pipeline::EventTransform::export_rows)
//!   / `import_rows` — output stays byte-identical to the serial
//!   pipeline across arbitrarily many re-cuts (property-tested per
//!   registered op).
//!
//! Three built-in controllers ship: [`SkewController`] re-cuts stripes
//! from the observed per-shard event histogram of the last epoch
//! (piecewise-uniform density model), [`ChunkController`] runs AIMD on
//! the chunk size targeting a backpressure/throughput balance, and
//! [`ClientWindowController`] runs the same AIMD core (shared in
//! [`aimd`]) on each serving-plane client's in-flight credit window.
//! All are deterministic functions of the samples. The applied history
//! (epochs, re-cuts with skew before/after, chunk changes, per-client
//! window changes) is surfaced in
//! [`StreamReport::adaptive`](super::StreamReport::adaptive).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context as _, Result};

use crate::metrics::{shard_skew_of, LiveNode};

use super::report::ReportEmitter;
use super::stage::BatchProcessor;
use super::ClientPlane;

/// One reconfiguration action a [`Controller`] may request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reconfigure {
    /// Replace sharded stage `stage`'s stripe boundaries. `bounds` are
    /// ascending stripe *end* columns (exclusive), one per shard, the
    /// last equal to the canvas width; every stripe must stay at least
    /// `max(halo, 1)` pixels wide so adjacent-stripe ghosting still
    /// covers every neighbourhood.
    RecutStripes {
        /// Stage index (position in the compiled graph).
        stage: usize,
        /// New stripe end columns.
        bounds: Vec<u16>,
    },
    /// Retarget the edge chunk size (events per batch). Applied to the
    /// fan-in merge and forwarded to sources that honour
    /// [`EventSource::set_chunk_hint`](super::EventSource::set_chunk_hint).
    ChunkSize(usize),
    /// Retarget a serving-plane client's in-flight credit window
    /// (events). Applied through the topology's attached
    /// [`ClientPlane`]s rather than the batch processor — windows live
    /// on the ingest edge, not in a stage.
    ClientWindow {
        /// Client node name (as published by its `LiveNode`).
        client: String,
        /// New window in events.
        window: usize,
    },
}

/// A sharded (or serial) stage node's live handle, surfaced by
/// [`BatchProcessor::telemetry`] for the driver to sample.
pub struct StageTelemetry {
    /// The stage's live counter cell.
    pub node: Arc<LiveNode>,
    /// Current stripe end columns (empty for serial nodes).
    pub bounds: Vec<u16>,
    /// The stage's declared halo (ghost radius).
    pub halo: u16,
}

/// Per-stage slice of an [`EpochSample`].
#[derive(Debug, Clone)]
pub struct StageSample {
    /// Stage index in the compiled graph.
    pub stage: usize,
    /// Stage description.
    pub name: String,
    /// Home events per shard **during this epoch** (drained from the
    /// live plane; empty for serial nodes).
    pub epoch_shard_events: Vec<u64>,
    /// Stripe end columns in force during the epoch (empty for serial
    /// nodes).
    pub bounds: Vec<u16>,
    /// Declared halo.
    pub halo: u16,
}

/// Per-client slice of an [`EpochSample`] (serving plane). Counters are
/// **epoch deltas**, computed by the driver from each client's
/// cumulative [`LiveNode`] totals.
#[derive(Debug, Clone)]
pub struct ClientSample {
    /// Client node name (`client:3`, `http:7`, …).
    pub name: String,
    /// Events accepted from this client during the epoch.
    pub events: u64,
    /// Ingest batches accepted during the epoch.
    pub batches: u64,
    /// Credit stalls (the client's reader blocked on a full window)
    /// during the epoch.
    pub backpressure_waits: u64,
    /// In-flight credit window in force at the sample point.
    pub window: usize,
}

/// What a [`Controller`] sees at each epoch barrier.
#[derive(Debug, Clone)]
pub struct EpochSample {
    /// Epoch index (0-based).
    pub epoch: u64,
    /// Batches processed during this epoch.
    pub batches: u64,
    /// Events that entered the edge during this epoch.
    pub events_in: u64,
    /// Producer full-queue suspensions during this epoch (the edge
    /// backpressure gauge).
    pub backpressure_waits: u64,
    /// `true` when the driver actually exposes a backpressure gauge
    /// (the coroutine drivers' bounded edge channel). The sync driver
    /// has no queue, so its waits are structurally zero — controllers
    /// keying off backpressure must treat that as "no signal", not
    /// "no congestion".
    pub backpressure_gauged: bool,
    /// Chunk size currently in force.
    pub chunk_size: usize,
    /// Per-stage telemetry.
    pub stages: Vec<StageSample>,
    /// Per-client telemetry from attached serving planes (empty when no
    /// listener node is running).
    pub clients: Vec<ClientSample>,
}

/// An adaptive policy: observes one [`EpochSample`] per epoch and may
/// request reconfigurations. Controllers run in configuration order;
/// their actions apply at the same epoch barrier.
pub trait Controller: Send {
    /// Inspect the epoch's telemetry; return any reconfigurations.
    fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure>;

    /// Human-readable description (reports, logs).
    fn describe(&self) -> String;
}

// ----------------------------------------------------------------- aimd

/// The additive-increase / multiplicative-decrease core shared by every
/// backpressure-keyed tuner ([`ChunkController`] for the edge chunk,
/// [`ClientWindowController`] for serving-plane credit windows).
pub mod aimd {
    /// AIMD policy parameters plus the decision function: an epoch
    /// whose waits-per-batch rate exceeds `pressure` is congested and
    /// halves the controlled value (floored at `min`); a quiet epoch
    /// grows it by `step` (capped at `max`).
    #[derive(Debug, Clone, Copy, PartialEq)]
    pub struct Aimd {
        /// Floor for the controlled value.
        pub min: usize,
        /// Ceiling for the controlled value.
        pub max: usize,
        /// Additive-increase step per quiet epoch.
        pub step: usize,
        /// Waits-per-batch above which an epoch counts as congested.
        pub pressure: f64,
    }

    impl Aimd {
        /// Policy with repaired-sane bounds (`min ≥ 1`, `max ≥ min`,
        /// `step ≥ 1`).
        pub fn new(min: usize, max: usize, step: usize, pressure: f64) -> Self {
            let min = min.max(1);
            Aimd { min, max: max.max(min), step: step.max(1), pressure }
        }

        /// `true` when the epoch's wait rate crosses the pressure bar.
        pub fn congested(&self, waits: u64, batches: u64) -> bool {
            waits as f64 / batches.max(1) as f64 > self.pressure
        }

        /// Next value for `current` given the epoch's wait/batch counts.
        pub fn next(&self, current: usize, waits: u64, batches: u64) -> usize {
            let next = if self.congested(waits, batches) {
                (current / 2).max(self.min)
            } else {
                (current + self.step).min(self.max)
            };
            next.clamp(self.min, self.max)
        }
    }
}

pub use aimd::Aimd;

// ------------------------------------------------------------ controllers

/// Re-cuts a sharded stage's stripes whenever the epoch's shard-event
/// histogram is skewed past a threshold. The new boundaries equalize
/// load under a piecewise-uniform density model (events spread evenly
/// within each old stripe), which converges on stable hotspots in a
/// few epochs. A cut is only issued when the model predicts an actual
/// improvement — integer column rounding on very narrow stripes can
/// otherwise produce a nominally rebalanced cut that the model itself
/// scores worse, and re-issuing it every epoch would churn workers for
/// nothing.
pub struct SkewController {
    /// Minimum observed epoch skew (max/mean) that triggers a re-cut.
    threshold: f64,
}

impl Default for SkewController {
    fn default() -> Self {
        SkewController { threshold: 1.25 }
    }
}

impl SkewController {
    /// Controller with an explicit skew threshold (≥ 1).
    pub fn with_threshold(threshold: f64) -> Self {
        SkewController { threshold: threshold.max(1.0) }
    }
}

impl Controller for SkewController {
    fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
        let mut out = Vec::new();
        for stage in &sample.stages {
            if stage.bounds.len() < 2 {
                continue;
            }
            let skew = shard_skew_of(&stage.epoch_shard_events);
            if skew < self.threshold {
                continue;
            }
            let min_width = stage.halo.max(1);
            let bounds =
                rebalance_bounds(&stage.bounds, &stage.epoch_shard_events, min_width);
            let predicted = rebin_skew(&stage.bounds, &stage.epoch_shard_events, &bounds);
            if bounds != stage.bounds && predicted < skew {
                out.push(Reconfigure::RecutStripes { stage: stage.stage, bounds });
            }
        }
        out
    }

    fn describe(&self) -> String {
        format!("skew(threshold {:.2})", self.threshold)
    }
}

/// AIMD chunk-size tuner. Backpressure waits on the edge channel mean
/// the producer keeps suspending on a full queue — the consumer is the
/// bottleneck and bigger batches only add latency and resident memory,
/// so the chunk halves (multiplicative decrease). A quiet epoch means
/// the edge has headroom, so the chunk grows by a fixed step (additive
/// increase) to amortize per-batch overhead. Clamped to `[min, max]`
/// by the shared [`Aimd`] core. Inert under drivers with no
/// backpressure gauge (the sync loop): zero waits there mean "no
/// signal", and acting on them would march the chunk unconditionally
/// to the ceiling.
pub struct ChunkController {
    aimd: Aimd,
}

impl Default for ChunkController {
    fn default() -> Self {
        ChunkController { aimd: Aimd::new(256, 65_536, 512, 0.5) }
    }
}

impl ChunkController {
    /// Tuner with explicit clamp bounds.
    pub fn with_bounds(min: usize, max: usize) -> Self {
        let d = Self::default();
        ChunkController { aimd: Aimd::new(min, max, d.aimd.step, d.aimd.pressure) }
    }
}

impl Controller for ChunkController {
    fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
        if !sample.backpressure_gauged {
            return Vec::new();
        }
        let next =
            self.aimd.next(sample.chunk_size, sample.backpressure_waits, sample.batches);
        if next == sample.chunk_size {
            Vec::new()
        } else {
            vec![Reconfigure::ChunkSize(next)]
        }
    }

    fn describe(&self) -> String {
        format!("chunk(AIMD {}..{})", self.aimd.min, self.aimd.max)
    }
}

/// Per-client AIMD window tuner for the serving plane. Each attached
/// client owns a credit window bounding its events in flight between
/// reader thread and merge. Credit stalls mean the trunk isn't
/// draining that client fast enough — halve its window so one firehose
/// cannot monopolize merge buffering; a quiet active client grows
/// additively back toward the ceiling; idle clients (no batches, no
/// stalls) are left alone. Windows apply through the topology's
/// attached [`ClientPlane`]s, and every change lands in
/// [`AdaptiveReport::window_changes`]. Unlike [`ChunkController`] this
/// needs no coroutine backpressure gauge: credit stalls are counted by
/// the client readers themselves, under any driver.
pub struct ClientWindowController {
    aimd: Aimd,
}

impl Default for ClientWindowController {
    fn default() -> Self {
        ClientWindowController { aimd: Aimd::new(64, 65_536, 256, 0.5) }
    }
}

impl ClientWindowController {
    /// Tuner with explicit window bounds.
    pub fn with_bounds(min: usize, max: usize) -> Self {
        let d = Self::default();
        ClientWindowController { aimd: Aimd::new(min, max, d.aimd.step, d.aimd.pressure) }
    }
}

impl Controller for ClientWindowController {
    fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
        let mut out = Vec::new();
        for client in &sample.clients {
            if client.batches == 0 && client.backpressure_waits == 0 {
                continue;
            }
            let next =
                self.aimd.next(client.window, client.backpressure_waits, client.batches);
            if next != client.window {
                out.push(Reconfigure::ClientWindow {
                    client: client.name.clone(),
                    window: next,
                });
            }
        }
        out
    }

    fn describe(&self) -> String {
        format!("client-window(AIMD {}..{})", self.aimd.min, self.aimd.max)
    }
}

// ---------------------------------------------------------- configuration

/// A controller nameable from the CLI (`--adaptive skew,chunk,…`): the
/// two built-ins, or any third-party controller registered through
/// [`registry::register_controller`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ControllerKind {
    /// [`SkewController`] with defaults.
    Skew,
    /// [`ChunkController`] with defaults.
    Chunk,
    /// [`ClientWindowController`] with defaults.
    ClientWindow,
    /// A controller resolved by name through [`registry`] at build time
    /// (so a config stays a plain cloneable value while the factory
    /// lives in the registry).
    Custom(String),
}

impl ControllerKind {
    /// Instantiate the controller. Built-ins never fail; a
    /// [`Custom`](ControllerKind::Custom) name fails if it was
    /// unregistered between parse and build.
    pub fn build(&self) -> Result<Box<dyn Controller>> {
        match self {
            ControllerKind::Skew => Ok(Box::new(SkewController::default())),
            ControllerKind::Chunk => Ok(Box::new(ChunkController::default())),
            ControllerKind::ClientWindow => Ok(Box::new(ClientWindowController::default())),
            ControllerKind::Custom(name) => registry::build(name),
        }
    }
}

/// Parse a CLI controller list: `"skew"`, `"chunk"`, `"skew,chunk"`, or
/// any name registered through [`registry::register_controller`] —
/// third-party controllers resolve end to end from `--adaptive`.
pub fn parse_controllers(s: &str) -> Result<Vec<ControllerKind>> {
    let mut kinds = Vec::new();
    for name in s.split(',') {
        let kind = match name.trim() {
            "skew" => ControllerKind::Skew,
            "chunk" => ControllerKind::Chunk,
            "client-window" => ControllerKind::ClientWindow,
            other if registry::is_registered(other) => {
                ControllerKind::Custom(other.to_string())
            }
            other => bail!(
                "unknown controller {other:?} (known: {})",
                registry::registered_names().join("|")
            ),
        };
        if !kinds.contains(&kind) {
            kinds.push(kind);
        }
    }
    if kinds.is_empty() {
        bail!("--adaptive needs at least one controller (skew|chunk|client-window)");
    }
    Ok(kinds)
}

/// The pluggable controller registry: a public registration path for
/// third-party [`Controller`] implementations, so custom policies work
/// end to end — `register_controller("mine", …)` once at startup, then
/// `--adaptive mine` on the CLI or
/// [`ControllerKind::Custom`]`("mine")` in an [`AdaptiveConfig`].
/// Before this, custom controllers could only ride
/// [`run_topology_with_adaptive`](super::run_topology_with_adaptive)
/// by hand.
pub mod registry {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};

    use anyhow::{bail, Result};

    use super::Controller;

    type Factory = Arc<dyn Fn() -> Box<dyn Controller> + Send + Sync>;

    fn table() -> &'static Mutex<HashMap<String, Factory>> {
        static TABLE: OnceLock<Mutex<HashMap<String, Factory>>> = OnceLock::new();
        TABLE.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Register a controller factory under `name`. The name becomes
    /// valid in `--adaptive` lists and
    /// [`parse_controllers`](super::parse_controllers). Built-in names
    /// (`skew`, `chunk`, `client-window`) are reserved and duplicates
    /// are rejected —
    /// registration is global and process-wide, so collisions should be
    /// loud, not last-write-wins.
    pub fn register_controller<F>(name: &str, factory: F) -> Result<()>
    where
        F: Fn() -> Box<dyn Controller> + Send + Sync + 'static,
    {
        let name = name.trim();
        if name.is_empty() {
            bail!("controller name cannot be empty");
        }
        if matches!(name, "skew" | "chunk" | "client-window") {
            bail!("controller name {name:?} is reserved for a built-in");
        }
        let mut table = table().lock().unwrap();
        if table.contains_key(name) {
            bail!("controller {name:?} is already registered");
        }
        table.insert(name.to_string(), Arc::new(factory));
        Ok(())
    }

    /// `true` when `name` resolves — a built-in or a registered custom.
    pub fn is_registered(name: &str) -> bool {
        matches!(name, "skew" | "chunk" | "client-window")
            || table().lock().unwrap().contains_key(name)
    }

    /// Every resolvable name, built-ins first, customs sorted.
    pub fn registered_names() -> Vec<String> {
        let mut names =
            vec!["skew".to_string(), "chunk".to_string(), "client-window".to_string()];
        let mut custom: Vec<String> = table().lock().unwrap().keys().cloned().collect();
        custom.sort();
        names.extend(custom);
        names
    }

    /// Instantiate a controller by name (built-in or registered).
    pub fn build(name: &str) -> Result<Box<dyn Controller>> {
        match name {
            "skew" => Ok(Box::new(super::SkewController::default())),
            "chunk" => Ok(Box::new(super::ChunkController::default())),
            "client-window" => Ok(Box::new(super::ClientWindowController::default())),
            other => {
                let factory = table().lock().unwrap().get(other).cloned();
                match factory {
                    Some(factory) => Ok(factory()),
                    None => bail!(
                        "controller {other:?} is not registered (known: {})",
                        registered_names().join(", ")
                    ),
                }
            }
        }
    }
}

/// Declarative adaptive configuration (clonable: lives inside
/// [`TopologyConfig`](super::TopologyConfig)).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdaptiveConfig {
    /// Controllers to run, in order.
    pub controllers: Vec<ControllerKind>,
    /// Batches per epoch (sampling period).
    pub epoch_batches: u64,
}

/// Default batches per epoch for `--adaptive` without `--epoch`.
pub const DEFAULT_EPOCH_BATCHES: u64 = 32;

impl AdaptiveConfig {
    /// Config running `controllers` at the default epoch length.
    pub fn new(controllers: Vec<ControllerKind>) -> Self {
        AdaptiveConfig { controllers, epoch_batches: DEFAULT_EPOCH_BATCHES }
    }

    /// Builder-style epoch override.
    pub fn with_epoch(mut self, epoch_batches: u64) -> Self {
        self.epoch_batches = epoch_batches.max(1);
        self
    }

    /// Instantiate the configured controllers (fails when a
    /// [`ControllerKind::Custom`] name is no longer registered).
    pub fn build(&self) -> Result<AdaptiveRuntime> {
        Ok(AdaptiveRuntime {
            epoch_batches: self.epoch_batches.max(1),
            controllers: self
                .controllers
                .iter()
                .map(ControllerKind::build)
                .collect::<Result<_>>()?,
        })
    }
}

/// Instantiated controllers plus their sampling period — what
/// [`run_topology_with_adaptive`](super::run_topology_with_adaptive)
/// consumes. Build one from an [`AdaptiveConfig`], or assemble custom
/// [`Controller`]s directly (tests force re-cuts this way).
pub struct AdaptiveRuntime {
    /// Batches per epoch.
    pub epoch_batches: u64,
    /// Controllers, run in order at every epoch barrier.
    pub controllers: Vec<Box<dyn Controller>>,
}

// -------------------------------------------------------------- history

/// One applied stripe re-cut.
#[derive(Debug, Clone)]
pub struct RecutRecord {
    /// Epoch at whose barrier the re-cut applied.
    pub epoch: u64,
    /// Stage index.
    pub stage: usize,
    /// Observed skew of the epoch's shard histogram under the old cut.
    pub skew_before: f64,
    /// Predicted skew of the same histogram re-binned under the new cut
    /// (piecewise-uniform density; the next epoch measures the real
    /// value).
    pub skew_after: f64,
    /// The new stripe end columns.
    pub bounds: Vec<u16>,
}

/// One applied chunk-size change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkChange {
    /// Epoch at whose barrier the change applied.
    pub epoch: u64,
    /// Chunk size before.
    pub from: usize,
    /// Chunk size after.
    pub to: usize,
}

/// One applied per-client window change (serving plane).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WindowChange {
    /// Epoch at whose barrier the change applied.
    pub epoch: u64,
    /// Client node name.
    pub client: String,
    /// Window before (events).
    pub from: usize,
    /// Window after (events).
    pub to: usize,
}

/// Reconfiguration history of one adaptive run, surfaced in
/// [`StreamReport::adaptive`](super::StreamReport::adaptive).
#[derive(Debug, Clone, Default)]
pub struct AdaptiveReport {
    /// Completed epochs (controller sampling rounds).
    pub epochs: u64,
    /// Applied stripe re-cuts, in order.
    pub recuts: Vec<RecutRecord>,
    /// Applied chunk-size changes, in order.
    pub chunk_changes: Vec<ChunkChange>,
    /// Applied per-client window changes, in order.
    pub window_changes: Vec<WindowChange>,
    /// Chunk size in force when the stream ended.
    pub final_chunk: usize,
}

// -------------------------------------------------------------- adaptor

/// Driver-side epoch loop: counts batches, samples the plane at every
/// epoch barrier, runs the controllers, applies their actions, and
/// keeps the history. One per adaptive run, owned by whichever driver
/// loop processes batches (sync loop, coroutine consumer, or fan-out
/// router — all single-threaded with respect to the processor).
pub(crate) struct Adaptor {
    controllers: Vec<Box<dyn Controller>>,
    epoch_batches: u64,
    batches_in_epoch: u64,
    last_events_in: u64,
    last_waits: u64,
    chunk: usize,
    /// Whether the driving loop's backpressure totals are a real gauge
    /// (coroutine edge channel) or structurally zero (sync loop).
    backpressure_gauged: bool,
    /// Serving planes whose clients are sampled and window-tuned.
    planes: Vec<Arc<dyn ClientPlane>>,
    /// Cumulative (events, batches, waits) per client at the last
    /// epoch, for delta computation.
    last_clients: HashMap<String, (u64, u64, u64)>,
    /// Per-epoch JSON line sink (`--report-json`).
    emitter: Option<Arc<ReportEmitter>>,
    report: AdaptiveReport,
}

impl Adaptor {
    pub(crate) fn new(
        runtime: AdaptiveRuntime,
        initial_chunk: usize,
        backpressure_gauged: bool,
    ) -> Self {
        Adaptor {
            controllers: runtime.controllers,
            epoch_batches: runtime.epoch_batches.max(1),
            batches_in_epoch: 0,
            last_events_in: 0,
            last_waits: 0,
            chunk: initial_chunk.max(1),
            backpressure_gauged,
            planes: Vec::new(),
            last_clients: HashMap::new(),
            emitter: None,
            report: AdaptiveReport::default(),
        }
    }

    /// Attach the serving planes discovered on the merged source, so
    /// epochs sample their clients and window changes reach them.
    pub(crate) fn set_planes(&mut self, planes: Vec<Arc<dyn ClientPlane>>) {
        self.planes = planes;
    }

    /// Stream one JSON line per epoch through `emitter`.
    pub(crate) fn set_emitter(&mut self, emitter: Arc<ReportEmitter>) {
        self.emitter = Some(emitter);
    }

    /// Account one processed batch; at an epoch barrier, sample, run
    /// the controllers, and apply their actions to `processor`.
    /// `events_in`/`backpressure_waits` are the edge's running totals.
    /// Returns the new chunk size when a controller changed it (the
    /// caller forwards it to the source side).
    pub(crate) fn after_batch<P: BatchProcessor + ?Sized>(
        &mut self,
        processor: &mut P,
        events_in: u64,
        backpressure_waits: u64,
    ) -> Result<Option<usize>> {
        self.batches_in_epoch += 1;
        if self.batches_in_epoch < self.epoch_batches {
            return Ok(None);
        }
        let epoch = self.report.epochs;
        let stages: Vec<StageSample> = processor
            .telemetry()
            .into_iter()
            .enumerate()
            .map(|(i, t)| StageSample {
                stage: i,
                name: t.node.name().to_string(),
                epoch_shard_events: t.node.take_epoch_shards(),
                bounds: t.bounds,
                halo: t.halo,
            })
            .collect();
        let mut clients = Vec::new();
        for plane in &self.planes {
            for c in plane.client_samples() {
                let last = self.last_clients.get(&c.name).copied().unwrap_or((0, 0, 0));
                self.last_clients
                    .insert(c.name.clone(), (c.events, c.batches, c.backpressure_waits));
                clients.push(ClientSample {
                    events: c.events.saturating_sub(last.0),
                    batches: c.batches.saturating_sub(last.1),
                    backpressure_waits: c.backpressure_waits.saturating_sub(last.2),
                    ..c
                });
            }
        }
        let sample = EpochSample {
            epoch,
            batches: self.batches_in_epoch,
            events_in: events_in.saturating_sub(self.last_events_in),
            backpressure_waits: backpressure_waits.saturating_sub(self.last_waits),
            backpressure_gauged: self.backpressure_gauged,
            chunk_size: self.chunk,
            stages,
            clients,
        };
        let mut new_chunk = None;
        for controller in &mut self.controllers {
            for change in controller.observe(&sample) {
                match &change {
                    Reconfigure::RecutStripes { stage, bounds } => {
                        let observed = sample
                            .stages
                            .iter()
                            .find(|s| s.stage == *stage)
                            .with_context(|| {
                                format!(
                                    "controller {} re-cut unknown stage {stage}",
                                    controller.describe()
                                )
                            })?;
                        let skew_before = shard_skew_of(&observed.epoch_shard_events);
                        let skew_after = rebin_skew(
                            &observed.bounds,
                            &observed.epoch_shard_events,
                            bounds,
                        );
                        processor.reconfigure(&change).with_context(|| {
                            format!("applying re-cut from {}", controller.describe())
                        })?;
                        self.report.recuts.push(RecutRecord {
                            epoch,
                            stage: *stage,
                            skew_before,
                            skew_after,
                            bounds: bounds.clone(),
                        });
                    }
                    Reconfigure::ChunkSize(n) => {
                        let n = (*n).max(1);
                        if n != self.chunk {
                            processor.reconfigure(&change).with_context(|| {
                                format!("applying chunk from {}", controller.describe())
                            })?;
                            self.report.chunk_changes.push(ChunkChange {
                                epoch,
                                from: self.chunk,
                                to: n,
                            });
                            self.chunk = n;
                            new_chunk = Some(n);
                        }
                    }
                    Reconfigure::ClientWindow { client, window } => {
                        let window = (*window).max(1);
                        let from = sample
                            .clients
                            .iter()
                            .find(|c| &c.name == client)
                            .map(|c| c.window);
                        // A client may detach between sample and apply;
                        // unknown names are skipped, not errors.
                        let applied =
                            self.planes.iter().any(|p| p.set_window(client, window));
                        if let Some(from) = from {
                            if applied && from != window {
                                self.report.window_changes.push(WindowChange {
                                    epoch,
                                    client: client.clone(),
                                    from,
                                    to: window,
                                });
                            }
                        }
                    }
                }
            }
        }
        if let Some(emitter) = &self.emitter {
            emitter.emit_epoch(&sample)?;
        }
        self.report.epochs += 1;
        self.batches_in_epoch = 0;
        self.last_events_in = events_in;
        self.last_waits = backpressure_waits;
        Ok(new_chunk)
    }

    /// Close out the run and return the history.
    pub(crate) fn finish(mut self) -> AdaptiveReport {
        self.report.final_chunk = self.chunk;
        self.report
    }
}

// ---------------------------------------------------------- cut algebra

/// Piecewise-linear cumulative mass of `counts` over the stripes ending
/// at `bounds`, evaluated at column `x` (events spread uniformly within
/// each stripe).
fn cumulative_at(bounds: &[u16], counts: &[u64], x: u16) -> f64 {
    let mut acc = 0.0;
    let mut lo = 0u16;
    for (&hi, &c) in bounds.iter().zip(counts) {
        if x >= hi {
            acc += c as f64;
        } else {
            if x > lo && hi > lo {
                acc += c as f64 * f64::from(x - lo) / f64::from(hi - lo);
            }
            break;
        }
        lo = hi;
    }
    acc
}

/// Equal-load stripe boundaries from an observed per-stripe histogram,
/// under a piecewise-uniform density model. Keeps the shard count and
/// total width; every stripe stays at least `min_width` wide. Returns
/// the old bounds unchanged when the histogram is empty or the canvas
/// cannot fit `m` stripes of `min_width`.
pub(crate) fn rebalance_bounds(bounds: &[u16], counts: &[u64], min_width: u16) -> Vec<u16> {
    let m = bounds.len();
    let width = match bounds.last() {
        Some(&w) => w,
        None => return Vec::new(),
    };
    let total: u64 = counts.iter().sum();
    let min_width = min_width.max(1);
    if m <= 1
        || counts.len() != m
        || total == 0
        || (width as usize) < m * min_width as usize
    {
        return bounds.to_vec();
    }
    // Cut at the histogram's m-quantiles.
    let mut out = Vec::with_capacity(m);
    let mut prefix = 0.0f64;
    let mut lo = 0u16;
    let mut stripe = 0usize;
    for k in 1..m {
        let target = total as f64 * k as f64 / m as f64;
        while stripe < m - 1 && prefix + counts[stripe] as f64 < target {
            prefix += counts[stripe] as f64;
            lo = bounds[stripe];
            stripe += 1;
        }
        let hi = bounds[stripe];
        let c = counts[stripe] as f64;
        let frac = if c > 0.0 { ((target - prefix) / c).clamp(0.0, 1.0) } else { 1.0 };
        let x = f64::from(lo) + frac * f64::from(hi - lo);
        out.push(x.round() as u16);
    }
    out.push(width);
    // Enforce the minimum stripe width: cap from the right so the tail
    // stripes fit, then floor from the left so widths stay positive.
    for k in (0..m - 1).rev() {
        let cap = width - (m - 1 - k) as u16 * min_width;
        if out[k] > cap {
            out[k] = cap;
        }
    }
    let mut prev = 0u16;
    for b in out.iter_mut().take(m - 1) {
        if *b < prev + min_width {
            *b = prev + min_width;
        }
        prev = *b;
    }
    // A clamp conflict (cannot happen when width ≥ m·min_width, checked
    // above) would surface as a non-ascending cut: refuse rather than
    // emit an invalid one.
    let ascending = out.windows(2).all(|w| w[0] < w[1]) && out[0] >= min_width;
    if ascending {
        out
    } else {
        bounds.to_vec()
    }
}

/// Predicted skew of an observed histogram re-binned under new stripe
/// boundaries (piecewise-uniform density within each old stripe).
pub(crate) fn rebin_skew(old_bounds: &[u16], counts: &[u64], new_bounds: &[u16]) -> f64 {
    if new_bounds.is_empty() {
        return 0.0;
    }
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mut masses = Vec::with_capacity(new_bounds.len());
    let mut lo = 0u16;
    for &hi in new_bounds {
        let mass = cumulative_at(old_bounds, counts, hi) - cumulative_at(old_bounds, counts, lo);
        masses.push(mass.max(0.0));
        lo = hi;
    }
    let mean = total as f64 / masses.len() as f64;
    let max = masses.iter().cloned().fold(0.0f64, f64::max);
    max / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage_sample(bounds: Vec<u16>, hist: Vec<u64>, halo: u16) -> EpochSample {
        EpochSample {
            epoch: 0,
            batches: 10,
            events_in: hist.iter().sum(),
            backpressure_waits: 0,
            backpressure_gauged: true,
            chunk_size: 4096,
            stages: vec![StageSample {
                stage: 0,
                name: "stage".into(),
                epoch_shard_events: hist,
                bounds,
                halo,
            }],
            clients: Vec::new(),
        }
    }

    #[test]
    fn rebalance_moves_boundaries_toward_the_hotspot() {
        // 90% of traffic in the left stripe: the boundary must move
        // left so the right stripe absorbs part of the hot region.
        let new = rebalance_bounds(&[32, 64], &[90, 10], 1);
        assert_eq!(new.len(), 2);
        assert_eq!(*new.last().unwrap(), 64, "total width preserved");
        assert!(new[0] < 32, "boundary must move into the hot stripe, got {new:?}");
        // The predicted skew under the new cut improves on the observed.
        let before = shard_skew_of(&[90, 10]);
        let after = rebin_skew(&[32, 64], &[90, 10], &new);
        assert!(after < before, "predicted {after} must beat observed {before}");
        assert!(after < 1.1, "piecewise model should nearly equalize, got {after}");
    }

    #[test]
    fn rebalance_keeps_min_width_and_degenerate_inputs() {
        // All-zero histogram: no information, no re-cut.
        assert_eq!(rebalance_bounds(&[16, 32], &[0, 0], 1), vec![16, 32]);
        // Extreme histogram with a wide min width: stripes stay legal.
        let new = rebalance_bounds(&[8, 16, 24, 32], &[1000, 0, 0, 0], 4);
        let mut lo = 0u16;
        for &hi in &new {
            assert!(hi - lo >= 4, "stripe [{lo},{hi}) below min width in {new:?}");
            lo = hi;
        }
        assert_eq!(lo, 32);
        // A canvas too narrow for m stripes of min width: unchanged.
        assert_eq!(rebalance_bounds(&[2, 4, 5], &[9, 9, 9], 2), vec![2, 4, 5]);
    }

    #[test]
    fn skew_controller_recuts_only_past_threshold() {
        let mut ctl = SkewController::with_threshold(1.5);
        // Balanced: no action.
        assert!(ctl.observe(&stage_sample(vec![32, 64], vec![50, 50], 1)).is_empty());
        // Serial stage: never acted on.
        assert!(ctl.observe(&stage_sample(Vec::new(), Vec::new(), 0)).is_empty());
        // Skewed: one re-cut for the right stage.
        let actions = ctl.observe(&stage_sample(vec![32, 64], vec![95, 5], 1));
        assert_eq!(actions.len(), 1);
        match &actions[0] {
            Reconfigure::RecutStripes { stage, bounds } => {
                assert_eq!(*stage, 0);
                assert!(bounds[0] < 32);
                assert_eq!(bounds[1], 64);
            }
            other => panic!("expected a re-cut, got {other:?}"),
        }
    }

    #[test]
    fn chunk_controller_runs_aimd() {
        let mut ctl = ChunkController::with_bounds(256, 8192);
        // Quiet epoch: additive increase.
        let mut sample = stage_sample(Vec::new(), Vec::new(), 0);
        sample.chunk_size = 1024;
        assert_eq!(ctl.observe(&sample), vec![Reconfigure::ChunkSize(1024 + 512)]);
        // Congested epoch: multiplicative decrease.
        sample.backpressure_waits = sample.batches; // 1 wait per batch
        assert_eq!(ctl.observe(&sample), vec![Reconfigure::ChunkSize(512)]);
        // Clamps hold at both ends.
        sample.chunk_size = 300;
        assert_eq!(ctl.observe(&sample), vec![Reconfigure::ChunkSize(256)]);
        sample.chunk_size = 256;
        assert!(ctl.observe(&sample).is_empty(), "already at the floor");
        sample.backpressure_waits = 0;
        sample.chunk_size = 8192;
        assert!(ctl.observe(&sample).is_empty(), "already at the ceiling");
        // No gauge (sync driver): zero waits mean "no signal", so the
        // tuner must sit still instead of marching to the ceiling.
        sample.chunk_size = 1024;
        sample.backpressure_gauged = false;
        assert!(ctl.observe(&sample).is_empty(), "ungauged drivers get no tuning");
    }

    #[test]
    fn aimd_core_is_shared_and_clamped() {
        let a = Aimd::new(64, 1024, 128, 0.5);
        assert_eq!(a.next(512, 0, 10), 640, "quiet: additive increase");
        assert_eq!(a.next(512, 10, 10), 256, "congested: halve");
        assert_eq!(a.next(100, 10, 10), 64, "floor holds");
        assert_eq!(a.next(1000, 0, 10), 1024, "ceiling holds");
        assert!(!a.congested(5, 10), "exactly at pressure is not congested");
        assert!(a.congested(6, 10));
        // Degenerate bounds are repaired, not trusted.
        let b = Aimd::new(0, 0, 0, 0.5);
        assert_eq!((b.min, b.max, b.step), (1, 1, 1));
    }

    #[test]
    fn client_window_controller_tunes_per_client() {
        let mut ctl = ClientWindowController::with_bounds(64, 8192);
        let mut sample = stage_sample(Vec::new(), Vec::new(), 0);
        sample.clients = vec![
            ClientSample {
                name: "client:0".into(),
                events: 10_000,
                batches: 10,
                backpressure_waits: 9,
                window: 4096,
            },
            ClientSample {
                name: "client:1".into(),
                events: 500,
                batches: 10,
                backpressure_waits: 0,
                window: 1024,
            },
            ClientSample {
                name: "client:2".into(),
                events: 0,
                batches: 0,
                backpressure_waits: 0,
                window: 1024,
            },
        ];
        let actions = ctl.observe(&sample);
        assert_eq!(
            actions,
            vec![
                Reconfigure::ClientWindow { client: "client:0".into(), window: 2048 },
                Reconfigure::ClientWindow { client: "client:1".into(), window: 1280 },
            ],
            "stalled client halves, quiet client grows, idle client is untouched"
        );
        // No clients, no actions — the controller is inert off the
        // serving plane (and safe to leave in a default list).
        sample.clients.clear();
        assert!(ctl.observe(&sample).is_empty());
    }

    #[test]
    fn client_window_is_a_reserved_built_in() {
        assert_eq!(
            parse_controllers("client-window").unwrap(),
            vec![ControllerKind::ClientWindow]
        );
        assert!(registry::is_registered("client-window"));
        assert!(registry::register_controller("client-window", || {
            Box::new(ClientWindowController::default())
        })
        .is_err());
        let rt = AdaptiveConfig::new(vec![ControllerKind::ClientWindow]).build().unwrap();
        assert!(rt.controllers[0].describe().starts_with("client-window"));
    }

    #[test]
    fn controller_lists_parse() {
        assert_eq!(parse_controllers("skew").unwrap(), vec![ControllerKind::Skew]);
        assert_eq!(
            parse_controllers("skew,chunk").unwrap(),
            vec![ControllerKind::Skew, ControllerKind::Chunk]
        );
        assert_eq!(
            parse_controllers("chunk, skew, chunk").unwrap(),
            vec![ControllerKind::Chunk, ControllerKind::Skew],
            "duplicates collapse, order of first mention wins"
        );
        assert!(parse_controllers("vibes").is_err());
        assert!(parse_controllers("").is_err());
    }

    #[test]
    fn adaptive_config_builds_runtime() {
        let cfg = AdaptiveConfig::new(parse_controllers("skew,chunk").unwrap()).with_epoch(4);
        let rt = cfg.build().unwrap();
        assert_eq!(rt.epoch_batches, 4);
        assert_eq!(rt.controllers.len(), 2);
        assert!(rt.controllers[0].describe().starts_with("skew"));
        assert!(rt.controllers[1].describe().starts_with("chunk"));
    }

    /// The registry closes the pluggable-controller loop: a registered
    /// name parses from a CLI-style list, builds through
    /// [`ControllerKind::Custom`], and bad names stay loud.
    #[test]
    fn registry_round_trips_custom_controllers() {
        struct Fixed;
        impl Controller for Fixed {
            fn observe(&mut self, _sample: &EpochSample) -> Vec<Reconfigure> {
                vec![Reconfigure::ChunkSize(512)]
            }
            fn describe(&self) -> String {
                "fixed(512)".into()
            }
        }
        registry::register_controller("fixed-512", || Box::new(Fixed)).unwrap();
        // Reserved and duplicate names are rejected.
        assert!(registry::register_controller("skew", || Box::new(Fixed)).is_err());
        assert!(registry::register_controller("fixed-512", || Box::new(Fixed)).is_err());
        assert!(registry::register_controller("", || Box::new(Fixed)).is_err());
        assert!(registry::is_registered("fixed-512"));
        assert!(registry::registered_names().contains(&"fixed-512".to_string()));
        // CLI-style parse resolves the custom name.
        let kinds = parse_controllers("fixed-512,chunk").unwrap();
        assert_eq!(kinds[0], ControllerKind::Custom("fixed-512".into()));
        assert_eq!(kinds[1], ControllerKind::Chunk);
        // And builds into a working runtime.
        let rt = AdaptiveConfig::new(kinds).with_epoch(2).build().unwrap();
        assert_eq!(rt.controllers.len(), 2);
        assert_eq!(rt.controllers[0].describe(), "fixed(512)");
        // Unknown names fail at parse with the known set listed.
        let err = format!("{}", parse_controllers("psychic").unwrap_err());
        assert!(err.contains("skew") && err.contains("fixed-512"), "got {err}");
        // An unregistered custom kind fails at build, not silently.
        assert!(ControllerKind::Custom("never-registered".into()).build().is_err());
    }
}
