//! `aestream` binary: the paper's CLI (Fig. 2B) plus the Fig. 4
//! scenario runner.

use anyhow::Result;

use aestream::bench::{fmt_rate, Table};
use aestream::camera;
use aestream::cli::{self, Command};
use aestream::coordinator::{run_graph, run_scenario, ScenarioConfig, TopologyOptions};
use aestream::pipeline::registry;
use aestream::runtime::Device;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match cli::parse(&args)? {
        Command::Help => {
            print!("{}", cli::USAGE);
            print!("{}", cli::filters_help());
        }
        Command::Table1 => {
            print!("{}", registry::render_table());
        }
        Command::Stream {
            inputs,
            spec,
            branches,
            config,
            threads,
            route,
            layout,
            shards,
            shard_threads,
            sink_threads,
            adaptive,
            report_json,
            decode_threads,
            buffer,
        } => {
            let multi = inputs.len() > 1 || branches.len() > 1;
            let branched = branches.iter().any(|b| !b.spec.is_empty());
            let staged = (!spec.is_empty() || branched) && (shards > 1 || shard_threads);
            let report = run_graph(
                inputs,
                spec,
                branches,
                TopologyOptions {
                    config,
                    source_threads: threads > 1,
                    route,
                    layout,
                    shards,
                    shard_threads,
                    sink_threads,
                    adaptive,
                    report_json,
                    decode_threads,
                    buffer,
                },
            )?;
            eprintln!(
                "processed {} events ({} out) in {:?} ({}) [{}x{}] — {} batches, \
                 peak {} in flight, {} backpressure waits",
                report.events_in,
                report.events_out,
                report.wall,
                fmt_rate(report.throughput(), "ev/s"),
                report.resolution.width,
                report.resolution.height,
                report.batches,
                report.peak_in_flight,
                report.backpressure_waits,
            );
            if report.decode_workers > 0 {
                eprintln!(
                    "  decode: {} workers / {} jobs, peak queue {}, peak busy {}, \
                     peak reassembly lag {}",
                    report.decode_workers,
                    report.decode_jobs,
                    report.decode_queue_depth,
                    report.decode_worker_busy,
                    report.decode_reassembly_lag,
                );
            }
            if report.buffer_bytes_on_disk > 0
                || report.buffer_records_spilled > 0
                || report.buffer_records_replayed > 0
                || report.buffer_corrupt_records_skipped > 0
            {
                eprintln!(
                    "  buffer: {} bytes on disk, {} records spilled, {} replayed, \
                     {} corrupt skipped{}",
                    report.buffer_bytes_on_disk,
                    report.buffer_records_spilled,
                    report.buffer_records_replayed,
                    report.buffer_corrupt_records_skipped,
                    if report.buffer_spill_active { " (spill active)" } else { "" },
                );
            }
            let source_dropped: u64 = report.sources.iter().map(|s| s.dropped).sum();
            if !multi && source_dropped > 0 {
                eprintln!(
                    "  warning: {source_dropped} events outside the declared \
                     geometry were dropped"
                );
            }
            if multi {
                for node in &report.sources {
                    eprintln!(
                        "  in  {}: {} events / {} batches, {} backpressure waits, \
                         {} dropped",
                        node.name, node.events, node.batches, node.backpressure_waits,
                        node.dropped,
                    );
                }
                eprintln!(
                    "  merge: peak {} events buffered, {} out-of-canvas dropped, \
                     {} stalls broken, {} late",
                    report.merge_peak_buffered,
                    report.merge_dropped,
                    report.merge_stalls_broken,
                    report.merge_late_events,
                );
            }
            if multi || staged || branched {
                for node in &report.stages {
                    let shard_note = if node.shard_events.is_empty() {
                        String::new()
                    } else {
                        format!(
                            " [{} shards, skew {:.2}]",
                            node.shard_events.len(),
                            node.shard_skew()
                        )
                    };
                    eprintln!(
                        "  stage {}: {} in / {} dropped, {} backpressure waits{}",
                        node.name, node.events, node.dropped, node.backpressure_waits,
                        shard_note,
                    );
                }
            }
            if multi {
                for node in &report.sinks {
                    eprintln!(
                        "  out {}: {} events / {} batches, {} frames, \
                         {} backpressure waits",
                        node.name, node.events, node.batches, node.frames,
                        node.backpressure_waits,
                    );
                }
            }
            if let Some(adaptive) = &report.adaptive {
                eprintln!(
                    "  adaptive: {} epochs, {} re-cuts, {} chunk changes \
                     (final chunk {})",
                    adaptive.epochs,
                    adaptive.recuts.len(),
                    adaptive.chunk_changes.len(),
                    adaptive.final_chunk,
                );
                for recut in &adaptive.recuts {
                    eprintln!(
                        "    epoch {}: re-cut stage {} (skew {:.2} → {:.2}) at {:?}",
                        recut.epoch, recut.stage, recut.skew_before, recut.skew_after,
                        recut.bounds,
                    );
                }
                for change in &adaptive.chunk_changes {
                    eprintln!(
                        "    epoch {}: chunk {} → {}",
                        change.epoch, change.from, change.to
                    );
                }
                for change in &adaptive.window_changes {
                    eprintln!(
                        "    epoch {}: client {} window {} → {}",
                        change.epoch, change.client, change.from, change.to
                    );
                }
            }
        }
        Command::Scenarios { duration_us, time_scale } => {
            eprintln!("generating {duration_us} µs synthetic recording (346x260)…");
            let recording = camera::paper_recording(duration_us, 42);
            eprintln!("  {} events", recording.len());
            let device = Device::open_default()?;
            let mut table = Table::new(&[
                "scenario", "frames", "fps", "events", "HtoD ms", "HtoD %", "HtoD MB", "wall ms",
            ]);
            for cfg in ScenarioConfig::paper_four(time_scale) {
                let r = run_scenario(&device, &recording, &cfg)?;
                table.row(&[
                    r.label.clone(),
                    r.frames.to_string(),
                    format!("{:.0}", r.fps()),
                    r.events.to_string(),
                    format!("{:.1}", r.stats.htod_ns as f64 / 1e6),
                    format!("{:.2}", r.htod_percent()),
                    format!("{:.2}", r.stats.htod_bytes as f64 / 1e6),
                    format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                ]);
            }
            print!("{}", table.render());
        }
    }
    Ok(())
}
