//! Scene models for the synthetic camera: luminance fields over time.
//!
//! A scene renders to a row-major `f32` luminance frame in `[0, 1]` at a
//! given simulated time; the camera differentiates consecutive frames to
//! produce events. Scenes are chosen to exercise the edge detector the
//! way the paper's recording does: high-contrast moving structure.

use crate::aer::Resolution;

/// A time-parameterized luminance field.
#[derive(Debug, Clone)]
pub enum Scene {
    /// Uniform black frame: only noise events.
    Blank,
    /// A vertical bright bar sweeping horizontally, wrapping around.
    MovingBar {
        /// Horizontal speed in pixels per second.
        speed_px_per_s: f64,
        /// Bar thickness in pixels.
        thickness_px: u16,
    },
    /// A bright dot orbiting the sensor centre.
    RotatingDot {
        /// Orbit radius in pixels.
        radius_px: f64,
        /// Orbit period in seconds.
        period_s: f64,
        /// Dot radius in pixels.
        dot_radius_px: f64,
    },
    /// A checkerboard flipping phase at a fixed frequency (stress test:
    /// every pixel changes at once).
    FlickeringCheckerboard {
        /// Square edge length in pixels.
        square_px: u16,
        /// Flips per second.
        rate_hz: f64,
    },
    /// Pixel-wise maximum of sub-scenes.
    Composite(Vec<Scene>),
}

impl Scene {
    /// Render the luminance frame at simulated time `t_us`.
    pub fn render(&self, res: Resolution, t_us: u64) -> Vec<f32> {
        let (w, h) = (res.width as usize, res.height as usize);
        let t_s = t_us as f64 / 1e6;
        match self {
            Scene::Blank => vec![0.0; w * h],
            Scene::MovingBar { speed_px_per_s, thickness_px } => {
                let mut frame = vec![0.0; w * h];
                let pos = (speed_px_per_s * t_s) % w as f64;
                for y in 0..h {
                    for dx in 0..*thickness_px as usize {
                        let x = (pos as usize + dx) % w;
                        frame[y * w + x] = 1.0;
                    }
                }
                frame
            }
            Scene::RotatingDot { radius_px, period_s, dot_radius_px } => {
                let mut frame = vec![0.0; w * h];
                let angle = 2.0 * std::f64::consts::PI * (t_s / period_s);
                let cx = w as f64 / 2.0 + radius_px * angle.cos();
                let cy = h as f64 / 2.0 + radius_px * angle.sin();
                let r2 = dot_radius_px * dot_radius_px;
                // Only touch the dot's bounding box.
                let x0 = (cx - dot_radius_px).floor().max(0.0) as usize;
                let x1 = ((cx + dot_radius_px).ceil() as usize).min(w.saturating_sub(1));
                let y0 = (cy - dot_radius_px).floor().max(0.0) as usize;
                let y1 = ((cy + dot_radius_px).ceil() as usize).min(h.saturating_sub(1));
                for y in y0..=y1.min(h - 1) {
                    for x in x0..=x1.min(w - 1) {
                        let (dx, dy) = (x as f64 - cx, y as f64 - cy);
                        if dx * dx + dy * dy <= r2 {
                            frame[y * w + x] = 1.0;
                        }
                    }
                }
                frame
            }
            Scene::FlickeringCheckerboard { square_px, rate_hz } => {
                let phase = ((t_s * rate_hz).floor() as u64) % 2;
                let sq = (*square_px).max(1) as usize;
                let mut frame = vec![0.0; w * h];
                for y in 0..h {
                    for x in 0..w {
                        let parity = ((x / sq) + (y / sq) + phase as usize) % 2;
                        frame[y * w + x] = parity as f32;
                    }
                }
                frame
            }
            Scene::Composite(scenes) => {
                let mut frame = vec![0.0; w * h];
                for s in scenes {
                    for (acc, v) in frame.iter_mut().zip(s.render(res, t_us)) {
                        *acc = f32::max(*acc, v);
                    }
                }
                frame
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RES: Resolution = Resolution::new(64, 48);

    #[test]
    fn blank_is_black() {
        assert!(Scene::Blank.render(RES, 123).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn moving_bar_moves() {
        let s = Scene::MovingBar { speed_px_per_s: 64.0, thickness_px: 2 };
        let a = s.render(RES, 0);
        let b = s.render(RES, 500_000); // half a second → 32 px
        assert_ne!(a, b);
        // Lit area is thickness × height in both frames.
        let lit = |f: &[f32]| f.iter().filter(|&&v| v > 0.0).count();
        assert_eq!(lit(&a), 2 * 48);
        assert_eq!(lit(&b), 2 * 48);
    }

    #[test]
    fn rotating_dot_stays_in_bounds_and_moves() {
        let s = Scene::RotatingDot { radius_px: 20.0, period_s: 1.0, dot_radius_px: 3.0 };
        let a = s.render(RES, 0);
        let b = s.render(RES, 250_000); // quarter turn
        assert_ne!(a, b);
        assert!(a.iter().filter(|&&v| v > 0.0).count() > 0);
    }

    #[test]
    fn checkerboard_flips_every_period() {
        let s = Scene::FlickeringCheckerboard { square_px: 8, rate_hz: 10.0 };
        let a = s.render(RES, 0);
        let b = s.render(RES, 100_000); // exactly one flip later
        let c = s.render(RES, 200_000); // two flips: back to phase 0
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn composite_is_pixelwise_max() {
        let bar = Scene::MovingBar { speed_px_per_s: 0.0, thickness_px: 4 };
        let comp = Scene::Composite(vec![Scene::Blank, bar.clone()]);
        assert_eq!(comp.render(RES, 0), bar.render(RES, 0));
    }
}
