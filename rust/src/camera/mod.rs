//! Synthetic event camera.
//!
//! The paper's use case streams a 24.8 s, 90 M-event recording from a
//! 346×260 DAVIS camera. We have no camera hardware and no access to the
//! original recording, so this module *simulates* one (DESIGN.md
//! §Substitutions): scenes of moving high-contrast structures generate
//! events exactly where luminance changes — the same spatio-temporal
//! statistics the edge detector consumes — plus Poisson background noise
//! matching real DVS behaviour.
//!
//! The generator is deterministic (seeded) and paced in simulated
//! microseconds, so recordings are reproducible byte-for-byte.

pub mod scene;

use crate::aer::{Event, Polarity, Resolution};
use crate::testutil::SplitMix64;

pub use scene::Scene;

/// Configuration for a synthetic recording.
#[derive(Debug, Clone)]
pub struct CameraConfig {
    /// Sensor geometry.
    pub resolution: Resolution,
    /// Scene to render.
    pub scene: Scene,
    /// Background noise rate in events per pixel per second (real DVS
    /// background activity is ~0.1–5 Hz/px depending on biasing).
    pub noise_rate_hz: f64,
    /// Frame cadence of the underlying scene animation in µs. Events are
    /// generated from luminance *changes* between consecutive scene
    /// frames and jittered uniformly inside the interval.
    pub frame_interval_us: u64,
    /// RNG seed (generation is fully deterministic).
    pub seed: u64,
}

impl Default for CameraConfig {
    fn default() -> Self {
        CameraConfig {
            resolution: Resolution::DAVIS_346,
            scene: Scene::MovingBar { speed_px_per_s: 200.0, thickness_px: 6 },
            noise_rate_hz: 1.0,
            frame_interval_us: 1000,
            seed: 0xD1CE,
        }
    }
}

/// A synthetic event camera: renders the scene and emits AER events.
pub struct SyntheticCamera {
    config: CameraConfig,
    rng: SplitMix64,
    /// Previous luminance frame (row-major, `pixels()` long).
    prev: Vec<f32>,
    /// Current simulated time in µs.
    now_us: u64,
    /// Per-pixel contrast threshold for event emission.
    threshold: f32,
}

impl SyntheticCamera {
    /// Create a camera; the first luminance frame is rendered at t=0.
    pub fn new(config: CameraConfig) -> Self {
        let rng = SplitMix64::new(config.seed);
        let prev = config.scene.render(config.resolution, 0);
        SyntheticCamera { config, rng, prev, now_us: 0, threshold: 0.1 }
    }

    /// Current simulated time (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Sensor geometry of this camera.
    pub fn resolution(&self) -> Resolution {
        self.config.resolution
    }

    /// Advance one scene frame and return the events it generated,
    /// sorted by timestamp.
    pub fn step(&mut self) -> Vec<Event> {
        let res = self.config.resolution;
        let t0 = self.now_us;
        let t1 = t0 + self.config.frame_interval_us;
        let next = self.config.scene.render(res, t1);

        let mut events = Vec::new();
        // --- signal events: contrast change beyond threshold.
        for y in 0..res.height {
            for x in 0..res.width {
                let idx = y as usize * res.width as usize + x as usize;
                let delta = next[idx] - self.prev[idx];
                if delta.abs() >= self.threshold {
                    // Multiple threshold crossings emit multiple events,
                    // like a real DVS pixel integrating log-intensity.
                    let n = (delta.abs() / self.threshold).floor() as u32;
                    let pol = Polarity::from_bool(delta > 0.0);
                    for _ in 0..n.min(4) {
                        let jitter = self.rng.next_below(self.config.frame_interval_us.max(1));
                        events.push(Event { t: t0 + jitter, x, y, p: pol });
                    }
                }
            }
        }
        // --- background noise: Poisson per frame over the whole array.
        let lambda = self.config.noise_rate_hz
            * res.pixels() as f64
            * (self.config.frame_interval_us as f64 / 1e6);
        let n_noise = poisson(&mut self.rng, lambda);
        for _ in 0..n_noise {
            events.push(Event {
                t: t0 + self.rng.next_below(self.config.frame_interval_us.max(1)),
                x: self.rng.next_below(res.width as u64) as u16,
                y: self.rng.next_below(res.height as u64) as u16,
                p: Polarity::from_bool(self.rng.next_bool(0.5)),
            });
        }

        events.sort_unstable_by_key(|e| e.t);
        self.prev = next;
        self.now_us = t1;
        events
    }

    /// Record until `duration_us` of simulated time has elapsed.
    pub fn record(&mut self, duration_us: u64) -> Vec<Event> {
        let mut out = Vec::new();
        let end = self.now_us + duration_us;
        while self.now_us < end {
            out.extend(self.step());
        }
        out
    }
}

/// Generate the paper-scale use-case recording: a 346×260 scene with a
/// moving bar and rotating dot, scaled to `duration_us`. The full-paper
/// configuration (24.8 s) produces tens of millions of events; benches
/// default to a few seconds.
pub fn paper_recording(duration_us: u64, seed: u64) -> Vec<Event> {
    let mut camera = SyntheticCamera::new(CameraConfig {
        resolution: Resolution::DAVIS_346,
        scene: Scene::Composite(vec![
            Scene::MovingBar { speed_px_per_s: 300.0, thickness_px: 8 },
            Scene::RotatingDot { radius_px: 70.0, period_s: 0.8, dot_radius_px: 10.0 },
        ]),
        noise_rate_hz: 2.0,
        frame_interval_us: 1000,
        seed,
    });
    camera.record(duration_us)
}

/// Knuth's Poisson sampler (fine for the λ ≲ 500 used here).
fn poisson(rng: &mut SplitMix64, lambda: f64) -> u64 {
    if lambda <= 0.0 {
        return 0;
    }
    // For large λ fall back to a normal approximation to avoid O(λ) loop.
    if lambda > 256.0 {
        // Box–Muller.
        let u1 = rng.next_f64().max(1e-12);
        let u2 = rng.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        return (lambda + lambda.sqrt() * z).max(0.0).round() as u64;
    }
    let l = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.next_f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::validate_stream;

    #[test]
    fn recording_is_deterministic() {
        let cfg = CameraConfig::default();
        let a = SyntheticCamera::new(cfg.clone()).record(50_000);
        let b = SyntheticCamera::new(cfg).record(50_000);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "a moving bar must generate events");
    }

    #[test]
    fn events_are_valid_for_sensor() {
        let cfg = CameraConfig::default();
        let events = SyntheticCamera::new(cfg.clone()).record(100_000);
        assert_eq!(validate_stream(&events, cfg.resolution), None);
    }

    #[test]
    fn noise_only_rate_is_approximately_poisson() {
        let cfg = CameraConfig {
            scene: Scene::Blank,
            noise_rate_hz: 10.0,
            frame_interval_us: 1000,
            ..Default::default()
        };
        let dur_s = 2.0;
        let events = SyntheticCamera::new(cfg.clone()).record((dur_s * 1e6) as u64);
        let expected = 10.0 * cfg.resolution.pixels() as f64 * dur_s;
        let got = events.len() as f64;
        assert!(
            (got - expected).abs() / expected < 0.15,
            "noise rate off: got {got}, expected ≈{expected}"
        );
    }

    #[test]
    fn moving_bar_produces_balanced_polarity() {
        // A bar sweeping produces ON at the leading edge and OFF at the
        // trailing edge in roughly equal numbers.
        let cfg = CameraConfig { noise_rate_hz: 0.0, ..Default::default() };
        let events = SyntheticCamera::new(cfg).record(200_000);
        let on = events.iter().filter(|e| e.p.is_on()).count() as f64;
        let off = events.len() as f64 - on;
        assert!(on > 0.0 && off > 0.0);
        assert!((on / off - 1.0).abs() < 0.3, "on/off = {}", on / off);
    }

    #[test]
    fn paper_recording_has_realistic_rate() {
        // The paper's recording runs ~3.6 Mev/s. Our default composite
        // scene should land within an order of magnitude.
        let events = paper_recording(200_000, 7); // 0.2 s
        let rate = events.len() as f64 / 0.2;
        assert!(rate > 1e4, "rate {rate} too low");
    }
}
