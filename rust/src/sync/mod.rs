//! Low-level synchronization substrates built from scratch.
//!
//! The paper contrasts lock-based buffer handoff (Fig. 1A) with
//! coroutine handoff (Fig. 1B) and mentions lock-free structures as the
//! classical alternative (§2.1). This module provides the lock-free
//! piece: a bounded single-producer/single-consumer ring buffer used by
//! the multi-threaded coroutine engine and the `spsc` ablation engine.

pub mod spsc;

pub use spsc::{spsc_ring, RingConsumer, RingProducer};
