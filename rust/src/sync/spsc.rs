//! Bounded lock-free single-producer single-consumer ring buffer.
//!
//! Classic Lamport queue with cached indices (the FastForward
//! optimization): producer and consumer each keep a local copy of the
//! opposing index and only reload it (an `Acquire` load) when the cached
//! value implies full/empty. In steady state, a push or pop touches only
//! one shared cache line.
//!
//! Used by:
//! * [`crate::engine::spsc`] — the lock-free ablation engine (§2.1's
//!   "approaches to eliminate locks"),
//! * [`crate::rt::sync_channel`] — the cross-thread async channel for
//!   coroutines that hop threads.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pad-and-align wrapper keeping `head` and `tail` on separate cache
/// lines. Without it the two counters share a line, so every producer
/// store invalidates the consumer's cached copy (and vice versa) even
/// though each side writes only its own index — false sharing that the
/// FastForward cached-index scheme is supposed to avoid. 64 bytes
/// covers x86-64 and most aarch64 parts (128-byte-line CPUs still get
/// a 2× reduction in collisions).
#[repr(align(64))]
struct CachePadded<T>(T);

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.0
    }
}

struct Ring<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Capacity, always a power of two (mask = cap - 1).
    mask: usize,
    /// Next slot to write (monotonically increasing, wrapped via mask).
    head: CachePadded<AtomicUsize>,
    /// Next slot to read.
    tail: CachePadded<AtomicUsize>,
    /// Set when the producer handle is dropped.
    closed: AtomicBool,
}

// SAFETY: T is sent across the channel; slots are accessed exclusively by
// the producer (between tail..head+cap) or consumer (between tail..head),
// coordinated by the acquire/release index protocol below.
unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

/// Producer half of the ring. Not `Clone`: single producer.
pub struct RingProducer<T> {
    ring: Arc<Ring<T>>,
    /// Local monotonic write index.
    head: usize,
    /// Cached copy of the consumer's tail.
    cached_tail: usize,
}

/// Consumer half of the ring. Not `Clone`: single consumer.
pub struct RingConsumer<T> {
    ring: Arc<Ring<T>>,
    /// Local monotonic read index.
    tail: usize,
    /// Cached copy of the producer's head.
    cached_head: usize,
}

/// Create a ring with capacity `cap` (rounded up to a power of two, min 2).
pub fn spsc_ring<T>(cap: usize) -> (RingProducer<T>, RingConsumer<T>) {
    let cap = cap.max(2).next_power_of_two();
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let ring = Arc::new(Ring {
        buf,
        mask: cap - 1,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        closed: AtomicBool::new(false),
    });
    (
        RingProducer { ring: ring.clone(), head: 0, cached_tail: 0 },
        RingConsumer { ring, tail: 0, cached_head: 0 },
    )
}

impl<T> RingProducer<T> {
    /// Capacity of the ring (power of two).
    pub fn capacity(&self) -> usize {
        self.ring.mask + 1
    }

    /// Attempt to push; returns `Err(item)` if the ring is full.
    #[inline]
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        let cap = self.ring.mask + 1;
        if self.head - self.cached_tail == cap {
            // Looks full with the cached tail; refresh from shared state.
            self.cached_tail = self.ring.tail.load(Ordering::Acquire);
            if self.head - self.cached_tail == cap {
                return Err(item);
            }
        }
        let slot = &self.ring.buf[self.head & self.ring.mask];
        // SAFETY: slot is outside tail..head, exclusively ours to write.
        unsafe { (*slot.get()).write(item) };
        self.head += 1;
        self.ring.head.store(self.head, Ordering::Release);
        Ok(())
    }

    /// Spin/yield until the push succeeds. Returns `false` (dropping the
    /// item) if the consumer side has been dropped.
    pub fn push_blocking(&mut self, mut item: T) -> bool {
        let mut spins = 0u32;
        loop {
            // Consumer gone (Arc count 2 → 1 means we're alone): pushing
            // would silently discard, so bail out before writing.
            if Arc::strong_count(&self.ring) == 1 {
                return false;
            }
            match self.try_push(item) {
                Ok(()) => return true,
                Err(back) => {
                    item = back;
                    backoff(&mut spins);
                }
            }
        }
    }

    /// Number of items currently buffered (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.head - self.ring.tail.load(Ordering::Acquire)
    }

    /// `true` if no items are buffered (approximate under concurrency).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark the ring closed *before* this handle is dropped — used by
    /// wrappers that must publish the close and then wake a parked
    /// consumer in a single, ordered sequence.
    pub fn close(&self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for RingProducer<T> {
    fn drop(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T> RingConsumer<T> {
    /// Attempt to pop; `None` if the ring is currently empty.
    #[inline]
    pub fn try_pop(&mut self) -> Option<T> {
        if self.cached_head == self.tail {
            self.cached_head = self.ring.head.load(Ordering::Acquire);
            if self.cached_head == self.tail {
                return None;
            }
        }
        let slot = &self.ring.buf[self.tail & self.ring.mask];
        // SAFETY: slot is inside tail..head: initialized and exclusively ours.
        let item = unsafe { (*slot.get()).assume_init_read() };
        self.tail += 1;
        self.ring.tail.store(self.tail, Ordering::Release);
        Some(item)
    }

    /// Pop, spinning/yielding while empty. `None` once the producer is
    /// dropped *and* the ring is drained.
    pub fn pop_blocking(&mut self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            if let Some(item) = self.try_pop() {
                return Some(item);
            }
            if self.is_closed() {
                // Drain anything the producer pushed before closing.
                return self.try_pop();
            }
            backoff(&mut spins);
        }
    }

    /// `true` once the producer handle has been dropped.
    pub fn is_closed(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
    }

    /// Number of items currently buffered (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.ring.head.load(Ordering::Acquire) - self.tail
    }

    /// `true` if no items are buffered (approximate under concurrency).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for RingConsumer<T> {
    fn drop(&mut self) {
        // Drop any items the producer left behind.
        while self.try_pop().is_some() {}
    }
}

/// Exponential-ish backoff: spin briefly, then yield to the OS. On the
/// single-core CI machine yielding early matters — a pinned spinner
/// starves the opposing side for a whole quantum otherwise.
#[inline]
fn backoff(spins: &mut u32) {
    if *spins < 6 {
        for _ in 0..(1 << *spins) {
            std::hint::spin_loop();
        }
        *spins += 1;
    } else {
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_and_tail_live_on_distinct_cache_lines() {
        let (p, _c) = spsc_ring::<u32>(8);
        let head = &*p.ring.head as *const AtomicUsize as usize;
        let tail = &*p.ring.tail as *const AtomicUsize as usize;
        assert_eq!(head % 64, 0, "head must start a cache line");
        assert_eq!(tail % 64, 0, "tail must start a cache line");
        assert!(head.abs_diff(tail) >= 64, "indices must not share a line");
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let (p, _c) = spsc_ring::<u32>(100);
        assert_eq!(p.capacity(), 128);
        let (p, _c) = spsc_ring::<u32>(0);
        assert_eq!(p.capacity(), 2);
    }

    #[test]
    fn fifo_order_single_thread() {
        let (mut p, mut c) = spsc_ring(8);
        for i in 0..8 {
            p.try_push(i).unwrap();
        }
        assert!(p.try_push(99).is_err(), "ring should be full");
        for i in 0..8 {
            assert_eq!(c.try_pop(), Some(i));
        }
        assert_eq!(c.try_pop(), None);
    }

    #[test]
    fn wraparound_many_times() {
        let (mut p, mut c) = spsc_ring(4);
        for round in 0u64..100 {
            for i in 0..3 {
                p.try_push(round * 10 + i).unwrap();
            }
            for i in 0..3 {
                assert_eq!(c.try_pop(), Some(round * 10 + i));
            }
        }
    }

    #[test]
    fn cross_thread_transfers_everything() {
        let (mut p, mut c) = spsc_ring(64);
        let n = 100_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                assert!(p.push_blocking(i));
            }
        });
        let mut expected = 0u64;
        while let Some(v) = c.pop_blocking() {
            assert_eq!(v, expected);
            expected += 1;
        }
        assert_eq!(expected, n);
        producer.join().unwrap();
    }

    #[test]
    fn consumer_drop_unblocks_producer() {
        let (mut p, c) = spsc_ring(2);
        p.try_push(1).unwrap();
        p.try_push(2).unwrap();
        drop(c);
        // Full ring + dropped consumer: push_blocking must bail out.
        assert!(!p.push_blocking(3));
    }

    #[test]
    fn producer_drop_lets_consumer_drain_then_close() {
        let (mut p, mut c) = spsc_ring(8);
        p.try_push(7).unwrap();
        drop(p);
        assert!(c.is_closed());
        assert_eq!(c.pop_blocking(), Some(7));
        assert_eq!(c.pop_blocking(), None);
    }

    #[test]
    fn drops_leftover_items() {
        // Drop-counting payload to verify no leaks of undrained items.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let (mut p, c) = spsc_ring(8);
        for _ in 0..5 {
            p.try_push(D).unwrap();
        }
        drop(c);
        drop(p);
        assert_eq!(DROPS.load(Ordering::SeqCst), 5);
    }
}
