//! Command-line interface — the paper's Fig. 2(B) composition syntax.
//!
//! ```text
//! aestream input file recording.aedat output udp 127.0.0.1:3333
//! aestream input synthetic --duration 2s filter polarity on output stdout
//! aestream input udp 0.0.0.0:3333 output file out.aedat
//! aestream scenarios --duration 2s --time-scale 20
//! aestream table1
//! ```
//!
//! Hand-rolled parsing (no clap offline): a token-stream grammar of
//! `input <spec> [filter <name> <args>…]* output <spec>` mirrors the
//! original AEStream CLI's free input/output pairing.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::aer::{Polarity, Resolution};
use crate::camera::CameraConfig;
use crate::coordinator::stream::{Sink, Source, StreamConfig, StreamDriver};
use crate::formats::Format;
use crate::pipeline::ops;
use crate::pipeline::Pipeline;

/// A parsed CLI invocation.
pub enum Command {
    /// `input … [filter …] output … [--chunk N] [--sync]`
    Stream { source: Source, pipeline: Pipeline, sink: Sink, config: StreamConfig },
    /// Run the four Fig. 4 scenarios.
    Scenarios {
        /// Synthetic recording length (µs).
        duration_us: u64,
        /// Replay speed multiplier.
        time_scale: f64,
    },
    /// Print the Table 1 feature matrix.
    Table1,
    /// Print usage.
    Help,
}

/// Parse a full argv (excluding the program name).
pub fn parse(args: &[String]) -> Result<Command> {
    let mut toks = args.iter().map(String::as_str).peekable();
    match toks.peek() {
        None => Ok(Command::Help),
        Some(&"help") | Some(&"--help") | Some(&"-h") => Ok(Command::Help),
        Some(&"table1") => Ok(Command::Table1),
        Some(&"scenarios") => {
            toks.next();
            let mut duration_us = 1_000_000;
            let mut time_scale = 10.0;
            while let Some(tok) = toks.next() {
                match tok {
                    "--duration" => {
                        duration_us = parse_duration(
                            toks.next().context("--duration needs a value")?,
                        )?
                        .as_micros() as u64
                    }
                    "--time-scale" => {
                        time_scale = toks
                            .next()
                            .context("--time-scale needs a value")?
                            .parse()
                            .context("bad --time-scale")?
                    }
                    other => bail!("unknown scenarios flag {other}"),
                }
            }
            Ok(Command::Scenarios { duration_us, time_scale })
        }
        Some(&"input") => parse_stream(&mut toks),
        Some(other) => bail!("unknown command {other:?}; try `aestream help`"),
    }
}

fn parse_stream<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Command> {
    // ---- input
    let kw = toks.next();
    debug_assert_eq!(kw, Some("input"));
    let source = match toks.next().context("input needs a kind")? {
        "file" => Source::File(PathBuf::from(toks.next().context("input file needs a path")?)),
        "udp" => Source::Udp {
            bind: toks.next().context("input udp needs an address")?.to_string(),
            idle_timeout: Duration::from_millis(500),
        },
        "synthetic" => {
            let mut duration_us = 1_000_000u64;
            while toks.peek() == Some(&"--duration") {
                toks.next();
                duration_us = parse_duration(toks.next().context("--duration needs a value")?)?
                    .as_micros() as u64;
            }
            Source::Synthetic { config: CameraConfig::default(), duration_us }
        }
        other => bail!("unknown input kind {other:?} (file|udp|synthetic)"),
    };

    // ---- filters
    let mut pipeline = Pipeline::new();
    let res = Resolution::DAVIS_346; // stateful filters need geometry
    while toks.peek() == Some(&"filter") {
        toks.next();
        let name = toks.next().context("filter needs a name")?;
        pipeline = match name {
            "polarity" => {
                let which = toks.next().context("filter polarity needs on|off")?;
                let p = match which {
                    "on" => Polarity::On,
                    "off" => Polarity::Off,
                    other => bail!("polarity must be on|off, got {other:?}"),
                };
                pipeline.then(ops::PolarityFilter::keep(p))
            }
            "crop" => {
                let mut dims = [0u16; 4];
                for d in dims.iter_mut() {
                    *d = toks
                        .next()
                        .context("filter crop needs x0 y0 w h")?
                        .parse()
                        .context("bad crop dimension")?;
                }
                pipeline.then(ops::RoiCrop::new(dims[0], dims[1], dims[2], dims[3]))
            }
            "downsample" => {
                let f = toks
                    .next()
                    .context("filter downsample needs a factor")?
                    .parse()
                    .context("bad factor")?;
                pipeline.then(ops::Downsample::new(f))
            }
            "refractory" => {
                let us = toks
                    .next()
                    .context("filter refractory needs µs")?
                    .parse()
                    .context("bad refractory period")?;
                pipeline.then(ops::RefractoryFilter::new(res, us))
            }
            "denoise" => {
                let us = toks
                    .next()
                    .context("filter denoise needs µs")?
                    .parse()
                    .context("bad denoise window")?;
                pipeline.then(ops::BackgroundActivityFilter::new(res, us))
            }
            "flip-x" => pipeline.then(ops::FlipX::new(res.width)),
            "flip-y" => pipeline.then(ops::FlipY::new(res.height)),
            other => bail!("unknown filter {other:?}"),
        };
    }

    // ---- output
    match toks.next() {
        Some("output") => {}
        other => bail!("expected `output`, got {other:?}"),
    }
    let sink = match toks.next().context("output needs a kind")? {
        "file" => {
            let path = PathBuf::from(toks.next().context("output file needs a path")?);
            let format = path
                .extension()
                .and_then(|e| e.to_str())
                .and_then(Format::from_extension)
                .context("cannot infer output format from extension")?;
            Sink::File(path, format)
        }
        "udp" => Sink::Udp(toks.next().context("output udp needs an address")?.to_string()),
        "stdout" => Sink::Stdout,
        "null" => Sink::Null,
        "frames" => {
            let window_us = toks
                .next()
                .context("output frames needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::Frames { window_us }
        }
        "view" => {
            let window_us = toks
                .next()
                .context("output view needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::View { window_us, max_frames: 8 }
        }
        other => bail!("unknown output kind {other:?} (file|udp|stdout|null|frames|view)"),
    };
    // ---- streaming options
    let mut config = StreamConfig::default();
    while let Some(tok) = toks.next() {
        match tok {
            "--chunk" => {
                config.chunk_size = toks
                    .next()
                    .context("--chunk needs an event count")?
                    .parse()
                    .context("bad --chunk")?;
                if config.chunk_size == 0 {
                    bail!("--chunk must be at least 1");
                }
            }
            "--sync" => config.driver = StreamDriver::Sync,
            extra => bail!("unexpected trailing argument {extra:?}"),
        }
    }
    Ok(Command::Stream { source, pipeline, sink, config })
}

/// Parse `"500ms"`, `"2s"`, `"1500us"`, or a bare number of seconds.
pub fn parse_duration(s: &str) -> Result<Duration> {
    let (num, unit) = match s.find(|c: char| c.is_alphabetic()) {
        Some(i) => s.split_at(i),
        None => (s, "s"),
    };
    let value: f64 = num.parse().with_context(|| format!("bad duration {s:?}"))?;
    let secs = match unit {
        "s" => value,
        "ms" => value / 1e3,
        "us" | "µs" => value / 1e6,
        other => bail!("unknown duration unit {other:?}"),
    };
    Ok(Duration::from_secs_f64(secs))
}

/// Usage text.
pub const USAGE: &str = "\
aestream — accelerated event-based processing with coroutines (reproduction)

USAGE:
  aestream input <file PATH | udp ADDR | synthetic [--duration D]>
           [filter <polarity on|off | crop X Y W H | downsample F |
                    refractory US | denoise US | flip-x | flip-y>]...
           output <file PATH | udp ADDR | stdout | null | frames WINDOW_US |
                   view WINDOW_US>
           [--chunk EVENTS] [--sync]
  aestream scenarios [--duration D] [--time-scale X]
  aestream table1
  aestream help

Streams run incrementally (O(chunk) memory) on the coroutine driver;
--chunk sets the batch size (default 4096) and --sync selects the
synchronous baseline driver instead.

EXAMPLES (paper Fig. 2B):
  aestream input file recording.aedat output udp 10.0.0.1:3333
  aestream input synthetic --duration 2s filter polarity on output stdout
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_paper_example() {
        let cmd =
            parse(&sv(&["input", "file", "r.aedat", "output", "udp", "1.2.3.4:3333"])).unwrap();
        match cmd {
            Command::Stream { source: Source::File(p), sink: Sink::Udp(a), .. } => {
                assert_eq!(p, PathBuf::from("r.aedat"));
                assert_eq!(a, "1.2.3.4:3333");
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_filters_in_order() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "polarity", "on", "filter", "downsample", "2",
            "output", "null",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { pipeline, .. } => {
                assert_eq!(pipeline.describe(), "polarity(on) | downsample(/2)");
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_scenarios_flags() {
        let cmd =
            parse(&sv(&["scenarios", "--duration", "500ms", "--time-scale", "5"])).unwrap();
        match cmd {
            Command::Scenarios { duration_us, time_scale } => {
                assert_eq!(duration_us, 500_000);
                assert_eq!(time_scale, 5.0);
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_streaming_flags() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "output", "null", "--chunk", "512", "--sync",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { config, .. } => {
                assert_eq!(config.chunk_size, 512);
                assert_eq!(config.driver, StreamDriver::Sync);
            }
            _ => panic!("wrong parse"),
        }
        // Defaults: coroutine driver, 4096-event chunks.
        match parse(&sv(&["input", "synthetic", "output", "null"])).unwrap() {
            Command::Stream { config, .. } => {
                assert_eq!(config.chunk_size, 4096);
                assert_ne!(config.driver, StreamDriver::Sync);
            }
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&["input", "synthetic", "output", "null", "--chunk", "0"])).is_err());
    }

    #[test]
    fn duration_units() {
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("500ms").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration("1500us").unwrap(), Duration::from_micros(1500));
        assert_eq!(parse_duration("3").unwrap(), Duration::from_secs(3));
        assert!(parse_duration("5fortnights").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(&sv(&["input"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "file", "y.weird"])).is_err());
        assert!(parse(&sv(&["frobnicate"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "null", "extra"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
    }
}
