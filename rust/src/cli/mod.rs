//! Command-line interface — the paper's Fig. 2(B) composition syntax.
//!
//! ```text
//! aestream input file recording.aedat output udp 127.0.0.1:3333
//! aestream input synthetic --duration 2s filter polarity on output stdout
//! aestream input udp 0.0.0.0:3333 output file out.aedat
//! aestream input synthetic input synthetic output file fused.aedat output null --threads 2
//! aestream scenarios --duration 2s --time-scale 20
//! aestream table1
//! ```
//!
//! Hand-rolled parsing (no clap offline): a token-stream grammar of
//! `input <spec>… [filter <name> <args>…]* output <spec>…` mirrors the
//! original AEStream CLI's free input/output pairing. Repeating
//! `input`/`output` clauses builds a fan-in/fan-out topology: the
//! inputs are merged in timestamp order onto a side-by-side canvas and
//! the outputs are fed per `--route` (broadcast by default).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::aer::{Polarity, Resolution};
use crate::camera::CameraConfig;
use crate::coordinator::stream::{RoutePolicy, Sink, Source, StreamConfig, StreamDriver};
use crate::formats::Format;
use crate::pipeline::fusion::SourceLayout;
use crate::pipeline::ops;
use crate::pipeline::Pipeline;

/// A parsed CLI invocation.
pub enum Command {
    /// `input …+ [filter …]* output …+ [--chunk N] [--sync] [--threads N] [--route R]`
    Stream {
        /// One or more inputs (several fan in through the merge).
        sources: Vec<Source>,
        /// The shared filter pipeline.
        pipeline: Pipeline,
        /// One or more outputs (several fan out per `route`).
        sinks: Vec<Sink>,
        /// Chunking and edge-driver configuration.
        config: StreamConfig,
        /// `--threads N`: 0/1 keeps every source on the executor
        /// thread; ≥ 2 pins each source to its own OS thread.
        threads: usize,
        /// How events are distributed across the outputs.
        route: RoutePolicy,
    },
    /// Run the four Fig. 4 scenarios.
    Scenarios {
        /// Synthetic recording length (µs).
        duration_us: u64,
        /// Replay speed multiplier.
        time_scale: f64,
    },
    /// Print the Table 1 feature matrix.
    Table1,
    /// Print usage.
    Help,
}

/// Parse a full argv (excluding the program name).
pub fn parse(args: &[String]) -> Result<Command> {
    let mut toks = args.iter().map(String::as_str).peekable();
    match toks.peek() {
        None => Ok(Command::Help),
        Some(&"help") | Some(&"--help") | Some(&"-h") => Ok(Command::Help),
        Some(&"table1") => Ok(Command::Table1),
        Some(&"scenarios") => {
            toks.next();
            let mut duration_us = 1_000_000;
            let mut time_scale = 10.0;
            while let Some(tok) = toks.next() {
                match tok {
                    "--duration" => {
                        duration_us = parse_duration(
                            toks.next().context("--duration needs a value")?,
                        )?
                        .as_micros() as u64
                    }
                    "--time-scale" => {
                        time_scale = toks
                            .next()
                            .context("--time-scale needs a value")?
                            .parse()
                            .context("bad --time-scale")?
                    }
                    other => bail!("unknown scenarios flag {other}"),
                }
            }
            Ok(Command::Scenarios { duration_us, time_scale })
        }
        Some(&"input") => parse_stream(&mut toks),
        Some(other) => bail!("unknown command {other:?}; try `aestream help`"),
    }
}

fn parse_input<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Source> {
    Ok(match toks.next().context("input needs a kind")? {
        "file" => Source::File(PathBuf::from(toks.next().context("input file needs a path")?)),
        "udp" => {
            let bind = toks.next().context("input udp needs an address")?.to_string();
            let mut geometry = None;
            while toks.peek() == Some(&"--geometry") {
                toks.next();
                geometry = Some(parse_geometry(
                    toks.next().context("--geometry needs WxH")?,
                )?);
            }
            Source::Udp { bind, idle_timeout: Duration::from_millis(500), geometry }
        }
        "synthetic" => {
            let mut duration_us = 1_000_000u64;
            while toks.peek() == Some(&"--duration") {
                toks.next();
                duration_us = parse_duration(toks.next().context("--duration needs a value")?)?
                    .as_micros() as u64;
            }
            Source::Synthetic { config: CameraConfig::default(), duration_us }
        }
        other => bail!("unknown input kind {other:?} (file|udp|synthetic)"),
    })
}

fn parse_output<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Sink> {
    Ok(match toks.next().context("output needs a kind")? {
        "file" => {
            let path = PathBuf::from(toks.next().context("output file needs a path")?);
            let format = path
                .extension()
                .and_then(|e| e.to_str())
                .and_then(Format::from_extension)
                .context("cannot infer output format from extension")?;
            Sink::File(path, format)
        }
        "udp" => Sink::Udp(toks.next().context("output udp needs an address")?.to_string()),
        "stdout" => Sink::Stdout,
        "null" => Sink::Null,
        "frames" => {
            let window_us = toks
                .next()
                .context("output frames needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::Frames { window_us }
        }
        "view" => {
            let window_us = toks
                .next()
                .context("output view needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::View { window_us, max_frames: 8 }
        }
        other => bail!("unknown output kind {other:?} (file|udp|stdout|null|frames|view)"),
    })
}

/// The canvas geometry the parsed inputs will fuse onto, as far as the
/// command line can know it before sources are opened: declared
/// geometries where given, DAVIS_346 otherwise, laid out by the same
/// [`SourceLayout::side_by_side`] the topology will use (one source of
/// truth for the layout math).
fn assumed_canvas(sources: &[Source]) -> Resolution {
    let resolutions: Vec<Resolution> = sources
        .iter()
        .map(|source| match source {
            Source::Udp { geometry: Some(res), .. } => *res,
            Source::Memory(_, res) => *res,
            _ => Resolution::DAVIS_346,
        })
        .collect();
    SourceLayout::side_by_side(&resolutions).canvas
}

fn parse_stream<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Command> {
    // ---- inputs (one or more clauses fan in)
    let mut sources = Vec::new();
    while toks.peek() == Some(&"input") {
        toks.next();
        sources.push(parse_input(toks)?);
    }
    debug_assert!(!sources.is_empty(), "parse_stream is entered on `input`");

    // ---- filters (one shared pipeline)
    let mut pipeline = Pipeline::new();
    // Stateful filters need geometry before the sources are opened. Use
    // what the command line declares: each input's explicit geometry
    // where given, the DAVIS_346 assumption otherwise, summed side by
    // side the way the fused canvas will be laid out. (Events beyond a
    // filter's geometry pass through it untracked rather than
    // panicking, so an undeclared larger sensor degrades gracefully.)
    let res = assumed_canvas(&sources);
    while toks.peek() == Some(&"filter") {
        toks.next();
        let name = toks.next().context("filter needs a name")?;
        pipeline = match name {
            "polarity" => {
                let which = toks.next().context("filter polarity needs on|off")?;
                let p = match which {
                    "on" => Polarity::On,
                    "off" => Polarity::Off,
                    other => bail!("polarity must be on|off, got {other:?}"),
                };
                pipeline.then(ops::PolarityFilter::keep(p))
            }
            "crop" => {
                let mut dims = [0u16; 4];
                for d in dims.iter_mut() {
                    *d = toks
                        .next()
                        .context("filter crop needs x0 y0 w h")?
                        .parse()
                        .context("bad crop dimension")?;
                }
                pipeline.then(ops::RoiCrop::new(dims[0], dims[1], dims[2], dims[3]))
            }
            "downsample" => {
                let f = toks
                    .next()
                    .context("filter downsample needs a factor")?
                    .parse()
                    .context("bad factor")?;
                pipeline.then(ops::Downsample::new(f))
            }
            "refractory" => {
                let us = toks
                    .next()
                    .context("filter refractory needs µs")?
                    .parse()
                    .context("bad refractory period")?;
                pipeline.then(ops::RefractoryFilter::new(res, us))
            }
            "denoise" => {
                let us = toks
                    .next()
                    .context("filter denoise needs µs")?
                    .parse()
                    .context("bad denoise window")?;
                pipeline.then(ops::BackgroundActivityFilter::new(res, us))
            }
            "flip-x" => pipeline.then(ops::FlipX::new(res.width)),
            "flip-y" => pipeline.then(ops::FlipY::new(res.height)),
            other => bail!("unknown filter {other:?}"),
        };
    }

    // ---- outputs (one or more clauses fan out)
    let mut sinks = Vec::new();
    match toks.next() {
        Some("output") => sinks.push(parse_output(toks)?),
        other => bail!("expected `output`, got {other:?}"),
    }
    while toks.peek() == Some(&"output") {
        toks.next();
        sinks.push(parse_output(toks)?);
    }

    // ---- streaming options
    let mut config = StreamConfig::default();
    let mut threads = 1usize;
    let mut route = RoutePolicy::Broadcast;
    while let Some(tok) = toks.next() {
        match tok {
            "--chunk" => {
                config.chunk_size = toks
                    .next()
                    .context("--chunk needs an event count")?
                    .parse()
                    .context("bad --chunk")?;
                if config.chunk_size == 0 {
                    bail!("--chunk must be at least 1");
                }
            }
            "--sync" => config.driver = StreamDriver::Sync,
            "--threads" => {
                threads = toks
                    .next()
                    .context("--threads needs a count")?
                    .parse()
                    .context("bad --threads")?;
            }
            "--route" => {
                route = match toks.next().context("--route needs a policy")? {
                    "broadcast" => RoutePolicy::Broadcast,
                    "polarity" => RoutePolicy::Polarity,
                    "stripes" => RoutePolicy::Stripes,
                    other => bail!("unknown route {other:?} (broadcast|polarity|stripes)"),
                };
            }
            extra => bail!("unexpected trailing argument {extra:?}"),
        }
    }
    Ok(Command::Stream { sources, pipeline, sinks, config, threads, route })
}

/// Parse `"500ms"`, `"2s"`, `"1500us"`, or a bare number of seconds.
pub fn parse_duration(s: &str) -> Result<Duration> {
    let (num, unit) = match s.find(|c: char| c.is_alphabetic()) {
        Some(i) => s.split_at(i),
        None => (s, "s"),
    };
    let value: f64 = num.parse().with_context(|| format!("bad duration {s:?}"))?;
    let secs = match unit {
        "s" => value,
        "ms" => value / 1e3,
        "us" | "µs" => value / 1e6,
        other => bail!("unknown duration unit {other:?}"),
    };
    Ok(Duration::from_secs_f64(secs))
}

/// Parse `"346x260"` into a [`Resolution`].
pub fn parse_geometry(s: &str) -> Result<Resolution> {
    let (w, h) = s.split_once('x').with_context(|| format!("geometry {s:?} must be WxH"))?;
    let width = w.parse().with_context(|| format!("bad geometry width {w:?}"))?;
    let height = h.parse().with_context(|| format!("bad geometry height {h:?}"))?;
    if width == 0 || height == 0 {
        bail!("geometry must be at least 1x1");
    }
    Ok(Resolution::new(width, height))
}

/// Usage text.
pub const USAGE: &str = "\
aestream — accelerated event-based processing with coroutines (reproduction)

USAGE:
  aestream input <file PATH | udp ADDR [--geometry WxH] |
                  synthetic [--duration D]>...
           [filter <polarity on|off | crop X Y W H | downsample F |
                    refractory US | denoise US | flip-x | flip-y>]...
           output <file PATH | udp ADDR | stdout | null | frames WINDOW_US |
                   view WINDOW_US>...
           [--chunk EVENTS] [--sync] [--threads N]
           [--route broadcast|polarity|stripes]
  aestream scenarios [--duration D] [--time-scale X]
  aestream table1
  aestream help

Streams run incrementally (O(chunk) memory) on the coroutine driver;
--chunk sets the batch size (default 4096) and --sync selects the
synchronous baseline driver instead.

Repeat `input` to fan several sources in: they merge in timestamp
order onto a side-by-side canvas (live UDP inputs must declare
--geometry). Repeat `output` to fan out; --route picks broadcast
(default), polarity (ON→first, OFF→second), or vertical stripes.
--threads 2+ pins each source to its own OS thread, feeding the
coroutine executor through a lock-free ring.

EXAMPLES (paper Fig. 2B and §6 fusion):
  aestream input file recording.aedat output udp 10.0.0.1:3333
  aestream input synthetic --duration 2s filter polarity on output stdout
  aestream input synthetic input synthetic \\
           output file fused.aedat output view 10000 --threads 2
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_paper_example() {
        let cmd =
            parse(&sv(&["input", "file", "r.aedat", "output", "udp", "1.2.3.4:3333"])).unwrap();
        match cmd {
            Command::Stream { sources, sinks, .. } => {
                assert_eq!(sources.len(), 1);
                assert_eq!(sinks.len(), 1);
                match (&sources[0], &sinks[0]) {
                    (Source::File(p), Sink::Udp(a)) => {
                        assert_eq!(*p, PathBuf::from("r.aedat"));
                        assert_eq!(a, "1.2.3.4:3333");
                    }
                    _ => panic!("wrong parse"),
                }
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_filters_in_order() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "polarity", "on", "filter", "downsample", "2",
            "output", "null",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { pipeline, .. } => {
                assert_eq!(pipeline.describe(), "polarity(on) | downsample(/2)");
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_scenarios_flags() {
        let cmd =
            parse(&sv(&["scenarios", "--duration", "500ms", "--time-scale", "5"])).unwrap();
        match cmd {
            Command::Scenarios { duration_us, time_scale } => {
                assert_eq!(duration_us, 500_000);
                assert_eq!(time_scale, 5.0);
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_streaming_flags() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "output", "null", "--chunk", "512", "--sync",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { config, threads, route, .. } => {
                assert_eq!(config.chunk_size, 512);
                assert_eq!(config.driver, StreamDriver::Sync);
                assert_eq!(threads, 1);
                assert_eq!(route, RoutePolicy::Broadcast);
            }
            _ => panic!("wrong parse"),
        }
        // Defaults: coroutine driver, 4096-event chunks.
        match parse(&sv(&["input", "synthetic", "output", "null"])).unwrap() {
            Command::Stream { config, .. } => {
                assert_eq!(config.chunk_size, 4096);
                assert_ne!(config.driver, StreamDriver::Sync);
            }
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&["input", "synthetic", "output", "null", "--chunk", "0"])).is_err());
    }

    #[test]
    fn parses_multi_io_topology() {
        // The acceptance-criteria invocation shape.
        let cmd = parse(&sv(&[
            "input", "synthetic", "--duration", "50ms", "input", "synthetic", "--duration",
            "50ms", "output", "file", "fused.aedat", "output", "null", "--threads", "2",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { sources, sinks, threads, route, .. } => {
                assert_eq!(sources.len(), 2);
                assert_eq!(sinks.len(), 2);
                assert_eq!(threads, 2);
                assert_eq!(route, RoutePolicy::Broadcast);
                assert!(matches!(sinks[0], Sink::File(..)));
                assert!(matches!(sinks[1], Sink::Null));
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_route_and_udp_geometry() {
        let cmd = parse(&sv(&[
            "input", "udp", "0.0.0.0:3333", "--geometry", "346x260", "input", "udp",
            "0.0.0.0:4444", "--geometry", "128x128", "output", "null", "output", "null",
            "--route", "polarity",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { sources, route, .. } => {
                assert_eq!(route, RoutePolicy::Polarity);
                match &sources[0] {
                    Source::Udp { geometry, .. } => {
                        assert_eq!(*geometry, Some(Resolution::new(346, 260)));
                    }
                    _ => panic!("wrong parse"),
                }
            }
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&[
            "input", "synthetic", "output", "null", "--route", "zigzag",
        ]))
        .is_err());
    }

    #[test]
    fn duration_units() {
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("500ms").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration("1500us").unwrap(), Duration::from_micros(1500));
        assert_eq!(parse_duration("3").unwrap(), Duration::from_secs(3));
        assert!(parse_duration("5fortnights").is_err());
    }

    #[test]
    fn geometry_syntax() {
        assert_eq!(parse_geometry("346x260").unwrap(), Resolution::new(346, 260));
        assert!(parse_geometry("346").is_err());
        assert!(parse_geometry("0x260").is_err());
        assert!(parse_geometry("axb").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(&sv(&["input"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "file", "y.weird"])).is_err());
        assert!(parse(&sv(&["frobnicate"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "null", "extra"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "null", "--threads"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
    }
}
