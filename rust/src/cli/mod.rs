//! Command-line interface — the paper's Fig. 2(B) composition syntax.
//!
//! ```text
//! aestream input file recording.aedat output udp 127.0.0.1:3333
//! aestream input synthetic --duration 2s filter polarity on output stdout
//! aestream input udp 0.0.0.0:3333 output file out.aedat
//! aestream input synthetic input synthetic output file fused.aedat output null --threads 2
//! aestream scenarios --duration 2s --time-scale 20
//! aestream table1
//! ```
//!
//! Hand-rolled parsing (no clap offline): a token-stream grammar of
//! `input <spec>… [filter <name> <args>…]* output <spec>…` mirrors the
//! original AEStream CLI's free input/output pairing. Repeating
//! `input`/`output` clauses builds a fan-in/fan-out topology: the
//! inputs are merged in timestamp order onto a canvas (`--layout
//! side-by-side|grid|overlay`, or explicit per-input `--offset X,Y` —
//! declaring both is an error) and the outputs are fed per `--route`
//! (broadcast by default). `branch [filter …]* output …` clauses give
//! each output its *own* filter chain — the multi-branch graph shape.
//! The whole clause syntax is sugar: everything lowers onto a
//! [`crate::stream::GraphSpec`] through
//! [`crate::coordinator::stream::lower_to_graph`], and a golden test
//! asserts the lowering matches the hand-built builder graph.
//!
//! Filters parse into a deferred [`PipelineSpec`], **not** a built
//! pipeline: geometry-keyed stages (refractory, denoise, flips) are
//! instantiated by the coordinator from the *opened* sources' primed
//! headers, never from parse-time assumptions. `--shards N` spreads
//! every shardable stage over N stripe-shard workers (append `@serial`
//! to a filter to pin it); `--shard-threads` gives each shard worker
//! its own OS thread.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::aer::{Polarity, Resolution};
use crate::camera::CameraConfig;
use crate::coordinator::stream::{
    AdaptiveConfig, BranchSpec, DiskBufferConfig, FusionLayout, Input, ReplaySpeed,
    ReportTarget, RoutePolicy, Sink, Source, StreamConfig, StreamDriver,
};
use crate::formats::Format;
use crate::pipeline::{ops, PipelineSpec, StageSpec};
use crate::serve::ListenerConfig;
use crate::stream::adapt::parse_controllers;

/// A parsed CLI invocation.
pub enum Command {
    /// `input …+ [filter …]* ( output …+ | branch [filter …]* output … )
    /// [--chunk N] [--sync] [--threads N] [--route R] [--layout L]
    /// [--shards N] [--shard-threads]`
    Stream {
        /// One or more inputs (several fan in through the merge), each
        /// with its optional explicit canvas offset.
        inputs: Vec<Input>,
        /// The shared filter chain, deferred until geometry is known.
        spec: PipelineSpec,
        /// One or more fan-out branches. Legacy `output` clauses parse
        /// as chain-free branches; `branch [filter …]* output …`
        /// clauses carry their own filter chain — the declarative
        /// topology graph's multi-branch shape.
        branches: Vec<BranchSpec>,
        /// Chunking and edge-driver configuration.
        config: StreamConfig,
        /// `--threads N`: 0/1 keeps every source on the executor
        /// thread; ≥ 2 pins each source to its own OS thread.
        threads: usize,
        /// How events are distributed across the outputs.
        route: RoutePolicy,
        /// How fused inputs are arranged on the canvas.
        layout: FusionLayout,
        /// Shard workers per shardable filter stage.
        shards: usize,
        /// One OS thread per shard worker.
        shard_threads: bool,
        /// One OS-thread pump per sink (`--sink-threads`).
        sink_threads: bool,
        /// Adaptive controllers (`--adaptive skew,chunk --epoch N`).
        adaptive: Option<AdaptiveConfig>,
        /// Stream one JSON line per telemetry epoch, plus a final
        /// report line, to a file or `-` for stdout (`--report-json`).
        report_json: Option<ReportTarget>,
        /// `--decode-threads N|auto`: decode worker budget for the
        /// shared codec plane (`None` keeps decode inline on each
        /// ingest thread; `auto` derives from `available_parallelism`).
        decode_threads: Option<usize>,
        /// `--buffer disk=<dir>[:cap_bytes]`: make every output edge
        /// durable — each sink drains through its own crash-safe disk
        /// journal under `<dir>/out{j}` (`None` / `--buffer memory`
        /// keeps pure-memory edges).
        buffer: Option<DiskBufferConfig>,
    },
    /// Run the four Fig. 4 scenarios.
    Scenarios {
        /// Synthetic recording length (µs).
        duration_us: u64,
        /// Replay speed multiplier.
        time_scale: f64,
    },
    /// Print the Table 1 feature matrix.
    Table1,
    /// Print usage.
    Help,
}

/// Parse a full argv (excluding the program name).
pub fn parse(args: &[String]) -> Result<Command> {
    let mut toks = args.iter().map(String::as_str).peekable();
    match toks.peek() {
        None => Ok(Command::Help),
        Some(&"help") | Some(&"--help") | Some(&"-h") => Ok(Command::Help),
        Some(&"table1") => Ok(Command::Table1),
        Some(&"scenarios") => {
            toks.next();
            let mut duration_us = 1_000_000;
            let mut time_scale = 10.0;
            while let Some(tok) = toks.next() {
                match tok {
                    "--duration" => {
                        duration_us = parse_duration(
                            toks.next().context("--duration needs a value")?,
                        )?
                        .as_micros() as u64
                    }
                    "--time-scale" => {
                        time_scale = toks
                            .next()
                            .context("--time-scale needs a value")?
                            .parse()
                            .context("bad --time-scale")?
                    }
                    other => bail!("unknown scenarios flag {other}"),
                }
            }
            Ok(Command::Scenarios { duration_us, time_scale })
        }
        Some(&"input") => parse_stream(&mut toks),
        Some(other) => bail!("unknown command {other:?}; try `aestream help`"),
    }
}

fn parse_input<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Input> {
    let kind = toks.next().context("input needs a kind")?;
    let mut path = None;
    let mut bind = None;
    match kind {
        "file" => path = Some(PathBuf::from(toks.next().context("input file needs a path")?)),
        "udp" => bind = Some(toks.next().context("input udp needs an address")?.to_string()),
        "tcp-listen" | "http-listen" => {
            bind = Some(
                toks.next()
                    .with_context(|| format!("input {kind} needs a bind address"))?
                    .to_string(),
            )
        }
        "synthetic" => {}
        "replay" => {
            path = Some(PathBuf::from(
                toks.next().context("input replay needs a journal directory")?,
            ))
        }
        other => bail!(
            "unknown input kind {other:?} (file|udp|tcp-listen|http-listen|synthetic|replay)"
        ),
    }
    let listener = matches!(kind, "tcp-listen" | "http-listen");
    // Per-input flags, any order after the positional part.
    let mut geometry = None;
    let mut offset = None;
    let mut duration_us = 1_000_000u64;
    let mut window = None;
    let mut max_clients = None;
    let mut from_offset = 0u64;
    let mut speed = ReplaySpeed::default();
    loop {
        match toks.peek() {
            Some(&"--geometry") => {
                toks.next();
                geometry =
                    Some(parse_geometry(toks.next().context("--geometry needs WxH")?)?);
            }
            Some(&"--offset") => {
                toks.next();
                offset = Some(parse_offset(toks.next().context("--offset needs X,Y")?)?);
            }
            Some(&"--duration") if kind == "synthetic" => {
                toks.next();
                duration_us = parse_duration(toks.next().context("--duration needs a value")?)?
                    .as_micros() as u64;
            }
            Some(&"--window") if listener => {
                toks.next();
                let n: usize = toks
                    .next()
                    .context("--window needs an event count")?
                    .parse()
                    .context("bad --window")?;
                if n == 0 {
                    bail!("--window must be at least 1 event");
                }
                window = Some(n);
            }
            Some(&"--max-clients") if listener => {
                toks.next();
                let n: usize = toks
                    .next()
                    .context("--max-clients needs a count")?
                    .parse()
                    .context("bad --max-clients")?;
                if n == 0 {
                    bail!("--max-clients must be at least 1");
                }
                max_clients = Some(n);
            }
            Some(&"--from-offset") if kind == "replay" => {
                toks.next();
                from_offset = toks
                    .next()
                    .context("--from-offset needs a record count")?
                    .parse()
                    .context("bad --from-offset")?;
            }
            Some(&"--speed") if kind == "replay" => {
                toks.next();
                let value = toks.next().context("--speed needs orig|max")?;
                speed = ReplaySpeed::parse(value)
                    .with_context(|| format!("--speed must be orig|max, got {value:?}"))?;
            }
            _ => break,
        }
    }
    let source = match kind {
        "file" => Source::File { path: path.expect("parsed above"), geometry },
        "udp" => Source::Udp {
            bind: bind.expect("parsed above"),
            idle_timeout: Duration::from_millis(500),
            geometry,
        },
        "tcp-listen" | "http-listen" => {
            // Clients attach to a fixed canvas at runtime; there is
            // nothing to observe before they do.
            let geometry = geometry.with_context(|| {
                format!("input {kind} needs --geometry WxH (the canvas clients send into)")
            })?;
            let mut config = ListenerConfig::new(geometry);
            if let Some(window) = window {
                config = config.window(window);
            }
            if let Some(max) = max_clients {
                config = config.max_clients(max);
            }
            let bind = bind.expect("parsed above");
            if kind == "tcp-listen" {
                Source::TcpListen { bind, config }
            } else {
                Source::HttpListen { bind, config }
            }
        }
        "synthetic" => {
            if geometry.is_some() {
                bail!("input synthetic has a fixed geometry; drop --geometry");
            }
            Source::Synthetic { config: CameraConfig::default(), duration_us }
        }
        "replay" => {
            if geometry.is_some() {
                bail!("input replay observes geometry from the journal; drop --geometry");
            }
            Source::Replay { dir: path.expect("parsed above"), from_offset, speed }
        }
        _ => unreachable!("kind validated above"),
    };
    if listener && offset.is_some() {
        bail!("listener inputs cannot take --offset: the declared canvas joins the layout whole");
    }
    Ok(Input { source, offset })
}

fn parse_output<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Sink> {
    Ok(match toks.next().context("output needs a kind")? {
        "file" => {
            let path = PathBuf::from(toks.next().context("output file needs a path")?);
            let format = path
                .extension()
                .and_then(|e| e.to_str())
                .and_then(Format::from_extension)
                .context("cannot infer output format from extension")?;
            Sink::File(path, format)
        }
        "udp" => Sink::Udp(toks.next().context("output udp needs an address")?.to_string()),
        "stdout" => Sink::Stdout,
        "null" => Sink::Null,
        "frames" => {
            let window_us = toks
                .next()
                .context("output frames needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::Frames { window_us }
        }
        "view" => {
            let window_us = toks
                .next()
                .context("output view needs a window (µs)")?
                .parse()
                .context("bad window")?;
            Sink::View { window_us, max_frames: 8 }
        }
        "subscribe" => Sink::Subscribe {
            bind: toks.next().context("output subscribe needs a bind address")?.to_string(),
        },
        other => {
            bail!("unknown output kind {other:?} (file|udp|stdout|null|frames|view|subscribe)")
        }
    })
}

/// Parse one `filter NAME ARGS… [@serial]` clause into a deferred
/// stage. Geometry-keyed filters (refractory, denoise, flips) capture
/// their arguments only; the coordinator builds them for the *opened*
/// canvas.
fn parse_filter<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<StageSpec> {
    let name = toks.next().context("filter needs a name")?;
    let stage = match name {
        "polarity" => {
            let which = toks.next().context("filter polarity needs on|off")?;
            let p = match which {
                "on" => Polarity::On,
                "off" => Polarity::Off,
                other => bail!("polarity must be on|off, got {other:?}"),
            };
            StageSpec::new(move |_| ops::PolarityFilter::keep(p))
        }
        "crop" => {
            let mut dims = [0u16; 4];
            for d in dims.iter_mut() {
                *d = toks
                    .next()
                    .context("filter crop needs x0 y0 w h")?
                    .parse()
                    .context("bad crop dimension")?;
            }
            StageSpec::new(move |_| ops::RoiCrop::new(dims[0], dims[1], dims[2], dims[3]))
        }
        "downsample" => {
            let f: u16 = toks
                .next()
                .context("filter downsample needs a factor")?
                .parse()
                .context("bad factor")?;
            StageSpec::new(move |_| ops::Downsample::new(f))
        }
        "refractory" => {
            let us: u64 = toks
                .next()
                .context("filter refractory needs µs")?
                .parse()
                .context("bad refractory period")?;
            StageSpec::new(move |res: Resolution| ops::RefractoryFilter::new(res, us))
        }
        "denoise" => {
            let us: u64 = toks
                .next()
                .context("filter denoise needs µs")?
                .parse()
                .context("bad denoise window")?;
            StageSpec::new(move |res: Resolution| ops::BackgroundActivityFilter::new(res, us))
        }
        "flip-x" => StageSpec::new(|res: Resolution| ops::FlipX::new(res.width)),
        "flip-y" => StageSpec::new(|res: Resolution| ops::FlipY::new(res.height)),
        "transpose" => StageSpec::new(|_| ops::Transpose),
        "time-shift" => {
            let us: u64 = toks
                .next()
                .context("filter time-shift needs µs")?
                .parse()
                .context("bad time-shift offset")?;
            StageSpec::new(move |_| ops::TimeShift::new(us))
        }
        other => bail!("unknown filter {other:?}"),
    };
    if toks.peek() == Some(&"@serial") {
        toks.next();
        Ok(stage.pinned())
    } else {
        Ok(stage)
    }
}

fn parse_stream<'a, I: Iterator<Item = &'a str>>(
    toks: &mut std::iter::Peekable<I>,
) -> Result<Command> {
    // ---- inputs (one or more clauses fan in)
    let mut inputs = Vec::new();
    while toks.peek() == Some(&"input") {
        toks.next();
        inputs.push(parse_input(toks)?);
    }
    debug_assert!(!inputs.is_empty(), "parse_stream is entered on `input`");

    // ---- filters (one shared stage chain, geometry deferred)
    let mut spec = PipelineSpec::new();
    while toks.peek() == Some(&"filter") {
        toks.next();
        spec.push(parse_filter(toks)?);
    }

    // ---- outputs: plain `output` clauses (chain-free fan-out), or
    // `branch [filter …]* output …` clauses, each carrying its own
    // filter chain (the multi-branch graph shape). The two forms don't
    // mix — a branch *is* an output with a chain.
    let mut branches: Vec<BranchSpec> = Vec::new();
    match toks.peek() {
        Some(&"output") => {
            while toks.peek() == Some(&"output") {
                toks.next();
                branches.push(parse_output(toks)?.into());
            }
            if toks.peek() == Some(&"branch") {
                bail!("mixing bare `output` clauses with `branch` clauses is ambiguous; \
                       wrap every output in a branch");
            }
        }
        Some(&"branch") => {
            while toks.peek() == Some(&"branch") {
                toks.next();
                let mut branch_spec = PipelineSpec::new();
                while toks.peek() == Some(&"filter") {
                    toks.next();
                    branch_spec.push(parse_filter(toks)?);
                }
                match toks.next() {
                    Some("output") => branches
                        .push(BranchSpec { spec: branch_spec, sink: parse_output(toks)? }),
                    other => bail!("branch needs an `output` clause, got {other:?}"),
                }
            }
            if toks.peek() == Some(&"output") {
                bail!("mixing bare `output` clauses with `branch` clauses is ambiguous; \
                       wrap every output in a branch");
            }
        }
        other => bail!("expected `output` or `branch`, got {other:?}"),
    }

    // ---- streaming options
    let mut config = StreamConfig::default();
    let mut threads = 1usize;
    let mut route = RoutePolicy::Broadcast;
    let mut layout = FusionLayout::default();
    let mut layout_set = false;
    let mut shards = 1usize;
    let mut shard_threads = false;
    let mut sink_threads = false;
    let mut controllers = None;
    let mut epoch_batches: Option<u64> = None;
    let mut report_json = None;
    let mut decode_threads = None;
    let mut buffer = None;
    while let Some(tok) = toks.next() {
        match tok {
            "--chunk" => {
                config.chunk_size = toks
                    .next()
                    .context("--chunk needs an event count")?
                    .parse()
                    .context("bad --chunk")?;
                if config.chunk_size == 0 {
                    bail!("--chunk must be at least 1");
                }
            }
            "--sync" => config.driver = StreamDriver::Sync,
            "--threads" => {
                threads = toks
                    .next()
                    .context("--threads needs a count")?
                    .parse()
                    .context("bad --threads")?;
            }
            "--route" => {
                route = match toks.next().context("--route needs a policy")? {
                    "broadcast" => RoutePolicy::Broadcast,
                    "polarity" => RoutePolicy::Polarity,
                    "stripes" => RoutePolicy::Stripes,
                    other => bail!("unknown route {other:?} (broadcast|polarity|stripes)"),
                };
            }
            "--layout" => {
                layout = match toks.next().context("--layout needs a name")? {
                    "side-by-side" => FusionLayout::SideBySide,
                    "grid" => FusionLayout::Grid,
                    "overlay" => FusionLayout::Overlay,
                    other => bail!("unknown layout {other:?} (side-by-side|grid|overlay)"),
                };
                layout_set = true;
            }
            "--shards" => {
                shards = toks
                    .next()
                    .context("--shards needs a count")?
                    .parse()
                    .context("bad --shards")?;
                if shards == 0 {
                    bail!("--shards must be at least 1");
                }
            }
            "--shard-threads" => shard_threads = true,
            "--sink-threads" => sink_threads = true,
            "--adaptive" => {
                controllers = Some(parse_controllers(
                    toks.next().context("--adaptive needs a controller list")?,
                )?);
            }
            "--epoch" => {
                let n: u64 = toks
                    .next()
                    .context("--epoch needs a batch count")?
                    .parse()
                    .context("bad --epoch")?;
                if n == 0 {
                    bail!("--epoch must be at least 1 batch");
                }
                epoch_batches = Some(n);
            }
            "--report-json" => {
                report_json = Some(ReportTarget::parse(
                    toks.next().context("--report-json needs a path (or - for stdout)")?,
                ));
            }
            "--decode-threads" => {
                let value = toks.next().context("--decode-threads needs a count (or auto)")?;
                decode_threads = Some(if value == "auto" {
                    crate::stream::CodecPlaneConfig::default().workers
                } else {
                    let n: usize = value.parse().context("bad --decode-threads")?;
                    if n == 0 {
                        bail!("--decode-threads must be at least 1 (or auto)");
                    }
                    n
                });
            }
            "--buffer" => {
                buffer = parse_buffer(
                    toks.next().context("--buffer needs memory or disk=<dir>[:cap_bytes]")?,
                )?;
            }
            extra => bail!("unexpected trailing argument {extra:?}"),
        }
    }
    // `--layout` and per-input `--offset` both claim the canvas. The
    // old behavior — offsets silently winning, documented but invisible
    // at runtime — is now a parse error (and `GraphSpec::validate()`
    // rejects the same conflict for library users).
    if layout_set && inputs.iter().any(|input| input.offset.is_some()) {
        bail!(
            "--layout conflicts with explicit --offset placements: offsets define \
             the canvas themselves — drop one of the two"
        );
    }
    let adaptive = match (controllers, epoch_batches) {
        (Some(kinds), epoch) => {
            let mut cfg = AdaptiveConfig::new(kinds);
            if let Some(epoch) = epoch {
                cfg = cfg.with_epoch(epoch);
            }
            Some(cfg)
        }
        (None, Some(_)) => bail!("--epoch needs --adaptive to act on"),
        (None, None) => None,
    };
    Ok(Command::Stream {
        inputs,
        spec,
        branches,
        config,
        threads,
        route,
        layout,
        shards,
        shard_threads,
        sink_threads,
        adaptive,
        report_json,
        decode_threads,
        buffer,
    })
}

/// Parse the `--buffer` edge-durability policy: `memory` (the default
/// pure-memory edge) or `disk=<dir>[:cap_bytes]` for a crash-safe
/// journal per output edge, capped at `cap_bytes` on disk (default
/// 1 GiB when omitted).
fn parse_buffer(s: &str) -> Result<Option<DiskBufferConfig>> {
    if s == "memory" {
        return Ok(None);
    }
    let dir = s
        .strip_prefix("disk=")
        .with_context(|| format!("--buffer must be memory or disk=<dir>[:cap_bytes], got {s:?}"))?;
    const DEFAULT_CAP_BYTES: u64 = 1 << 30;
    let (dir, cap_bytes) = match dir.rsplit_once(':') {
        Some((dir, cap)) => {
            let cap: u64 = cap
                .parse()
                .with_context(|| format!("bad --buffer cap_bytes {cap:?}"))?;
            if cap == 0 {
                bail!("--buffer disk cap_bytes must be > 0");
            }
            (dir, cap)
        }
        None => (dir, DEFAULT_CAP_BYTES),
    };
    if dir.is_empty() {
        bail!("--buffer disk needs a journal directory");
    }
    Ok(Some(DiskBufferConfig::new(PathBuf::from(dir), cap_bytes)))
}

/// Filter reference rendered from the op registry
/// ([`crate::pipeline::registry::transform_ops`]), so the help text can
/// never drift from what is actually registered: one line per op with
/// its argument usage and declared parallelization class.
pub fn filters_help() -> String {
    use crate::pipeline::TransformClass;
    let mut out = String::from("FILTERS (from the op registry; append @serial to pin):\n");
    for op in crate::pipeline::registry::transform_ops() {
        let class = match op.class {
            TransformClass::Stateless => "stateless, shardable".to_string(),
            TransformClass::Stateful { halo } => format!("stateful, shardable (halo {halo})"),
            TransformClass::Barrier => "barrier, single node".to_string(),
        };
        out.push_str(&format!("  {:<24} {}\n", op.usage, class));
    }
    out
}

/// Parse `"X,Y"` into a canvas offset.
pub fn parse_offset(s: &str) -> Result<(u16, u16)> {
    let (x, y) = s.split_once(',').with_context(|| format!("offset {s:?} must be X,Y"))?;
    Ok((
        x.parse().with_context(|| format!("bad offset x {x:?}"))?,
        y.parse().with_context(|| format!("bad offset y {y:?}"))?,
    ))
}

/// Parse `"500ms"`, `"2s"`, `"1500us"`, or a bare number of seconds.
pub fn parse_duration(s: &str) -> Result<Duration> {
    let (num, unit) = match s.find(|c: char| c.is_alphabetic()) {
        Some(i) => s.split_at(i),
        None => (s, "s"),
    };
    let value: f64 = num.parse().with_context(|| format!("bad duration {s:?}"))?;
    let secs = match unit {
        "s" => value,
        "ms" => value / 1e3,
        "us" | "µs" => value / 1e6,
        other => bail!("unknown duration unit {other:?}"),
    };
    Ok(Duration::from_secs_f64(secs))
}

/// Parse `"346x260"` into a [`Resolution`].
pub fn parse_geometry(s: &str) -> Result<Resolution> {
    let (w, h) = s.split_once('x').with_context(|| format!("geometry {s:?} must be WxH"))?;
    let width = w.parse().with_context(|| format!("bad geometry width {w:?}"))?;
    let height = h.parse().with_context(|| format!("bad geometry height {h:?}"))?;
    if width == 0 || height == 0 {
        bail!("geometry must be at least 1x1");
    }
    Ok(Resolution::new(width, height))
}

/// Usage text.
pub const USAGE: &str = "\
aestream — accelerated event-based processing with coroutines (reproduction)

USAGE:
  aestream input <file PATH [--geometry WxH] | udp ADDR [--geometry WxH] |
                  tcp-listen ADDR --geometry WxH [--window N] [--max-clients N] |
                  http-listen ADDR --geometry WxH [--window N] [--max-clients N] |
                  synthetic [--duration D] |
                  replay DIR [--from-offset N] [--speed orig|max]> [--offset X,Y] ...
           [filter <polarity on|off | crop X Y W H | downsample F |
                    refractory US | denoise US | flip-x | flip-y |
                    transpose | time-shift US> [@serial]]...
           ( output <file PATH | udp ADDR | stdout | null | frames WINDOW_US |
                     view WINDOW_US | subscribe ADDR>...
           | branch [filter <...> [@serial]]... output <...> ... )
           [--chunk EVENTS] [--sync] [--threads N]
           [--route broadcast|polarity|stripes]
           [--layout side-by-side|grid|overlay]
           [--shards N] [--shard-threads] [--sink-threads]
           [--adaptive skew,chunk,client-window] [--epoch BATCHES]
           [--report-json PATH|-] [--decode-threads N|auto]
           [--buffer memory|disk=<dir>[:cap_bytes]]
  aestream scenarios [--duration D] [--time-scale X]
  aestream table1
  aestream help

Streams run incrementally (O(chunk) memory) on the coroutine driver;
--chunk sets the batch size (default 4096) and --sync selects the
synchronous baseline driver instead.

Repeat `input` to fan several sources in: they merge in timestamp
order onto a canvas laid out by --layout (side-by-side default, grid,
or overlay), or at explicit per-input --offset X,Y positions. Live UDP
inputs and headerless recordings must declare --geometry to join a
fused topology. Repeat `output` to fan out; --route picks broadcast
(default), polarity (ON→first, OFF→second), or vertical stripes.
--threads 2+ pins each source to its own OS thread, feeding the
coroutine executor through a lock-free ring.

Repeat `branch [filter …]* output …` instead of bare outputs to give
every output its own filter chain: the merged stream splits per
--route and each branch runs its private filters before its sink (one
merge, several independent stage chains — the multi-device fan-out
shape). --layout and per-input --offset both claim the canvas, so
combining them is an error (offsets alone define explicit placements).

Filters build for the geometry the *opened* inputs report (fused
canvas included). --shards N runs every shardable filter as N
stripe-shard nodes re-merged in order (append @serial to a filter to
pin it to one node); --shard-threads gives each shard its own OS
thread, and --sink-threads gives each output its own pump thread so a
slow file/UDP sink backpressures through a bounded queue instead of
stalling the router. An idle live input stalls fusion only for a
bounded grace, then heartbeats so its siblings keep flowing (stalls
are counted in the report).

--adaptive turns on the epoch-based adaptive runtime: every --epoch
batches (default 32) the driver samples live per-node counters and the
named controllers act — `skew` re-cuts shard stripe boundaries from
the observed per-shard load (stateful filters hand per-column state to
the new owners, so output stays byte-identical to serial), `chunk`
AIMD-tunes the batch size against edge backpressure. Third-party
controllers registered via stream::register_controller(name, factory)
resolve by name here too. The report lists every epoch, re-cut (skew
before/after), and chunk change.

`input tcp-listen ADDR --geometry WxH` serves the topology over the
network: any number of clients connect while it runs, each sending raw
little-endian SPIF words over TCP (http-listen accepts the same words
as HTTP POST bodies). Every client becomes its own merge lane behind a
credit window (--window, default 8192 events in flight), so memory
stays bounded by clients × window; --max-clients caps admission. The
`client-window` adaptive controller AIMD-tunes each client's window
from observed credit stalls. `output subscribe ADDR` is the mirror:
TCP consumers attach at runtime and receive every processed batch as
SPIF words; a slow consumer drops deliveries and is eventually
evicted, never stalling the pipeline. --report-json streams one JSON
line per telemetry epoch (and a final full report) to a file or `-`
for stdout — per-client windows, stalls, and admissions included.

--decode-threads N (or `auto`) moves packed-format decode off the
ingest threads onto a shared pool of N codec workers: readers hand raw
byte buffers to the pool, splittable formats (raw, evt2, aedat2, dat,
spif) decode in parallel slices, and sequence-keyed reassembly keeps
every stream's event order byte-identical to inline decode. The pool
is the process-wide decode budget — thread count stays N no matter how
many files or clients are in flight.

--buffer disk=<dir>[:cap_bytes] makes every output edge durable: each
sink drains through its own crash-safe append-only journal under
<dir>/out{j} (length-prefixed, CRC32-framed record batches), so a slow
or crashing sink spills to disk instead of growing memory — the
in-memory front stays bounded and disk use stays under cap_bytes
(default 1 GiB). On restart, `input replay <dir>/out{j}` re-serves the
recorded edge through the normal source API, byte-identical and in
order; --from-offset N skips the first N records (pair it with the
journal's acked offset for at-least-once resume) and --speed orig
paces emission to the recorded timestamps (default `max` replays as
fast as possible). The report counts bytes_on_disk, records
spilled/replayed, and corrupt records skipped.

EXAMPLES (paper Fig. 2B and §6 fusion):
  aestream input file recording.aedat output udp 10.0.0.1:3333
  aestream input synthetic --duration 2s filter polarity on output stdout
  aestream input synthetic input synthetic \\
           output file fused.aedat output view 10000 --threads 2
  aestream input file a.raw --geometry 346x260 --offset 0,0 \\
           input file b.raw --geometry 346x260 --offset 0,260 \\
           filter denoise 1000 output file fused.aedat --shards 4
  aestream input udp 0.0.0.0:3333 --geometry 346x260 \\
           filter denoise 1000 output file out.aedat \\
           --shards 4 --adaptive skew,chunk --epoch 64 --sink-threads
  aestream input synthetic input synthetic \\
           filter denoise 1000 \\
           branch filter polarity on output file on.aedat \\
           branch filter refractory 100 output frames 10000
  aestream input tcp-listen 0.0.0.0:7777 --geometry 346x260 \\
           filter denoise 1000 output subscribe 0.0.0.0:7778 \\
           --adaptive client-window --report-json -
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_paper_example() {
        let cmd =
            parse(&sv(&["input", "file", "r.aedat", "output", "udp", "1.2.3.4:3333"])).unwrap();
        match cmd {
            Command::Stream { inputs, branches, .. } => {
                assert_eq!(inputs.len(), 1);
                assert_eq!(branches.len(), 1);
                assert_eq!(inputs[0].offset, None);
                assert!(branches[0].spec.is_empty(), "bare outputs carry no chain");
                match (&inputs[0].source, &branches[0].sink) {
                    (Source::File { path, geometry }, Sink::Udp(a)) => {
                        assert_eq!(*path, PathBuf::from("r.aedat"));
                        assert_eq!(*geometry, None);
                        assert_eq!(a, "1.2.3.4:3333");
                    }
                    _ => panic!("wrong parse"),
                }
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_branch_clauses_with_private_chains() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "denoise", "1000", "branch", "filter", "polarity",
            "on", "output", "null", "branch", "output", "frames", "5000",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { spec, branches, .. } => {
                assert_eq!(spec.describe(), "denoise(1000µs)", "shared chain");
                assert_eq!(branches.len(), 2);
                assert_eq!(branches[0].spec.describe(), "polarity(on)");
                assert!(matches!(branches[0].sink, Sink::Null));
                assert!(branches[1].spec.is_empty());
                assert!(matches!(branches[1].sink, Sink::Frames { window_us: 5000 }));
            }
            _ => panic!("wrong parse"),
        }
        // A branch without an output is malformed.
        assert!(parse(&sv(&[
            "input", "synthetic", "branch", "filter", "polarity", "on",
        ]))
        .is_err());
        // Mixing bare outputs with branches is rejected, in either order.
        for args in [
            &["input", "synthetic", "branch", "output", "null", "output", "null"][..],
            &["input", "synthetic", "output", "null", "branch", "output", "null"][..],
        ] {
            let err = format!("{}", parse(&sv(args)).unwrap_err());
            assert!(err.contains("branch"), "got {err}");
        }
    }

    /// The `--layout`-vs-`--offset` bugfix: the old parser accepted
    /// both and silently ignored the layout at runtime; now the
    /// conflict is a parse error (and `GraphSpec::validate()` rejects
    /// the same combination for library users).
    #[test]
    fn layout_with_explicit_offsets_is_rejected() {
        let err = parse(&sv(&[
            "input", "file", "a.raw", "--geometry", "128x128", "--offset", "0,0", "input",
            "file", "b.raw", "--geometry", "128x128", "--offset", "0,128", "output", "null",
            "--layout", "grid",
        ]))
        .unwrap_err();
        assert!(format!("{err}").contains("--offset"), "got {err}");
        // Offsets alone stay fine (they define the canvas themselves)…
        parse(&sv(&[
            "input", "file", "a.raw", "--geometry", "128x128", "--offset", "0,0", "input",
            "file", "b.raw", "--geometry", "128x128", "--offset", "0,128", "output", "null",
        ]))
        .unwrap();
        // …and so does an explicit layout without offsets.
        parse(&sv(&[
            "input", "synthetic", "input", "synthetic", "output", "null", "--layout", "grid",
        ]))
        .unwrap();
    }

    #[test]
    fn parses_filters_in_order() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "polarity", "on", "filter", "downsample", "2",
            "output", "null",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { spec, .. } => {
                assert_eq!(spec.describe(), "polarity(on) | downsample(/2)");
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn filters_defer_geometry_and_accept_pinning() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "refractory", "100", "filter", "denoise", "1000",
            "@serial", "output", "null", "--shards", "4",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { spec, shards, shard_threads, .. } => {
                assert_eq!(shards, 4);
                assert!(!shard_threads);
                assert_eq!(spec.describe(), "refractory(100µs) | denoise(1000µs)");
                assert!(!spec.stages()[0].is_pinned());
                assert!(spec.stages()[1].is_pinned(), "@serial must pin the stage");
                // Geometry injection happens at build time, per canvas.
                let res = Resolution::new(32, 32);
                let mut a = spec.build_pipeline(res);
                let mut b = crate::pipeline::Pipeline::new()
                    .then(ops::RefractoryFilter::new(res, 100))
                    .then(ops::BackgroundActivityFilter::new(res, 1000));
                let events = crate::testutil::synthetic_events(500, 32, 32);
                assert_eq!(a.process(&events), b.process(&events));
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_layout_offset_and_file_geometry() {
        let cmd = parse(&sv(&[
            "input", "file", "a.raw", "--geometry", "128x128", "--offset", "0,0", "input",
            "file", "b.raw", "--geometry", "128x128", "--offset", "0,128", "output", "null",
            "--shard-threads",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { inputs, layout, shards, shard_threads, .. } => {
                assert_eq!(layout, FusionLayout::SideBySide, "offsets leave the default");
                assert_eq!(shards, 1);
                assert!(shard_threads);
                assert_eq!(inputs[0].offset, Some((0, 0)));
                assert_eq!(inputs[1].offset, Some((0, 128)));
                match &inputs[1].source {
                    Source::File { geometry, .. } => {
                        assert_eq!(*geometry, Some(Resolution::new(128, 128)));
                    }
                    _ => panic!("wrong parse"),
                }
            }
            _ => panic!("wrong parse"),
        }
        match parse(&sv(&[
            "input", "synthetic", "input", "synthetic", "output", "null", "--layout", "grid",
        ]))
        .unwrap()
        {
            Command::Stream { layout, .. } => assert_eq!(layout, FusionLayout::Grid),
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&[
            "input", "synthetic", "output", "null", "--layout", "diagonal",
        ]))
        .is_err());
        assert!(parse(&sv(&["input", "synthetic", "output", "null", "--shards", "0"]))
            .is_err());
        assert!(parse(&sv(&[
            "input", "synthetic", "--geometry", "10x10", "output", "null",
        ]))
        .is_err());
    }

    #[test]
    fn parses_scenarios_flags() {
        let cmd =
            parse(&sv(&["scenarios", "--duration", "500ms", "--time-scale", "5"])).unwrap();
        match cmd {
            Command::Scenarios { duration_us, time_scale } => {
                assert_eq!(duration_us, 500_000);
                assert_eq!(time_scale, 5.0);
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_adaptive_flags() {
        use crate::stream::ControllerKind;
        let cmd = parse(&sv(&[
            "input", "synthetic", "filter", "denoise", "1000", "output", "null", "--shards",
            "4", "--adaptive", "skew,chunk", "--epoch", "16", "--sink-threads",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { adaptive, sink_threads, shards, .. } => {
                assert!(sink_threads);
                assert_eq!(shards, 4);
                let adaptive = adaptive.expect("--adaptive parsed");
                assert_eq!(
                    adaptive.controllers,
                    vec![ControllerKind::Skew, ControllerKind::Chunk]
                );
                assert_eq!(adaptive.epoch_batches, 16);
            }
            _ => panic!("wrong parse"),
        }
        // Default epoch when only --adaptive is given.
        match parse(&sv(&["input", "synthetic", "output", "null", "--adaptive", "skew"]))
            .unwrap()
        {
            Command::Stream { adaptive, sink_threads, .. } => {
                assert!(!sink_threads);
                let adaptive = adaptive.expect("--adaptive parsed");
                assert_eq!(adaptive.controllers, vec![ControllerKind::Skew]);
                assert_eq!(
                    adaptive.epoch_batches,
                    crate::stream::adapt::DEFAULT_EPOCH_BATCHES
                );
            }
            _ => panic!("wrong parse"),
        }
        // No controllers at all ⇒ no adaptive runtime.
        match parse(&sv(&["input", "synthetic", "output", "null"])).unwrap() {
            Command::Stream { adaptive, .. } => assert!(adaptive.is_none()),
            _ => panic!("wrong parse"),
        }
        // Rejections: bad controller, zero epoch, orphan --epoch.
        assert!(parse(&sv(&[
            "input", "synthetic", "output", "null", "--adaptive", "psychic",
        ]))
        .is_err());
        assert!(parse(&sv(&[
            "input", "synthetic", "output", "null", "--adaptive", "skew", "--epoch", "0",
        ]))
        .is_err());
        assert!(parse(&sv(&["input", "synthetic", "output", "null", "--epoch", "8"]))
            .is_err());
    }

    #[test]
    fn parses_streaming_flags() {
        let cmd = parse(&sv(&[
            "input", "synthetic", "output", "null", "--chunk", "512", "--sync",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { config, threads, route, layout, shards, shard_threads, .. } => {
                assert_eq!(config.chunk_size, 512);
                assert_eq!(config.driver, StreamDriver::Sync);
                assert_eq!(threads, 1);
                assert_eq!(route, RoutePolicy::Broadcast);
                assert_eq!(layout, FusionLayout::SideBySide);
                assert_eq!(shards, 1);
                assert!(!shard_threads);
            }
            _ => panic!("wrong parse"),
        }
        // Defaults: coroutine driver, 4096-event chunks.
        match parse(&sv(&["input", "synthetic", "output", "null"])).unwrap() {
            Command::Stream { config, .. } => {
                assert_eq!(config.chunk_size, 4096);
                assert_ne!(config.driver, StreamDriver::Sync);
            }
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&["input", "synthetic", "output", "null", "--chunk", "0"])).is_err());
    }

    #[test]
    fn parses_multi_io_topology() {
        // The acceptance-criteria invocation shape.
        let cmd = parse(&sv(&[
            "input", "synthetic", "--duration", "50ms", "input", "synthetic", "--duration",
            "50ms", "output", "file", "fused.aedat", "output", "null", "--threads", "2",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { inputs, branches, threads, route, .. } => {
                assert_eq!(inputs.len(), 2);
                assert_eq!(branches.len(), 2);
                assert_eq!(threads, 2);
                assert_eq!(route, RoutePolicy::Broadcast);
                assert!(matches!(branches[0].sink, Sink::File(..)));
                assert!(matches!(branches[1].sink, Sink::Null));
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_replay_input() {
        let cmd = parse(&sv(&[
            "input", "replay", "/tmp/journal/out0", "--from-offset", "1000", "--speed",
            "orig", "output", "null",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { inputs, .. } => match &inputs[0].source {
                Source::Replay { dir, from_offset, speed } => {
                    assert_eq!(*dir, PathBuf::from("/tmp/journal/out0"));
                    assert_eq!(*from_offset, 1000);
                    assert_eq!(*speed, ReplaySpeed::Orig);
                }
                _ => panic!("wrong parse"),
            },
            _ => panic!("wrong parse"),
        }
        // Defaults: offset 0, max-speed replay.
        match parse(&sv(&["input", "replay", "j", "output", "null"])).unwrap() {
            Command::Stream { inputs, .. } => match &inputs[0].source {
                Source::Replay { from_offset, speed, .. } => {
                    assert_eq!(*from_offset, 0);
                    assert_eq!(*speed, ReplaySpeed::Max);
                }
                _ => panic!("wrong parse"),
            },
            _ => panic!("wrong parse"),
        }
        // Rejections: geometry is observed from the journal; bad speed;
        // replay-only flags on other input kinds; missing dir.
        assert!(parse(&sv(&[
            "input", "replay", "j", "--geometry", "10x10", "output", "null",
        ]))
        .is_err());
        assert!(parse(&sv(&[
            "input", "replay", "j", "--speed", "warp", "output", "null",
        ]))
        .is_err());
        assert!(parse(&sv(&[
            "input", "synthetic", "--from-offset", "5", "output", "null",
        ]))
        .is_err());
        assert!(parse(&sv(&["input", "replay", "output", "null"])).is_err());
    }

    #[test]
    fn parses_buffer_flag() {
        match parse(&sv(&[
            "input", "synthetic", "output", "null", "--buffer", "disk=/tmp/buf:65536",
        ]))
        .unwrap()
        {
            Command::Stream { buffer, .. } => {
                let buffer = buffer.expect("--buffer disk parsed");
                assert_eq!(buffer.dir, PathBuf::from("/tmp/buf"));
                assert_eq!(buffer.cap_bytes, 65536);
            }
            _ => panic!("wrong parse"),
        }
        // Cap defaults to 1 GiB when omitted.
        match parse(&sv(&[
            "input", "synthetic", "output", "null", "--buffer", "disk=/tmp/buf",
        ]))
        .unwrap()
        {
            Command::Stream { buffer, .. } => {
                assert_eq!(buffer.expect("parsed").cap_bytes, 1 << 30);
            }
            _ => panic!("wrong parse"),
        }
        // `memory` is the explicit default; bad shapes are rejected.
        match parse(&sv(&[
            "input", "synthetic", "output", "null", "--buffer", "memory",
        ]))
        .unwrap()
        {
            Command::Stream { buffer, .. } => assert!(buffer.is_none()),
            _ => panic!("wrong parse"),
        }
        for bad in ["tape=/tmp/x", "disk=", "disk=/tmp/x:0", "disk=/tmp/x:lots"] {
            assert!(
                parse(&sv(&["input", "synthetic", "output", "null", "--buffer", bad]))
                    .is_err(),
                "--buffer {bad} should be rejected"
            );
        }
    }

    /// `--adaptive` resolves third-party controller names through the
    /// registry, end to end from the CLI string.
    #[test]
    fn adaptive_resolves_registered_controllers() {
        use crate::stream::adapt::registry;
        use crate::stream::{Controller, EpochSample, Reconfigure};
        struct Noop;
        impl Controller for Noop {
            fn observe(&mut self, _s: &EpochSample) -> Vec<Reconfigure> {
                Vec::new()
            }
            fn describe(&self) -> String {
                "noop".into()
            }
        }
        registry::register_controller("cli-noop", || Box::new(Noop)).unwrap();
        match parse(&sv(&[
            "input", "synthetic", "output", "null", "--adaptive", "cli-noop,skew",
        ]))
        .unwrap()
        {
            Command::Stream { adaptive, .. } => {
                let adaptive = adaptive.expect("--adaptive parsed");
                assert_eq!(
                    adaptive.controllers,
                    vec![
                        crate::stream::ControllerKind::Custom("cli-noop".into()),
                        crate::stream::ControllerKind::Skew,
                    ]
                );
                // The config builds into a live runtime through the registry.
                assert_eq!(adaptive.build().unwrap().controllers.len(), 2);
            }
            _ => panic!("wrong parse"),
        }
    }

    #[test]
    fn parses_route_and_udp_geometry() {
        let cmd = parse(&sv(&[
            "input", "udp", "0.0.0.0:3333", "--geometry", "346x260", "input", "udp",
            "0.0.0.0:4444", "--geometry", "128x128", "output", "null", "output", "null",
            "--route", "polarity",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { inputs, route, .. } => {
                assert_eq!(route, RoutePolicy::Polarity);
                match &inputs[0].source {
                    Source::Udp { geometry, .. } => {
                        assert_eq!(*geometry, Some(Resolution::new(346, 260)));
                    }
                    _ => panic!("wrong parse"),
                }
            }
            _ => panic!("wrong parse"),
        }
        assert!(parse(&sv(&[
            "input", "synthetic", "output", "null", "--route", "zigzag",
        ]))
        .is_err());
    }

    #[test]
    fn parses_serving_clauses() {
        let cmd = parse(&sv(&[
            "input",
            "tcp-listen",
            "0.0.0.0:7777",
            "--geometry",
            "346x260",
            "--window",
            "4096",
            "--max-clients",
            "64",
            "output",
            "subscribe",
            "0.0.0.0:7778",
            "--adaptive",
            "client-window",
            "--report-json",
            "-",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { inputs, branches, adaptive, report_json, .. } => {
                match &inputs[0].source {
                    Source::TcpListen { bind, config } => {
                        assert_eq!(bind, "0.0.0.0:7777");
                        assert_eq!(config.geometry, Resolution::new(346, 260));
                        assert_eq!(config.window, 4096);
                        assert_eq!(config.max_clients, 64);
                    }
                    _ => panic!("wrong parse"),
                }
                assert!(
                    matches!(&branches[0].sink, Sink::Subscribe { bind } if bind == "0.0.0.0:7778")
                );
                assert_eq!(
                    adaptive.expect("--adaptive parsed").controllers,
                    vec![crate::stream::ControllerKind::ClientWindow]
                );
                assert_eq!(report_json, Some(ReportTarget::Stdout));
            }
            _ => panic!("wrong parse"),
        }
        // http-listen parses the same shape.
        match parse(&sv(&[
            "input", "http-listen", "0.0.0.0:8080", "--geometry", "128x128", "output", "null",
            "--report-json", "epochs.jsonl",
        ]))
        .unwrap()
        {
            Command::Stream { inputs, report_json, .. } => {
                assert!(matches!(&inputs[0].source, Source::HttpListen { .. }));
                assert_eq!(
                    report_json,
                    Some(ReportTarget::File(PathBuf::from("epochs.jsonl")))
                );
            }
            _ => panic!("wrong parse"),
        }
        // Listeners cannot observe geometry: declaring it is mandatory.
        let err = format!(
            "{}",
            parse(&sv(&["input", "tcp-listen", "0.0.0.0:7777", "output", "null"]))
                .unwrap_err()
        );
        assert!(err.contains("--geometry"), "got {err}");
        // A listener's canvas joins the layout whole: no --offset.
        assert!(parse(&sv(&[
            "input", "tcp-listen", ":7777", "--geometry", "8x8", "--offset", "0,0", "output",
            "null",
        ]))
        .is_err());
        // Zero-sized windows and client caps are rejected.
        assert!(parse(&sv(&[
            "input", "tcp-listen", ":7777", "--geometry", "8x8", "--window", "0", "output",
            "null",
        ]))
        .is_err());
        assert!(parse(&sv(&[
            "input", "tcp-listen", ":7777", "--geometry", "8x8", "--max-clients", "0",
            "output", "null",
        ]))
        .is_err());
    }

    #[test]
    fn duration_units() {
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("500ms").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration("1500us").unwrap(), Duration::from_micros(1500));
        assert_eq!(parse_duration("3").unwrap(), Duration::from_secs(3));
        assert!(parse_duration("5fortnights").is_err());
    }

    #[test]
    fn geometry_syntax() {
        assert_eq!(parse_geometry("346x260").unwrap(), Resolution::new(346, 260));
        assert!(parse_geometry("346").is_err());
        assert!(parse_geometry("0x260").is_err());
        assert!(parse_geometry("axb").is_err());
    }

    #[test]
    fn offset_syntax() {
        assert_eq!(parse_offset("0,0").unwrap(), (0, 0));
        assert_eq!(parse_offset("346,0").unwrap(), (346, 0));
        assert!(parse_offset("346").is_err());
        assert!(parse_offset("a,b").is_err());
    }

    /// Anti-drift: every op in the registry must parse on the CLI (so a
    /// new registry entry without a `parse_filter` arm fails here), and
    /// the rendered filter help covers exactly the registered set.
    #[test]
    fn cli_filters_cover_the_registry() {
        let help = filters_help();
        for op in crate::pipeline::registry::transform_ops() {
            assert!(help.contains(op.usage), "help missing op {:?}", op.name);
            // Canonical argument vector per op; extend when adding ops.
            let args: Vec<&str> = match op.name {
                "polarity" => vec!["polarity", "on"],
                "crop" => vec!["crop", "0", "0", "8", "8"],
                "downsample" => vec!["downsample", "2"],
                "refractory" => vec!["refractory", "100"],
                "denoise" => vec!["denoise", "1000"],
                "flip-x" => vec!["flip-x"],
                "flip-y" => vec!["flip-y"],
                "transpose" => vec!["transpose"],
                "time-shift" => vec!["time-shift", "50"],
                other => panic!("registry op {other:?} has no CLI test args — add them"),
            };
            let mut toks = args.iter().copied().peekable();
            let stage = parse_filter(&mut toks)
                .unwrap_or_else(|e| panic!("op {:?} failed to parse: {e}", op.name));
            assert_eq!(stage.class(), op.class, "op {:?}: CLI stage class drifted", op.name);
            assert!(toks.peek().is_none(), "op {:?} left unconsumed args", op.name);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(&sv(&["input"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "file", "y.weird"])).is_err());
        assert!(parse(&sv(&["frobnicate"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "null", "extra"])).is_err());
        assert!(parse(&sv(&["input", "file", "x", "output", "null", "--threads"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
    }
}
