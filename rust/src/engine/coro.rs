//! The coroutine engine: Fig. 1(B) of the paper.
//!
//! Two forms, both stackless coroutines:
//!
//! * [`run_checksum`] — **direct transfer** (the Fig. 3 contender): the
//!   producer is a [`crate::rt::Generator`] the consumer polls inline.
//!   Per-event handoff is one state-machine advance — the "overhead
//!   comparable to a regular function call" of the paper's C++20
//!   symmetric transfer. No buffers, no locks, no scheduler.
//! * [`run_checksum_channel`] — **scheduled transfer** (ablation): a
//!   producer/consumer task pair on the [`crate::rt::LocalExecutor`]
//!   exchanging events through an async channel. This is what a
//!   pipeline with real concurrent I/O uses; the `filter_ablation`
//!   bench quantifies its scheduling overhead against direct transfer.

use crate::aer::checksum::CoordinateChecksum;
use crate::aer::Event;
use crate::rt::generator::drive;
use crate::rt::{channel, LocalExecutor};
use std::cell::Cell;

/// Fig. 3 contender: producer coroutine polled directly by the consumer
/// via the zero-dispatch [`drive`] (C++20 symmetric-transfer analog).
pub fn run_checksum(events: &[Event]) -> CoordinateChecksum {
    let mut sum = CoordinateChecksum::new();
    drive(
        |y| async move {
            for ev in events {
                y.yield_item(*ev).await;
            }
        },
        |ev: Event| sum.push(&ev),
    );
    sum
}

/// Drive an arbitrary per-event workload through the direct-transfer
/// coroutine. Returns the number of events processed.
pub fn for_each<F: FnMut(&Event)>(events: &[Event], mut work: F) -> u64 {
    let mut n = 0u64;
    drive(
        |y| async move {
            for ev in events {
                y.yield_item(*ev).await;
            }
        },
        |ev: Event| {
            work(&ev);
            n += 1;
        },
    );
    n
}

/// Cross-thread coroutine variant (§6: "more work is needed to explore
/// further concurrency and parallelism"): the producer coroutine runs on
/// its own OS thread and feeds the consumer coroutine through the
/// lock-free [`crate::rt::sync_channel`] — coroutines *and* pipeline
/// parallelism, still without a mutex on the event path.
pub fn run_checksum_parallel(events: &[Event], ring_capacity: usize) -> CoordinateChecksum {
    use crate::rt::{block_on, sync_channel};
    let (mut tx, mut rx) = sync_channel::<Event>(ring_capacity.max(2));
    std::thread::scope(|scope| {
        let consumer = scope.spawn(move || {
            block_on(async move {
                let mut local = CoordinateChecksum::new();
                while let Some(ev) = rx.recv().await {
                    local.push(&ev);
                }
                local
            })
        });
        block_on(async move {
            for ev in events {
                if tx.send(*ev).await.is_err() {
                    return;
                }
            }
        });
        consumer.join().expect("consumer panicked")
    })
}

/// Ablation variant: the same pipeline through the run-queue executor
/// and an async channel of the given capacity.
pub fn run_checksum_channel(events: &[Event], channel_capacity: usize) -> CoordinateChecksum {
    let result = Cell::new(CoordinateChecksum::new());
    {
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel::<Event>(channel_capacity.max(1));
        ex.spawn(async move {
            for ev in events {
                // If the consumer is gone the stream is dead; stop.
                if tx.send(*ev).await.is_err() {
                    return;
                }
            }
        });
        let result_ref = &result;
        ex.spawn(async move {
            let mut local = CoordinateChecksum::new();
            while let Some(ev) = rx.recv().await {
                local.push(&ev);
            }
            result_ref.set(local);
        });
        ex.run();
    }
    result.get()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::checksum::reference_checksum;
    use crate::testutil::synthetic_events;

    #[test]
    fn direct_transfer_matches_reference() {
        let events = synthetic_events(3000, 346, 260);
        assert_eq!(run_checksum(&events), reference_checksum(&events));
    }

    #[test]
    fn direct_transfer_empty_stream() {
        assert_eq!(run_checksum(&[]), CoordinateChecksum::new());
    }

    #[test]
    fn parallel_variant_matches_reference() {
        let events = synthetic_events(20_000, 346, 260);
        for cap in [4, 256, 4096] {
            assert_eq!(
                run_checksum_parallel(&events, cap),
                reference_checksum(&events),
                "cap={cap}"
            );
        }
    }

    #[test]
    fn parallel_variant_empty_stream() {
        assert_eq!(run_checksum_parallel(&[], 8), CoordinateChecksum::new());
    }

    #[test]
    fn channel_variant_matches_reference() {
        let events = synthetic_events(3000, 346, 260);
        for cap in [1, 16, 256, 4096] {
            assert_eq!(
                run_checksum_channel(&events, cap),
                reference_checksum(&events),
                "cap={cap}"
            );
        }
    }

    #[test]
    fn for_each_preserves_order() {
        let events = synthetic_events(500, 64, 64);
        let mut seen = Vec::new();
        let n = for_each(&events, |e| seen.push(*e));
        assert_eq!(n, 500);
        assert_eq!(seen, events);
    }

    #[test]
    fn zero_capacity_clamped_to_one() {
        let events = synthetic_events(10, 8, 8);
        assert_eq!(run_checksum_channel(&events, 0), reference_checksum(&events));
    }
}
