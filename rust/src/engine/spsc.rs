//! Lock-free ablation engine: two threads over a wait-free SPSC ring.
//!
//! §2.1 of the paper notes that lock-*free* approaches exist but are
//! "rarely suitable for practical use". This engine makes that claim
//! testable: a producer thread pushes packed events into the
//! [`crate::sync::spsc`] ring and a consumer thread pops them — no
//! mutexes, but (unlike coroutines) a real thread boundary with cache
//! traffic and, on a loaded machine, scheduler interference. The
//! `filter_ablation` bench compares it against both Fig. 3 contenders.

use crate::aer::checksum::CoordinateChecksum;
use crate::aer::Event;
use crate::sync::spsc::spsc_ring;

/// Run the checksum workload across a lock-free ring between two threads.
pub fn run_checksum(events: &[Event], ring_capacity: usize) -> CoordinateChecksum {
    let (mut tx, mut rx) = spsc_ring::<Event>(ring_capacity.max(2));
    std::thread::scope(|scope| {
        let consumer = scope.spawn(move || {
            let mut local = CoordinateChecksum::new();
            while let Some(ev) = rx.pop_blocking() {
                local.push(&ev);
            }
            local
        });
        for ev in events {
            if !tx.push_blocking(*ev) {
                break; // consumer died
            }
        }
        drop(tx); // close the ring: consumer drains then exits
        consumer.join().expect("consumer panicked")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::checksum::reference_checksum;
    use crate::testutil::synthetic_events;

    #[test]
    fn matches_reference() {
        let events = synthetic_events(20_000, 346, 260);
        for cap in [2, 64, 4096] {
            assert_eq!(run_checksum(&events, cap), reference_checksum(&events), "cap={cap}");
        }
    }

    #[test]
    fn tiny_stream() {
        let events = synthetic_events(3, 16, 16);
        assert_eq!(run_checksum(&events, 2), reference_checksum(&events));
    }
}
