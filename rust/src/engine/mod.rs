//! Concurrency engines — the contenders of the paper's Fig. 3 benchmark.
//!
//! All engines solve the same problem: ferry a stream of events from a
//! producer to one or more consumers that apply a per-event workload
//! (the coordinate checksum of §4.1), and return the merged result. They
//! differ *only* in the synchronization mechanism:
//!
//! | Engine | Paper analog | Mechanism |
//! |---|---|---|
//! | [`sync`] | dashed baseline in Fig. 3 | direct function call per event, single thread, zero synchronization |
//! | [`threaded`] | "threads" (Fig. 1A) | producer fills fixed-size buffers, hands them through a `Mutex<VecDeque>` + `Condvar` to worker threads |
//! | [`coro`] | "coroutines" (Fig. 1B) | producer/consumer stackless coroutines with per-event cooperative handoff, no locks |
//! | [`spsc`] | §2.1's lock-free alternative (ablation) | producer thread → consumer thread over a wait-free ring |
//!
//! Every engine is verified against [`crate::aer::checksum::reference_checksum`]
//! at the end of each run, exactly as the paper verifies its checksum.

pub mod coro;
pub mod spsc;
pub mod sync;
pub mod threaded;

use crate::aer::checksum::CoordinateChecksum;
use crate::aer::Event;

/// Which engine to run — used by benches, the coordinator and the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Single-threaded direct call (no synchronization baseline).
    Sync,
    /// Lock-based buffered threading with the given buffer size and
    /// worker count.
    Threaded { buffer_size: usize, workers: usize },
    /// Coroutines with direct control transfer (generator polled by the
    /// consumer) — the paper's Fig. 3 contender.
    Coro,
    /// Coroutines through the run-queue executor + an async channel of
    /// the given capacity (scheduled transfer; ablation).
    CoroChannel { channel_capacity: usize },
    /// Lock-free SPSC ring between two threads (ablation).
    Spsc { ring_capacity: usize },
}

impl EngineKind {
    /// Human-readable name used in bench reports.
    pub fn label(&self) -> String {
        match self {
            EngineKind::Sync => "sync".into(),
            EngineKind::Threaded { buffer_size, workers } => {
                format!("threads(buf={buffer_size},n={workers})")
            }
            EngineKind::Coro => "coro".into(),
            EngineKind::CoroChannel { channel_capacity } => {
                format!("coro-chan(cap={channel_capacity})")
            }
            EngineKind::Spsc { ring_capacity } => format!("spsc(cap={ring_capacity})"),
        }
    }

    /// Run the checksum workload over `events` with this engine.
    pub fn run_checksum(&self, events: &[Event]) -> CoordinateChecksum {
        match *self {
            EngineKind::Sync => sync::run_checksum(events),
            EngineKind::Threaded { buffer_size, workers } => {
                threaded::run_checksum(events, buffer_size, workers)
            }
            EngineKind::Coro => coro::run_checksum(events),
            EngineKind::CoroChannel { channel_capacity } => {
                coro::run_checksum_channel(events, channel_capacity)
            }
            EngineKind::Spsc { ring_capacity } => spsc::run_checksum(events, ring_capacity),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::checksum::reference_checksum;
    use crate::testutil::synthetic_events;

    /// Every engine must produce exactly the reference checksum — this is
    /// the cross-engine equivalence invariant the whole Fig. 3 benchmark
    /// rests on.
    #[test]
    fn all_engines_agree_with_reference() {
        let events = synthetic_events(10_000, 346, 260);
        let expected = reference_checksum(&events);
        let kinds = [
            EngineKind::Sync,
            EngineKind::Threaded { buffer_size: 256, workers: 1 },
            EngineKind::Threaded { buffer_size: 1024, workers: 2 },
            EngineKind::Threaded { buffer_size: 4096, workers: 4 },
            EngineKind::Coro,
            EngineKind::CoroChannel { channel_capacity: 1 },
            EngineKind::CoroChannel { channel_capacity: 64 },
            EngineKind::Spsc { ring_capacity: 1024 },
        ];
        for kind in kinds {
            let got = kind.run_checksum(&events);
            assert_eq!(got.sum, expected.sum, "engine {} checksum mismatch", kind.label());
            assert_eq!(got.count, expected.count, "engine {} count mismatch", kind.label());
        }
    }

    #[test]
    fn empty_stream_all_engines() {
        for kind in [
            EngineKind::Sync,
            EngineKind::Threaded { buffer_size: 256, workers: 2 },
            EngineKind::Coro,
            EngineKind::CoroChannel { channel_capacity: 1 },
            EngineKind::Spsc { ring_capacity: 16 },
        ] {
            let got = kind.run_checksum(&[]);
            assert_eq!(got.count, 0, "engine {}", kind.label());
            assert_eq!(got.sum, 0, "engine {}", kind.label());
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(EngineKind::Sync.label(), "sync");
        assert_eq!(
            EngineKind::Threaded { buffer_size: 256, workers: 2 }.label(),
            "threads(buf=256,n=2)"
        );
        assert_eq!(EngineKind::Coro.label(), "coro");
        assert_eq!(
            EngineKind::CoroChannel { channel_capacity: 1 }.label(),
            "coro-chan(cap=1)"
        );
        assert_eq!(EngineKind::Spsc { ring_capacity: 8 }.label(), "spsc(cap=8)");
    }
}
