//! The conventional lock-based engine: Fig. 1(A) of the paper.
//!
//! "…one or more threads wait for fixed-size buffers to process. To
//! create the buffers, a single thread reads from a massive event array
//! cached in RAM…" (§4.1). The producer copies events into fixed-size
//! `Vec<Event>` buffers and hands them to workers through a
//! `Mutex<VecDeque>` + `Condvar` — the textbook synchronized queue the
//! paper benchmarks against. The locking cost, buffer-fill latency and
//! wake-up latency are precisely what the coroutine engine eliminates.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::aer::checksum::CoordinateChecksum;
use crate::aer::Event;

/// Shared state between the producer and the worker pool.
struct SharedQueue {
    queue: Mutex<QueueState>,
    /// Workers wait here for buffers; the producer waits here for space.
    ready: Condvar,
    space: Condvar,
}

struct QueueState {
    buffers: VecDeque<Vec<Event>>,
    /// Producer finished: workers drain and exit.
    done: bool,
}

/// Maximum number of filled buffers in flight before the producer blocks.
///
/// Two, i.e. double buffering — exactly the design the paper's Fig. 1(A)
/// depicts: the IO thread fills one buffer while the worker drains the
/// other, and each full buffer "activates" the waiting side. A deeper
/// queue would amortize the wake-up latency the paper is measuring
/// (and is swept explicitly by the `filter_ablation` bench).
const MAX_QUEUED_BUFFERS: usize = 2;

/// Run the checksum workload through the lock-based buffered pipeline.
///
/// * `buffer_size` — events per hand-off buffer (the paper sweeps 2^8,
///   2^10, 2^12);
/// * `workers` — number of consumer threads (≥ 1).
pub fn run_checksum(events: &[Event], buffer_size: usize, workers: usize) -> CoordinateChecksum {
    let buffer_size = buffer_size.max(1);
    let workers = workers.max(1);
    let shared = SharedQueue {
        queue: Mutex::new(QueueState { buffers: VecDeque::new(), done: false }),
        ready: Condvar::new(),
        space: Condvar::new(),
    };

    std::thread::scope(|scope| {
        // ------------------------------------------------------- workers
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(scope.spawn(|| {
                let mut local = CoordinateChecksum::new();
                loop {
                    let buffer = {
                        let mut state = shared.queue.lock().unwrap();
                        loop {
                            if let Some(buf) = state.buffers.pop_front() {
                                shared.space.notify_one();
                                break Some(buf);
                            }
                            if state.done {
                                break None;
                            }
                            state = shared.ready.wait(state).unwrap();
                        }
                    };
                    match buffer {
                        // Per-event work, identical to the sync and
                        // coroutine engines: the benchmark isolates the
                        // synchronization cost, so no engine may get a
                        // differently-shaped (e.g. vectorized) inner loop.
                        Some(buf) => {
                            for ev in &buf {
                                local.push(ev);
                            }
                        }
                        None => return local,
                    }
                }
            }));
        }

        // ------------------------------------------------------ producer
        // The producer is this thread: fill buffers and hand them over.
        for chunk in events.chunks(buffer_size) {
            // The copy into a fresh Vec is part of what's being measured:
            // the buffered design pays it, the coroutine design doesn't.
            let buf = chunk.to_vec();
            let mut state = shared.queue.lock().unwrap();
            while state.buffers.len() >= MAX_QUEUED_BUFFERS {
                state = shared.space.wait(state).unwrap();
            }
            state.buffers.push_back(buf);
            drop(state);
            shared.ready.notify_one();
        }
        {
            let mut state = shared.queue.lock().unwrap();
            state.done = true;
        }
        shared.ready.notify_all();

        // --------------------------------------------------------- merge
        let mut total = CoordinateChecksum::new();
        for h in handles {
            total.merge(&h.join().expect("worker panicked"));
        }
        total
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::checksum::reference_checksum;
    use crate::testutil::synthetic_events;

    #[test]
    fn matches_reference_single_worker() {
        let events = synthetic_events(5000, 346, 260);
        assert_eq!(run_checksum(&events, 256, 1), reference_checksum(&events));
    }

    #[test]
    fn matches_reference_many_workers() {
        let events = synthetic_events(5000, 346, 260);
        for workers in [2, 4, 8] {
            assert_eq!(
                run_checksum(&events, 128, workers),
                reference_checksum(&events),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn buffer_size_larger_than_stream() {
        let events = synthetic_events(10, 64, 64);
        assert_eq!(run_checksum(&events, 4096, 2), reference_checksum(&events));
    }

    #[test]
    fn buffer_size_one_degenerates_gracefully() {
        let events = synthetic_events(100, 64, 64);
        assert_eq!(run_checksum(&events, 1, 1), reference_checksum(&events));
    }

    #[test]
    fn zero_params_are_clamped() {
        let events = synthetic_events(50, 64, 64);
        assert_eq!(run_checksum(&events, 0, 0), reference_checksum(&events));
    }
}
