//! The no-synchronization baseline: "a single-threaded
//! non-synchronization method" (paper §4.1), i.e. a plain function call
//! per event. This is the dashed black line in Fig. 3 — the upper bound
//! any synchronization mechanism is measured against.

use crate::aer::checksum::CoordinateChecksum;
use crate::aer::Event;

/// Run the checksum workload with direct calls, no threads, no buffers.
pub fn run_checksum(events: &[Event]) -> CoordinateChecksum {
    let mut sum = CoordinateChecksum::new();
    for ev in events {
        sum.push(ev);
    }
    sum
}

/// Generic single-threaded drive: apply `work` to every event in order.
/// Used by the pipeline when no concurrency is requested.
pub fn for_each<F: FnMut(&Event)>(events: &[Event], mut work: F) {
    for ev in events {
        work(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::checksum::reference_checksum;
    use crate::testutil::synthetic_events;

    #[test]
    fn matches_reference_by_construction() {
        let events = synthetic_events(1234, 100, 100);
        assert_eq!(run_checksum(&events), reference_checksum(&events));
    }

    #[test]
    fn for_each_visits_in_order() {
        let events = synthetic_events(10, 8, 8);
        let mut seen = Vec::new();
        for_each(&events, |e| seen.push(*e));
        assert_eq!(seen, events);
    }
}
