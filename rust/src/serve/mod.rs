//! Network serving plane: multi-client ingest/egress for running
//! topologies.
//!
//! The paper's closing discussion (§6) points at exactly this shape:
//! "sending multiple inputs to a single neuromorphic compute platform"
//! over commodity transport. The streaming layer already fans N
//! *declared* sources into one timestamp-ordered merge; this module
//! makes the fan-in **dynamic** — a topology keeps serving while TCP
//! and HTTP clients attach and detach at runtime:
//!
//! * [`ClientHub`] ([`hub`]) — the dynamic-client registry behind a
//!   listener. The accept loop admits connections; each admitted client
//!   becomes a [`ClientLane`](crate::stream::ClientLane) the fan-in
//!   merge adopts at its next safe point. Per-client flow control is a
//!   **credit window**: the reader thread may keep at most `window`
//!   events in flight toward the merge, so total serving-plane memory
//!   is bounded by `clients × window` no matter how fast clients push.
//!   The adaptive `client-window` controller
//!   ([`crate::stream::ClientWindowController`]) retunes each window by
//!   AIMD from observed credit stalls.
//! * [`ListenerSource`] ([`listen`]) — the
//!   [`EventSource`](crate::stream::EventSource) face of a hub: a
//!   `tcp-listen` socket speaking raw SPIF-framed words
//!   ([`crate::net::spif`]) over a byte stream, or an `http-listen`
//!   socket accepting `POST` bodies of the same words. It compiles into
//!   a graph as a `Listener` node
//!   ([`crate::stream::GraphSpec`]); the merge discovers its hub
//!   through [`EventSource::client_plane`](crate::stream::EventSource::client_plane).
//! * [`SubscribeSink`] ([`subscribe`]) — the egress mirror: an
//!   [`EventSink`](crate::stream::EventSink) that fans every processed
//!   batch out to N dynamically attached TCP subscribers, each behind
//!   its own bounded queue and writer thread. A slow subscriber is
//!   never allowed to backpressure the trunk: its deliveries are
//!   dropped (counted per subscriber) and a persistently stalled one is
//!   evicted.
//!
//! Every client and subscriber publishes a
//! [`LiveNode`](crate::metrics::LiveNode) into the telemetry plane, so
//! admissions, credit stalls, evictions, and window history all land in
//! [`StreamReport`](crate::stream::StreamReport) — and stream out live
//! through `--report-json`.

pub mod hub;
pub mod listen;
pub mod subscribe;

pub use hub::{ClientHub, ClientIngest};
pub use listen::{ListenerConfig, ListenerSource};
pub use subscribe::SubscribeSink;

/// OS thread label clipped to the 15-byte Linux thread-name limit at a
/// char boundary (`pthread_setname_np` silently rejects longer names).
pub(crate) fn thread_label(name: &str) -> String {
    let mut label = name.to_string();
    let mut end = label.len().min(15);
    while !label.is_char_boundary(end) {
        end -= 1;
    }
    label.truncate(end);
    label
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_labels_fit_the_linux_limit() {
        assert_eq!(thread_label("client:7"), "client:7");
        assert_eq!(thread_label("sub:123456789012345"), "sub:12345678901");
        assert!(thread_label("shard:refractory(100µs):0").len() <= 15);
        // Multi-byte chars never split: truncation lands on a boundary.
        assert_eq!(thread_label("sink:µµµµµµµµµµ"), "sink:µµµµµ");
    }
}
