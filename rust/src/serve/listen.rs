//! Socket listeners: the [`EventSource`] face of a [`ClientHub`].
//!
//! [`ListenerSource::bind_tcp`] serves raw SPIF-framed words over a TCP
//! byte stream (the UDP datagram format of [`crate::net::spif`], minus
//! the 350-word datagram ceiling — words are simply contiguous);
//! [`ListenerSource::bind_http`] serves a minimal `POST` endpoint whose
//! request bodies carry the same little-endian words. Both spawn one
//! accept thread plus one named reader thread per admitted client; the
//! listener itself compiles into a topology as a `Listener` graph node
//! that is polled inline by the fan-in merge (never pumped), acting as
//! a heartbeat while its hub's clients carry the actual data lanes.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::aer::{Event, Resolution};
use crate::net::spif;
use crate::stream::codec_plane::MAX_BACKLOG;
use crate::stream::{ClientPlane, CodecPlane, DecodeStream, EventSource};

use super::hub::{ClientHub, ClientIngest};
use super::thread_label;

/// Read buffer per client connection.
const READ_BUF: usize = 16 * 1024;
/// Poll cadence of the non-blocking accept loop.
const ACCEPT_IDLE: Duration = Duration::from_millis(2);
/// Per-client socket read timeout, so readers notice shutdown.
const READ_TIMEOUT: Duration = Duration::from_millis(100);
/// HTTP requests: header and body ceilings for the minimal parser.
const MAX_HEADER: usize = 64 * 1024;
const MAX_BODY: usize = 4 * 1024 * 1024;

/// How a listener interprets client bytes.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Protocol {
    /// Contiguous little-endian SPIF words on a raw TCP stream.
    Tcp,
    /// `POST` requests whose bodies are the same words.
    Http,
}

/// Tunables for one listener, applied to every admitted client.
#[derive(Clone, Copy, Debug)]
pub struct ListenerConfig {
    /// Canvas events are filtered to; listeners cannot infer geometry
    /// from the wire, so it must be declared.
    pub geometry: Resolution,
    /// Initial per-client credit window (events in flight), retuned
    /// live by the `client-window` AIMD controller.
    pub window: usize,
    /// Admission ceiling on concurrent clients.
    pub max_clients: usize,
    /// End the source once no client has been connected for this long
    /// (`None` serves forever).
    pub idle_timeout: Option<Duration>,
}

impl ListenerConfig {
    /// Defaults: 8192-event windows, 1024 clients, serve forever.
    pub fn new(geometry: Resolution) -> Self {
        ListenerConfig { geometry, window: 8192, max_clients: 1024, idle_timeout: None }
    }

    /// Set the initial per-client credit window.
    pub fn window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Set the admission ceiling.
    pub fn max_clients(mut self, max: usize) -> Self {
        self.max_clients = max;
        self
    }

    /// End the stream after this long with zero connected clients.
    pub fn idle_timeout(mut self, timeout: Duration) -> Self {
        self.idle_timeout = Some(timeout);
        self
    }
}

/// A bound listener: [`EventSource`] heartbeat + [`ClientHub`] plane.
pub struct ListenerSource {
    hub: Arc<ClientHub>,
    local_addr: SocketAddr,
    kind: &'static str,
    accept: Option<JoinHandle<()>>,
    idle_timeout: Option<Duration>,
    idle_since: Option<Instant>,
}

impl ListenerSource {
    /// Bind a raw SPIF-over-TCP listener.
    pub fn bind_tcp<A: ToSocketAddrs>(addr: A, config: ListenerConfig) -> Result<Self> {
        Self::bind(addr, config, Protocol::Tcp)
    }

    /// Bind an HTTP `POST` ingest listener.
    pub fn bind_http<A: ToSocketAddrs>(addr: A, config: ListenerConfig) -> Result<Self> {
        Self::bind(addr, config, Protocol::Http)
    }

    fn bind<A: ToSocketAddrs>(
        addr: A,
        config: ListenerConfig,
        protocol: Protocol,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("serve: bind listener")?;
        listener
            .set_nonblocking(true)
            .context("serve: set listener non-blocking")?;
        let local_addr = listener.local_addr().context("serve: listener local addr")?;
        let hub = ClientHub::new(config.geometry, config.window, config.max_clients);
        let accept_hub = hub.clone();
        let accept = std::thread::Builder::new()
            .name("serve:accept".into())
            .spawn(move || accept_loop(listener, accept_hub, protocol))
            .context("serve: spawn accept thread")?;
        Ok(ListenerSource {
            hub,
            local_addr,
            kind: match protocol {
                Protocol::Tcp => "tcp-listen",
                Protocol::Http => "http-listen",
            },
            accept: Some(accept),
            idle_timeout: config.idle_timeout,
            idle_since: None,
        })
    }

    /// The bound address (with the OS-chosen port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The client registry behind this listener.
    pub fn hub(&self) -> Arc<ClientHub> {
        self.hub.clone()
    }
}

impl EventSource for ListenerSource {
    /// The listener itself never yields events — clients do, through
    /// their own merge lanes. It heartbeats while serving and ends the
    /// stream on shutdown or idle timeout.
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        if self.hub.is_closed() {
            return Ok(None);
        }
        if let Some(timeout) = self.idle_timeout {
            if self.hub.active_clients() == 0 {
                let since = *self.idle_since.get_or_insert_with(Instant::now);
                if since.elapsed() >= timeout {
                    self.hub.shutdown();
                    return Ok(None);
                }
            } else {
                self.idle_since = None;
            }
        }
        Ok(Some(Vec::new()))
    }

    fn resolution(&self) -> Resolution {
        self.hub.geometry()
    }

    fn is_live(&self) -> bool {
        true
    }

    fn describe(&self) -> String {
        format!("{}({})", self.kind, self.local_addr)
    }

    fn client_plane(&self) -> Option<Arc<dyn ClientPlane>> {
        Some(self.hub.clone())
    }

    /// Every client admitted from here on hands its wire bytes to the
    /// shared pool instead of decoding on its reader thread.
    fn set_codec_plane(&mut self, plane: Arc<CodecPlane>) {
        self.hub.set_decode_plane(plane);
    }
}

impl Drop for ListenerSource {
    fn drop(&mut self) {
        self.hub.shutdown();
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

fn accept_loop(listener: TcpListener, hub: Arc<ClientHub>, protocol: Protocol) {
    while !hub.is_closed() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                let prefix = match protocol {
                    Protocol::Tcp => "client",
                    Protocol::Http => "http",
                };
                match hub.admit(prefix) {
                    Some(ingest) => spawn_reader(stream, ingest, protocol),
                    None => refuse(stream, protocol),
                }
            }
            Err(err) if err.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_IDLE);
            }
            Err(err) if err.kind() == ErrorKind::Interrupted => {}
            // Accept errors (e.g. fd pressure) are transient: back off.
            Err(_) => std::thread::sleep(ACCEPT_IDLE),
        }
    }
}

fn spawn_reader(stream: TcpStream, ingest: ClientIngest, protocol: Protocol) {
    let name = thread_label(ingest.name());
    let run = move || match (protocol, ingest.decode_plane()) {
        (Protocol::Tcp, Some(plane)) => read_spif_stream_pooled(stream, &ingest, &plane),
        (Protocol::Tcp, None) => read_spif_stream(stream, &ingest),
        (Protocol::Http, plane) => serve_http(stream, &ingest, plane.as_ref()),
    };
    if let Err(err) = std::thread::Builder::new().name(name).spawn(run) {
        // Thread exhaustion: the dropped ingest counts the disconnect.
        debug_assert!(false, "serve: spawn client reader: {err}");
    }
}

/// Tell a refused connection why, as well as the protocol allows.
fn refuse(mut stream: TcpStream, protocol: Protocol) {
    if protocol == Protocol::Http {
        let _ = respond(&mut stream, "503 Service Unavailable", b"{\"accepted\":0}\n");
    }
    // Raw TCP has no side-channel: dropping the socket is the refusal.
}

/// Decode contiguous little-endian SPIF words off a byte stream,
/// carrying partial words across reads. Events are stamped with their
/// arrival time and filtered to the declared geometry. Any disconnect
/// — polite or abrupt, even mid-word — is a clean end of lane.
fn read_spif_stream(mut stream: TcpStream, ingest: &ClientIngest) {
    let geometry = ingest.geometry();
    let mut buf = [0u8; READ_BUF];
    let mut carry: Vec<u8> = Vec::with_capacity(4);
    loop {
        let read = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(err)
                if err.kind() == ErrorKind::WouldBlock
                    || err.kind() == ErrorKind::TimedOut =>
            {
                if !ingest.open() {
                    break;
                }
                continue;
            }
            Err(err) if err.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        let t = ingest.now_us();
        carry.extend_from_slice(&buf[..read]);
        let whole = carry.len() - carry.len() % 4;
        let mut batch = Vec::with_capacity(whole / 4);
        let mut rejected = 0u64;
        for word in carry[..whole].chunks_exact(4) {
            let ev = spif::unpack_word(u32::from_le_bytes(word.try_into().unwrap()), t);
            if geometry.contains(&ev) {
                batch.push(ev);
            } else {
                rejected += 1;
            }
        }
        carry.drain(..whole);
        if rejected > 0 {
            ingest.count_dropped(rejected);
        }
        if !ingest.push(batch) {
            break;
        }
    }
}

/// [`read_spif_stream`], decoupled: wire bytes go to the shared codec
/// plane and come back in order through the per-stream reassembly, so
/// this thread does socket I/O and credit accounting only. The credit
/// window still blocks *here* — backpressure lands on the reader, never
/// on a decode worker.
fn read_spif_stream_pooled(mut stream: TcpStream, ingest: &ClientIngest, plane: &Arc<CodecPlane>) {
    let mut dstream = plane.open_spif_stream(ingest.geometry());
    let mut buf = [0u8; READ_BUF];
    loop {
        let read = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(err)
                if err.kind() == ErrorKind::WouldBlock
                    || err.kind() == ErrorKind::TimedOut =>
            {
                if !ingest.open() {
                    break;
                }
                // Idle socket: flush anything the workers finished so
                // decoded events never wait on the next wire read.
                let mut batch = Vec::new();
                match dstream.poll(&mut batch) {
                    Ok(rejected) => {
                        if rejected > 0 {
                            ingest.count_dropped(rejected);
                        }
                        if !batch.is_empty() && !ingest.push(batch) {
                            return;
                        }
                    }
                    Err(_) => return,
                }
                continue;
            }
            Err(err) if err.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        if dstream.submit_stamped(&buf[..read], ingest.now_us()).is_err() {
            break;
        }
        let mut batch = Vec::new();
        // A reader that outruns the workers waits here, bounding
        // per-client memory at O(MAX_BACKLOG × piece).
        let drained = if dstream.backlog() > MAX_BACKLOG {
            dstream.poll_wait(&mut batch)
        } else {
            dstream.poll(&mut batch)
        };
        match drained {
            Ok(rejected) => {
                if rejected > 0 {
                    ingest.count_dropped(rejected);
                }
                if !ingest.push(batch) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // Disconnect: drain what is still in flight (a torn trailing word
    // is dropped, exactly as the inline loop drops its carry).
    if dstream.finish().is_err() {
        return;
    }
    let mut batch = Vec::new();
    let mut rejected = 0;
    while !dstream.done() {
        match dstream.poll_wait(&mut batch) {
            Ok(r) => rejected += r,
            Err(_) => return,
        }
    }
    if rejected > 0 {
        ingest.count_dropped(rejected);
    }
    let _ = ingest.push(batch);
}

/// Decode one HTTP request body through the shared pool: submit, then
/// drain to completion so the reply can carry the accepted count. The
/// wire contract (whole words only) is checked up front — the plane
/// carries torn words across submits, which a datagram body must not
/// need.
fn decode_body_pooled(
    dstream: &mut DecodeStream,
    body: &[u8],
    t: u64,
) -> Result<(Vec<Event>, u64)> {
    if body.len() % 4 != 0 {
        anyhow::bail!("spif: body length {} not a multiple of 4", body.len());
    }
    dstream.submit_stamped(body, t)?;
    let mut batch = Vec::new();
    let mut rejected = 0;
    while !dstream.done() {
        rejected += dstream.poll_wait(&mut batch)?;
    }
    Ok((batch, rejected))
}

/// Serve keep-alive HTTP on one connection: `POST` bodies of SPIF
/// words are decoded (on the shared pool, when one is attached),
/// filtered, and pushed as one batch each.
fn serve_http(mut stream: TcpStream, ingest: &ClientIngest, plane: Option<&Arc<CodecPlane>>) {
    let geometry = ingest.geometry();
    let mut dstream = plane.map(|plane| plane.open_spif_stream(geometry));
    let mut pending: Vec<u8> = Vec::new();
    'requests: loop {
        // Accumulate until the blank line ending the request head.
        let head_end = loop {
            if let Some(pos) = find_subslice(&pending, b"\r\n\r\n") {
                break pos + 4;
            }
            if pending.len() > MAX_HEADER {
                let _ = respond(
                    &mut stream,
                    "431 Request Header Fields Too Large",
                    b"header too large\n",
                );
                break 'requests;
            }
            if !read_more(&mut stream, &mut pending, ingest) {
                break 'requests;
            }
        };
        let head = String::from_utf8_lossy(&pending[..head_end]).into_owned();
        let method = head.split_whitespace().next().unwrap_or("").to_string();
        let content_length = head
            .lines()
            .find_map(|line| {
                let (name, value) = line.split_once(':')?;
                name.eq_ignore_ascii_case("content-length")
                    .then(|| value.trim().parse::<usize>().ok())?
            })
            .unwrap_or(0);
        if content_length > MAX_BODY {
            let _ = respond(&mut stream, "413 Payload Too Large", b"body too large\n");
            break;
        }
        while pending.len() < head_end + content_length {
            if !read_more(&mut stream, &mut pending, ingest) {
                break 'requests;
            }
        }
        let body: Vec<u8> = pending[head_end..head_end + content_length].to_vec();
        pending.drain(..head_end + content_length);
        if method != "POST" {
            if respond(&mut stream, "405 Method Not Allowed", b"POST events here\n")
                .is_err()
            {
                break;
            }
            continue;
        }
        let decoded = match &mut dstream {
            Some(dstream) => decode_body_pooled(dstream, &body, ingest.now_us()),
            None => spif::decode_datagram(&body, ingest.now_us()).map(|events| {
                let total = events.len();
                let batch: Vec<Event> =
                    events.into_iter().filter(|ev| geometry.contains(ev)).collect();
                let rejected = (total - batch.len()) as u64;
                (batch, rejected)
            }),
        };
        match decoded {
            Ok((batch, rejected)) => {
                if rejected > 0 {
                    ingest.count_dropped(rejected);
                }
                let accepted = batch.len();
                if !ingest.push(batch) {
                    break;
                }
                let reply = format!("{{\"accepted\":{accepted}}}\n");
                if respond(&mut stream, "200 OK", reply.as_bytes()).is_err() {
                    break;
                }
            }
            Err(_) => {
                if respond(&mut stream, "400 Bad Request", b"body must be u32 words\n")
                    .is_err()
                {
                    break;
                }
            }
        }
    }
}

/// One socket read into `pending`; `false` ends the connection.
fn read_more(stream: &mut TcpStream, pending: &mut Vec<u8>, ingest: &ClientIngest) -> bool {
    let mut buf = [0u8; READ_BUF];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return false,
            Ok(n) => {
                pending.extend_from_slice(&buf[..n]);
                return true;
            }
            Err(err)
                if err.kind() == ErrorKind::WouldBlock
                    || err.kind() == ErrorKind::TimedOut =>
            {
                if !ingest.open() {
                    return false;
                }
            }
            Err(err) if err.kind() == ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Write a minimal `HTTP/1.1` response.
fn respond(stream: &mut TcpStream, status: &str, body: &[u8]) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Length: {}\r\nContent-Type: application/json\r\n\
         Connection: keep-alive\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// First offset of `needle` in `haystack`.
fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|window| window == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search_finds_header_terminator() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"ab\r\ncd", b"\r\n\r\n"), None);
    }

    #[test]
    fn listener_heartbeats_then_times_out_idle() {
        let config = ListenerConfig::new(Resolution::new(8, 8))
            .idle_timeout(Duration::from_millis(20));
        let mut listener = ListenerSource::bind_tcp("127.0.0.1:0", config).unwrap();
        assert!(listener.local_addr().port() != 0);
        // Live idle: heartbeats are empty batches, not end of stream.
        assert!(listener.next_batch().unwrap().unwrap().is_empty());
        std::thread::sleep(Duration::from_millis(40));
        assert!(listener.next_batch().unwrap().is_none(), "idle timeout fired");
        assert!(listener.hub().is_closed());
    }

    #[test]
    fn tcp_client_words_arrive_filtered_and_stamped() {
        let config = ListenerConfig::new(Resolution::new(16, 16));
        let mut listener = ListenerSource::bind_tcp("127.0.0.1:0", config).unwrap();
        let hub = listener.hub();
        let mut client = TcpStream::connect(listener.local_addr()).unwrap();
        let inside = spif::pack_word(&Event::on(3, 4, 0)).to_le_bytes();
        let outside = spif::pack_word(&Event::on(300, 4, 0)).to_le_bytes();
        client.write_all(&inside).unwrap();
        client.write_all(&outside).unwrap();
        client.flush().unwrap();
        // Adopt the lane and poll until the reader thread delivers.
        let mut lanes = hub.take_lanes();
        let deadline = Instant::now() + Duration::from_secs(5);
        while lanes.is_empty() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
            lanes = hub.take_lanes();
        }
        let lane = &mut lanes.pop().expect("client lane admitted");
        let mut got = Vec::new();
        while got.is_empty() && Instant::now() < deadline {
            match lane.source.next_batch().unwrap() {
                Some(batch) => got.extend(batch),
                None => break,
            }
        }
        assert_eq!(got.len(), 1, "out-of-geometry word filtered");
        assert_eq!((got[0].x, got[0].y), (3, 4));
        drop(client);
        let mut dropped = lane.source.dropped();
        while dropped == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
            dropped = lane.source.dropped();
        }
        assert_eq!(dropped, 1, "rejected word counted");
        assert!(listener.next_batch().unwrap().is_some());
    }
}
