//! The dynamic-client registry behind a serving-plane listener.
//!
//! Three parties share a [`ClientHub`]:
//!
//! * the **accept loop** calls [`ClientHub::admit`] per connection and
//!   hands the returned [`ClientIngest`] to a per-client reader thread;
//! * the **fan-in merge** drains freshly admitted lanes through the
//!   [`ClientPlane`] face and pulls batches from each client's
//!   [`EventSource`];
//! * the **adaptive epoch loop** samples cumulative per-client counters
//!   and retargets credit windows
//!   ([`ClientPlane::set_window`]).
//!
//! Flow control is a per-client credit window: a reader may keep at
//! most `window` events in flight toward the merge (one oversized batch
//! is allowed through an empty lane so a window smaller than a wire
//! batch cannot wedge the client). A reader that runs out of credit
//! sleeps in bounded steps — one `backpressure_wait` counted per stall
//! episode on the client's [`LiveNode`] — which is exactly the signal
//! the AIMD `client-window` controller feeds on. Total serving-plane
//! memory is therefore `O(clients × window)` regardless of client
//! behavior.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::aer::{Event, Resolution};
use crate::metrics::LiveNode;
use crate::stream::{ClientLane, ClientPlane, ClientSample, CodecPlane, EventSource};

/// Bounded sleep per credit-wait step: long enough not to burn a core,
/// short enough that a freed window resumes ingest promptly.
const CREDIT_WAIT: Duration = Duration::from_micros(200);

/// Shared per-client state: the reader thread, the merge-side source,
/// and the hub all hold an `Arc` of it.
struct ClientState {
    name: String,
    node: Arc<LiveNode>,
    /// Credit window (events the reader may keep in flight).
    window: AtomicUsize,
    /// Events currently in flight between reader and merge.
    in_flight: AtomicUsize,
    /// Either side departed (reader finished, or the merge dropped the
    /// lane): pushes stop, and the client no longer counts as active.
    gone: AtomicBool,
    /// Events the reader rejected at ingest (outside the declared
    /// geometry, surfaced through [`EventSource::dropped`]).
    dropped: AtomicU64,
}

/// Registry + admission control for one listener's clients.
pub struct ClientHub {
    origin: Instant,
    geometry: Resolution,
    default_window: usize,
    max_clients: usize,
    closed: AtomicBool,
    admitted: AtomicU64,
    refused: AtomicU64,
    disconnected: AtomicU64,
    next_id: AtomicU64,
    inner: Mutex<HubInner>,
    /// Shared decode worker pool, when the topology runs one: readers
    /// hand raw wire bytes to it instead of decoding inline, so the
    /// decode thread budget stays fixed no matter how many clients
    /// connect.
    decode: Mutex<Option<Arc<CodecPlane>>>,
}

struct HubInner {
    clients: Vec<Arc<ClientState>>,
    /// Lanes admitted but not yet adopted by the merge.
    pending: Vec<ClientLane>,
}

impl ClientHub {
    /// A hub admitting up to `max_clients` concurrent clients, each
    /// starting with `window` events of in-flight credit, filtered to
    /// `geometry`.
    pub fn new(geometry: Resolution, window: usize, max_clients: usize) -> Arc<ClientHub> {
        Arc::new(ClientHub {
            origin: Instant::now(),
            geometry,
            default_window: window.max(1),
            max_clients: max_clients.max(1),
            closed: AtomicBool::new(false),
            admitted: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            disconnected: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            inner: Mutex::new(HubInner { clients: Vec::new(), pending: Vec::new() }),
            decode: Mutex::new(None),
        })
    }

    /// Route every client's packed-word decode through `plane` (readers
    /// admitted before this call keep decoding inline).
    pub fn set_decode_plane(&self, plane: Arc<CodecPlane>) {
        *self.decode.lock().unwrap() = Some(plane);
    }

    /// The shared decode pool, when one is attached.
    pub fn decode_plane(&self) -> Option<Arc<CodecPlane>> {
        self.decode.lock().unwrap().clone()
    }

    /// Microseconds since the hub came up — the arrival timestamp
    /// stamped onto wire events (SPIF words carry none by design).
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// The declared canvas every client is filtered to.
    pub fn geometry(&self) -> Resolution {
        self.geometry
    }

    /// Admit one connection: registers the client, queues its lane for
    /// the merge, and returns the reader-side ingest handle. `None`
    /// when the hub is closed or at capacity (counted as refused).
    pub fn admit(self: &Arc<Self>, prefix: &str) -> Option<ClientIngest> {
        if self.is_closed() {
            self.refused.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let active =
            inner.clients.iter().filter(|c| !c.gone.load(Ordering::Relaxed)).count();
        if active >= self.max_clients {
            drop(inner);
            self.refused.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let name = format!("{prefix}:{id}");
        let node = Arc::new(LiveNode::new(name.clone()));
        let state = Arc::new(ClientState {
            name: name.clone(),
            node: node.clone(),
            window: AtomicUsize::new(self.default_window),
            in_flight: AtomicUsize::new(0),
            gone: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
        });
        let (tx, rx) = std::sync::mpsc::channel::<Vec<Event>>();
        let source = ClientSource {
            rx,
            state: state.clone(),
            geometry: self.geometry,
            name,
        };
        inner.clients.push(state.clone());
        inner.pending.push(ClientLane { source: Box::new(source), node });
        drop(inner);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Some(ClientIngest { hub: self.clone(), state, tx: Some(tx) })
    }

    /// Stop admitting and tell every reader and lane to wind down. The
    /// merge sees each client lane end cleanly as its reader exits.
    pub fn shutdown(&self) {
        self.closed.store(true, Ordering::Relaxed);
    }

    /// `true` once [`shutdown`](Self::shutdown) was called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Clients currently connected (admitted and not yet departed).
    pub fn active_clients(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .clients
            .iter()
            .filter(|c| !c.gone.load(Ordering::Relaxed))
            .count()
    }

    /// Connections admitted over the hub's lifetime.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Connections refused (closed hub or at capacity).
    pub fn refused(&self) -> u64 {
        self.refused.load(Ordering::Relaxed)
    }

    /// Clients that connected and have since departed.
    pub fn disconnected(&self) -> u64 {
        self.disconnected.load(Ordering::Relaxed)
    }
}

impl ClientPlane for ClientHub {
    fn take_lanes(&self) -> Vec<ClientLane> {
        std::mem::take(&mut self.inner.lock().unwrap().pending)
    }

    fn client_samples(&self) -> Vec<ClientSample> {
        self.inner
            .lock()
            .unwrap()
            .clients
            .iter()
            .map(|c| {
                let report = c.node.sample();
                ClientSample {
                    name: c.name.clone(),
                    events: report.events,
                    batches: report.batches,
                    backpressure_waits: report.backpressure_waits,
                    window: c.window.load(Ordering::Relaxed),
                }
            })
            .collect()
    }

    fn set_window(&self, client: &str, window: usize) -> bool {
        let inner = self.inner.lock().unwrap();
        match inner.clients.iter().find(|c| c.name == client) {
            Some(state) => {
                state.window.store(window.max(1), Ordering::Relaxed);
                true
            }
            None => false,
        }
    }
}

/// Reader-thread handle for one admitted client: stamp, filter, and
/// push decoded batches under the credit window.
pub struct ClientIngest {
    hub: Arc<ClientHub>,
    state: Arc<ClientState>,
    /// `Option` so `Drop` can sever the channel before counting the
    /// disconnect.
    tx: Option<Sender<Vec<Event>>>,
}

impl ClientIngest {
    /// The client's report name (`client:<id>` / `http:<id>`).
    pub fn name(&self) -> &str {
        &self.state.name
    }

    /// Arrival timestamp for events decoded now.
    pub fn now_us(&self) -> u64 {
        self.hub.now_us()
    }

    /// The geometry to filter decoded events against.
    pub fn geometry(&self) -> Resolution {
        self.hub.geometry()
    }

    /// The shared decode pool to hand wire bytes to, when the topology
    /// runs one (`None` means decode inline on the reader thread).
    pub fn decode_plane(&self) -> Option<Arc<CodecPlane>> {
        self.hub.decode_plane()
    }

    /// `true` while both the hub and this client's lane are up.
    pub fn open(&self) -> bool {
        !self.hub.is_closed() && !self.state.gone.load(Ordering::Relaxed)
    }

    /// Count events rejected at ingest (outside the declared geometry).
    pub fn count_dropped(&self, n: u64) {
        self.state.dropped.fetch_add(n, Ordering::Relaxed);
    }

    /// Push one decoded batch toward the merge, waiting for credit if
    /// the window is full (one `backpressure_wait` per stall episode).
    /// Returns `false` when the plane shut down or the merge side hung
    /// up — the reader should stop.
    pub fn push(&self, batch: Vec<Event>) -> bool {
        if batch.is_empty() {
            return self.open();
        }
        let len = batch.len();
        let mut stalled = false;
        loop {
            if !self.open() {
                return false;
            }
            let window = self.state.window.load(Ordering::Relaxed);
            let in_flight = self.state.in_flight.load(Ordering::Relaxed);
            // An empty lane always admits one batch, even oversized:
            // a window smaller than a wire batch must not wedge the
            // client, and the bound stays max(window, batch).
            if in_flight == 0 || in_flight + len <= window {
                self.state.in_flight.fetch_add(len, Ordering::Relaxed);
                let sent = self
                    .tx
                    .as_ref()
                    .expect("ingest channel lives until drop")
                    .send(batch)
                    .is_ok();
                if !sent {
                    self.state.in_flight.fetch_sub(len, Ordering::Relaxed);
                }
                return sent;
            }
            if !stalled {
                stalled = true;
                self.state.node.add_backpressure_wait();
            }
            std::thread::sleep(CREDIT_WAIT);
        }
    }
}

impl Drop for ClientIngest {
    fn drop(&mut self) {
        // Severing the sender lets the merge drain the lane and see a
        // clean end of stream (`Ok(None)`) — a disconnect, abrupt or
        // polite, is never an error.
        self.tx = None;
        self.state.gone.store(true, Ordering::Relaxed);
        self.hub.disconnected.fetch_add(1, Ordering::Relaxed);
    }
}

/// Merge-side face of one client: a live, non-blocking
/// [`EventSource`] over the ingest channel.
struct ClientSource {
    rx: Receiver<Vec<Event>>,
    state: Arc<ClientState>,
    geometry: Resolution,
    name: String,
}

impl EventSource for ClientSource {
    fn next_batch(&mut self) -> Result<Option<Vec<Event>>> {
        match self.rx.try_recv() {
            Ok(batch) => {
                // Credit returns the moment the merge owns the batch.
                self.state.in_flight.fetch_sub(batch.len(), Ordering::Relaxed);
                Ok(Some(batch))
            }
            Err(TryRecvError::Empty) => Ok(Some(Vec::new())),
            Err(TryRecvError::Disconnected) => Ok(None),
        }
    }

    fn resolution(&self) -> Resolution {
        self.geometry
    }

    fn is_live(&self) -> bool {
        true
    }

    fn dropped(&self) -> u64 {
        self.state.dropped.load(Ordering::Relaxed)
    }

    fn describe(&self) -> String {
        self.name.clone()
    }
}

impl Drop for ClientSource {
    fn drop(&mut self) {
        // The merge let go of the lane: stop the reader's pushes.
        self.state.gone.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_caps_and_counts() {
        let hub = ClientHub::new(Resolution::new(8, 8), 64, 2);
        let a = hub.admit("client").expect("first client fits");
        let _b = hub.admit("client").expect("second client fits");
        assert!(hub.admit("client").is_none(), "capacity 2");
        assert_eq!((hub.admitted(), hub.refused()), (2, 1));
        assert_eq!(hub.active_clients(), 2);
        drop(a);
        assert_eq!(hub.disconnected(), 1);
        assert_eq!(hub.active_clients(), 1);
        // A departed slot frees capacity for the next admission.
        assert!(hub.admit("client").is_some());
        hub.shutdown();
        assert!(hub.admit("client").is_none(), "closed hub refuses");
    }

    #[test]
    fn lanes_flow_events_exactly_once_and_return_credit() {
        let hub = ClientHub::new(Resolution::new(16, 16), 8, 4);
        let ingest = hub.admit("client").unwrap();
        assert_eq!(ingest.name(), "client:0");
        let mut lanes = hub.take_lanes();
        assert_eq!(lanes.len(), 1);
        assert!(hub.take_lanes().is_empty(), "pending drains once");
        let lane = &mut lanes[0];
        assert!(ingest.push(vec![Event::on(1, 1, 10), Event::on(2, 2, 20)]));
        let got = lane.source.next_batch().unwrap().unwrap();
        assert_eq!(got.len(), 2);
        assert!(lane.source.next_batch().unwrap().unwrap().is_empty(), "live idle");
        // Fill the window exactly: 8 in flight blocks the next push
        // until the merge drains — emulated by the closed-hub bail.
        assert!(ingest.push((0..8).map(|i| Event::on(0, 0, 30 + i)).collect()));
        hub.shutdown();
        assert!(!ingest.push(vec![Event::on(3, 3, 99)]), "no credit + closed hub");
        drop(ingest);
        // Remaining batches drain, then the lane ends cleanly.
        assert_eq!(lane.source.next_batch().unwrap().unwrap().len(), 8);
        assert!(lane.source.next_batch().unwrap().is_none(), "clean end after drop");
    }

    #[test]
    fn windows_retarget_and_sample_through_the_plane() {
        let hub = ClientHub::new(Resolution::new(8, 8), 128, 4);
        let ingest = hub.admit("client").unwrap();
        assert!(hub.set_window("client:0", 32));
        assert!(!hub.set_window("client:9", 32), "unknown client");
        let samples = hub.client_samples();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].window, 32);
        assert_eq!(samples[0].name, "client:0");
        assert!(hub.set_window("client:0", 0), "floor clamps to 1");
        assert_eq!(hub.client_samples()[0].window, 1);
        drop(ingest);
        assert_eq!(hub.client_samples().len(), 1, "history outlives the client");
    }
}
