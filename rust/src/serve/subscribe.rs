//! Subscription egress: fan processed batches out to TCP consumers.
//!
//! [`SubscribeSink`] is the serving plane's egress mirror of
//! [`super::ListenerSource`]: a sink that accepts TCP subscribers at
//! runtime and forwards every consumed batch — encoded once as
//! contiguous little-endian SPIF words — to each of them. Every
//! subscriber sits behind its own bounded queue and writer thread, so
//! a slow or stuck consumer can never backpressure the trunk: its
//! deliveries are dropped (counted on its [`LiveNode`]) and after
//! enough consecutive stalls the subscriber is evicted outright.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::aer::Event;
use crate::metrics::LiveNode;
use crate::net::spif;
use crate::stream::{EventSink, SinkSummary};

use super::thread_label;

/// Encoded batches a subscriber may queue before deliveries drop.
const SUB_QUEUE_BATCHES: usize = 8;
/// Consecutive full-queue stalls before a subscriber is evicted.
const EVICT_STALLS: u32 = 64;
/// Poll cadence of the non-blocking accept loop.
const ACCEPT_IDLE: Duration = Duration::from_millis(2);
/// Writer-side socket timeout, so writers notice dead peers.
const WRITE_TIMEOUT: Duration = Duration::from_millis(500);

struct Subscriber {
    tx: SyncSender<Arc<Vec<u8>>>,
    node: Arc<LiveNode>,
    /// Consecutive full-queue stalls (reset by any delivery).
    stalls: u32,
    /// Set by the writer thread when the socket dies.
    dead: Arc<AtomicBool>,
    writer: Option<JoinHandle<()>>,
}

struct SubShared {
    closed: AtomicBool,
    subscribers: Mutex<Vec<Subscriber>>,
}

/// Fan-out sink serving dynamically attached TCP subscribers.
pub struct SubscribeSink {
    local_addr: SocketAddr,
    shared: Arc<SubShared>,
    accept: Option<JoinHandle<()>>,
    /// Writer handles of departed subscribers, joined at finish.
    retired: Vec<JoinHandle<()>>,
    evicted: u64,
    /// Counters carried over from departed subscribers.
    waits: u64,
    dropped: u64,
}

impl SubscribeSink {
    /// Bind the subscription port and start accepting consumers.
    pub fn bind<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("subscribe: bind listener")?;
        listener
            .set_nonblocking(true)
            .context("subscribe: set listener non-blocking")?;
        let local_addr = listener.local_addr().context("subscribe: local addr")?;
        let shared = Arc::new(SubShared {
            closed: AtomicBool::new(false),
            subscribers: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::Builder::new()
            .name("sub:accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .context("subscribe: spawn accept thread")?;
        Ok(SubscribeSink {
            local_addr,
            shared,
            accept: Some(accept),
            retired: Vec::new(),
            evicted: 0,
            waits: 0,
            dropped: 0,
        })
    }

    /// The bound address (with the OS-chosen port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Consumers currently attached.
    pub fn subscriber_count(&self) -> usize {
        self.shared.subscribers.lock().unwrap().len()
    }

    /// Subscribers evicted for persistent stalling.
    pub fn evictions(&self) -> u64 {
        self.evicted
    }

    /// Fold a departing subscriber's counters into the sink totals and
    /// keep its writer handle for the final join.
    fn retire(&mut self, sub: Subscriber) {
        let report = sub.node.sample();
        self.waits += report.backpressure_waits;
        self.dropped += report.dropped;
        // Severing `tx` ends the writer's loop.
        drop(sub.tx);
        if let Some(handle) = sub.writer {
            self.retired.push(handle);
        }
    }

    fn close(&mut self) -> (u64, u64) {
        self.shared.closed.store(true, Ordering::Relaxed);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        let departing = std::mem::take(&mut *self.shared.subscribers.lock().unwrap());
        for sub in departing {
            self.retire(sub);
        }
        for handle in self.retired.drain(..) {
            let _ = handle.join();
        }
        (self.waits, self.dropped)
    }
}

impl EventSink for SubscribeSink {
    /// Deliver one batch to every live subscriber. Never blocks on a
    /// slow consumer: full queues drop the delivery, and persistent
    /// stalling evicts.
    fn consume(&mut self, events: &[Event]) -> Result<()> {
        if events.is_empty() {
            return Ok(());
        }
        // Encode once, share the bytes across all subscriber queues.
        let mut payload = Vec::with_capacity(events.len() * 4);
        for ev in events {
            payload.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
        }
        // `Arc<Vec<u8>>`, not `Arc<[u8]>`: `Vec → Arc<[u8]>` re-copies
        // every byte into a fresh allocation (the refcount header must
        // precede the data); wrapping the Vec is a pointer move.
        let payload = Arc::new(payload);
        let mut departing: Vec<Subscriber> = Vec::new();
        {
            let mut subs = self.shared.subscribers.lock().unwrap();
            let mut i = 0;
            while i < subs.len() {
                let sub = &mut subs[i];
                if sub.dead.load(Ordering::Relaxed) {
                    departing.push(subs.swap_remove(i));
                    continue;
                }
                match sub.tx.try_send(payload.clone()) {
                    Ok(()) => {
                        sub.node.add_events(events.len() as u64);
                        sub.node.add_batch();
                        sub.stalls = 0;
                        i += 1;
                    }
                    Err(TrySendError::Full(_)) => {
                        sub.node.add_backpressure_wait();
                        sub.node.add_dropped(events.len() as u64);
                        sub.stalls += 1;
                        if sub.stalls >= EVICT_STALLS {
                            self.evicted += 1;
                            departing.push(subs.swap_remove(i));
                        } else {
                            i += 1;
                        }
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        departing.push(subs.swap_remove(i));
                    }
                }
            }
        }
        for sub in departing {
            self.retire(sub);
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        let (waits, dropped) = self.close();
        Ok(SinkSummary { frames: 0, backpressure_waits: waits, dropped })
    }

    fn describe(&self) -> String {
        format!("subscribe({})", self.local_addr)
    }
}

impl Drop for SubscribeSink {
    fn drop(&mut self) {
        // Best-effort teardown when `finish` never ran.
        self.close();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<SubShared>) {
    let mut next_id = 0u64;
    while !shared.closed.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let name = format!("sub:{next_id}");
                next_id += 1;
                let (tx, rx) = std::sync::mpsc::sync_channel::<Arc<Vec<u8>>>(SUB_QUEUE_BATCHES);
                let dead = Arc::new(AtomicBool::new(false));
                let writer_dead = dead.clone();
                let writer = std::thread::Builder::new()
                    .name(thread_label(&name))
                    .spawn(move || write_loop(stream, rx, writer_dead))
                    .ok();
                if writer.is_none() {
                    continue;
                }
                shared.subscribers.lock().unwrap().push(Subscriber {
                    node: Arc::new(LiveNode::new(name)),
                    tx,
                    stalls: 0,
                    dead,
                    writer,
                });
            }
            Err(err) if err.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_IDLE);
            }
            Err(err) if err.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(ACCEPT_IDLE),
        }
    }
}

fn write_loop(
    mut stream: TcpStream,
    rx: std::sync::mpsc::Receiver<Arc<Vec<u8>>>,
    dead: Arc<AtomicBool>,
) {
    for payload in rx {
        if stream.write_all(&payload).is_err() || stream.flush().is_err() {
            dead.store(true, Ordering::Relaxed);
            return;
        }
    }
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::time::Instant;

    fn wait_for<F: FnMut() -> bool>(mut ready: F) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !ready() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn subscribers_receive_every_word() {
        let mut sink = SubscribeSink::bind("127.0.0.1:0").unwrap();
        let mut consumer = TcpStream::connect(sink.local_addr()).unwrap();
        consumer
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        wait_for(|| sink.subscriber_count() == 1);
        let events = [Event::on(1, 2, 10), Event::off(3, 4, 20)];
        sink.consume(&events).unwrap();
        let mut wire = [0u8; 8];
        consumer.read_exact(&mut wire).unwrap();
        for (ev, chunk) in events.iter().zip(wire.chunks_exact(4)) {
            let word = u32::from_le_bytes(chunk.try_into().unwrap());
            let back = spif::unpack_word(word, ev.t);
            assert_eq!((back.x, back.y, back.p), (ev.x, ev.y, ev.p));
        }
        let summary = sink.finish().unwrap();
        assert_eq!(summary.dropped, 0);
    }

    #[test]
    fn dead_consumers_are_pruned_not_blocking() {
        let mut sink = SubscribeSink::bind("127.0.0.1:0").unwrap();
        let consumer = TcpStream::connect(sink.local_addr()).unwrap();
        wait_for(|| sink.subscriber_count() == 1);
        drop(consumer);
        // Deliveries keep flowing; the dead peer is detected by its
        // writer and pruned on a later consume.
        let batch = [Event::on(0, 0, 1)];
        wait_for(|| {
            sink.consume(&batch).unwrap();
            sink.subscriber_count() == 0
        });
        assert_eq!(sink.subscriber_count(), 0, "dead subscriber pruned");
        sink.finish().unwrap();
    }

    #[test]
    fn no_subscribers_is_not_an_error() {
        let mut sink = SubscribeSink::bind("127.0.0.1:0").unwrap();
        sink.consume(&[Event::on(1, 1, 1)]).unwrap();
        let summary = sink.finish().unwrap();
        assert_eq!((summary.frames, summary.dropped), (0, 0));
    }
}
