//! Backpressure policies for bounded event queues.
//!
//! §6 of the paper: "We have had success deploying AEStream on embedded
//! systems, but there is presently no guarantee that bottlenecks do not
//! occur." This module makes the bottleneck behaviour *explicit and
//! configurable*: a bounded accumulation queue with a policy for what
//! happens when the consumer falls behind, plus high-watermark metrics
//! so deployments can observe pressure instead of silently losing data.

use crate::aer::Event;

/// What to do when the queue is full and another event arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the incoming event (favor old data; bounded latency for
    /// what's already queued).
    DropNewest,
    /// Drop the oldest queued event (favor fresh data; the right choice
    /// for closed-loop control where stale events are worthless).
    DropOldest,
    /// Reject the push; the producer must retry (lossless, couples the
    /// producer's rate to the consumer's).
    Reject,
}

/// A bounded event queue with an overflow policy and pressure metrics.
#[derive(Debug)]
pub struct BoundedQueue {
    buf: std::collections::VecDeque<Event>,
    capacity: usize,
    policy: OverflowPolicy,
    /// Events dropped by policy so far.
    pub dropped: u64,
    /// Pushes rejected (Reject policy) so far.
    pub rejected: u64,
    /// Highest queue occupancy observed.
    pub high_watermark: usize,
    /// Total events accepted.
    pub accepted: u64,
}

impl BoundedQueue {
    /// New queue with `capacity` (≥1) and `policy`.
    pub fn new(capacity: usize, policy: OverflowPolicy) -> Self {
        BoundedQueue {
            buf: std::collections::VecDeque::with_capacity(capacity.max(1)),
            capacity: capacity.max(1),
            policy,
            dropped: 0,
            rejected: 0,
            high_watermark: 0,
            accepted: 0,
        }
    }

    /// Capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// `true` when empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Occupancy as a fraction of capacity (pressure gauge).
    pub fn pressure(&self) -> f64 {
        self.buf.len() as f64 / self.capacity as f64
    }

    /// Push one event, applying the overflow policy. Returns `false`
    /// iff the event was not enqueued (dropped or rejected).
    pub fn push(&mut self, ev: Event) -> bool {
        if self.buf.len() == self.capacity {
            match self.policy {
                OverflowPolicy::DropNewest => {
                    self.dropped += 1;
                    return false;
                }
                OverflowPolicy::DropOldest => {
                    self.buf.pop_front();
                    self.dropped += 1;
                }
                OverflowPolicy::Reject => {
                    self.rejected += 1;
                    return false;
                }
            }
        }
        self.buf.push_back(ev);
        self.accepted += 1;
        self.high_watermark = self.high_watermark.max(self.buf.len());
        true
    }

    /// Drain up to `max` events (consumer side).
    pub fn drain(&mut self, max: usize) -> Vec<Event> {
        let n = max.min(self.buf.len());
        self.buf.drain(..n).collect()
    }

    /// Drain everything.
    pub fn drain_all(&mut self) -> Vec<Event> {
        self.buf.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64) -> Event {
        Event::on(1, 1, t)
    }

    #[test]
    fn drop_newest_keeps_oldest() {
        let mut q = BoundedQueue::new(2, OverflowPolicy::DropNewest);
        assert!(q.push(ev(1)));
        assert!(q.push(ev(2)));
        assert!(!q.push(ev(3)));
        assert_eq!(q.dropped, 1);
        assert_eq!(q.drain_all().iter().map(|e| e.t).collect::<Vec<_>>(), [1, 2]);
    }

    #[test]
    fn drop_oldest_keeps_newest() {
        let mut q = BoundedQueue::new(2, OverflowPolicy::DropOldest);
        q.push(ev(1));
        q.push(ev(2));
        assert!(q.push(ev(3)), "incoming event is enqueued");
        assert_eq!(q.dropped, 1);
        assert_eq!(q.drain_all().iter().map(|e| e.t).collect::<Vec<_>>(), [2, 3]);
    }

    #[test]
    fn reject_preserves_content_and_counts() {
        let mut q = BoundedQueue::new(1, OverflowPolicy::Reject);
        assert!(q.push(ev(1)));
        assert!(!q.push(ev(2)));
        assert_eq!((q.rejected, q.dropped), (1, 0));
        assert_eq!(q.drain_all().len(), 1);
    }

    #[test]
    fn watermark_and_pressure_track_occupancy() {
        let mut q = BoundedQueue::new(4, OverflowPolicy::DropNewest);
        for t in 0..3 {
            q.push(ev(t));
        }
        assert_eq!(q.high_watermark, 3);
        assert!((q.pressure() - 0.75).abs() < 1e-9);
        q.drain(2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.high_watermark, 3, "watermark is sticky");
    }

    #[test]
    fn drain_respects_max_and_order() {
        let mut q = BoundedQueue::new(8, OverflowPolicy::Reject);
        for t in 0..6 {
            q.push(ev(t));
        }
        let first = q.drain(4);
        assert_eq!(first.iter().map(|e| e.t).collect::<Vec<_>>(), [0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }
}
