//! Standard per-event transforms.
//!
//! Each transform is a small, independently testable function object
//! with the uniform [`EventTransform`] signature — the paper's
//! freely-composable pipeline stages.

use crate::aer::{Event, Polarity, Resolution};
use crate::pipeline::{EventTransform, TransformClass};

// ---------------------------------------------------------------------
// Per-pixel state hand-off (adaptive re-cuts)
// ---------------------------------------------------------------------

/// Export columns `x0..x1` of a row-major per-pixel state plane as the
/// column-major rows [`EventTransform::export_rows`] specifies. Columns
/// past the plane's width are clamped off (events outside the
/// configured geometry are untracked, so there is nothing to move).
fn export_state_cols(state: &[u64], res: Resolution, x0: u16, x1: u16) -> Vec<u64> {
    let (w, h) = (res.width as usize, res.height as usize);
    let x1 = (x1 as usize).min(w);
    let x0 = (x0 as usize).min(x1);
    let mut out = Vec::with_capacity((x1 - x0) * h);
    for x in x0..x1 {
        for y in 0..h {
            out.push(state[y * w + x]);
        }
    }
    out
}

/// Inverse of [`export_state_cols`]: write rows back into the plane.
/// Ignores a row count that does not match the clamped span (a foreign
/// or stale export must never scribble over unrelated pixels).
fn import_state_cols(state: &mut [u64], res: Resolution, x0: u16, x1: u16, rows: &[u64]) {
    let (w, h) = (res.width as usize, res.height as usize);
    let x1 = (x1 as usize).min(w);
    let x0 = (x0 as usize).min(x1);
    if rows.len() != (x1 - x0) * h {
        return;
    }
    let mut it = rows.iter();
    for x in x0..x1 {
        for y in 0..h {
            state[y * w + x] = *it.next().expect("length checked");
        }
    }
}

// ---------------------------------------------------------------------
// Polarity filter
// ---------------------------------------------------------------------

/// Keep only events of one polarity.
#[derive(Debug, Clone)]
pub struct PolarityFilter {
    keep: Polarity,
}

impl PolarityFilter {
    /// Keep only `keep`-polarity events.
    pub fn keep(keep: Polarity) -> Self {
        PolarityFilter { keep }
    }
}

impl EventTransform for PolarityFilter {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        (ev.p == self.keep).then_some(ev)
    }
    fn describe(&self) -> String {
        format!("polarity({})", if self.keep.is_on() { "on" } else { "off" })
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

// ---------------------------------------------------------------------
// Region-of-interest crop
// ---------------------------------------------------------------------

/// Keep events inside `[x0, x0+w) × [y0, y0+h)` and re-origin them to
/// the crop window.
#[derive(Debug, Clone)]
pub struct RoiCrop {
    pub x0: u16,
    pub y0: u16,
    pub width: u16,
    pub height: u16,
}

impl RoiCrop {
    /// New crop window.
    pub fn new(x0: u16, y0: u16, width: u16, height: u16) -> Self {
        RoiCrop { x0, y0, width, height }
    }
}

impl EventTransform for RoiCrop {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        if ev.x >= self.x0
            && ev.x < self.x0 + self.width
            && ev.y >= self.y0
            && ev.y < self.y0 + self.height
        {
            Some(Event { x: ev.x - self.x0, y: ev.y - self.y0, ..ev })
        } else {
            None
        }
    }
    fn describe(&self) -> String {
        format!("crop({},{},{}x{})", self.x0, self.y0, self.width, self.height)
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

// ---------------------------------------------------------------------
// Spatial downsample
// ---------------------------------------------------------------------

/// Integer spatial downsampling: coordinates divided by `factor`.
/// (Event-count preserving; use with a refractory filter to thin.)
#[derive(Debug, Clone)]
pub struct Downsample {
    factor: u16,
}

impl Downsample {
    /// Downsample by `factor` (≥1).
    pub fn new(factor: u16) -> Self {
        Downsample { factor: factor.max(1) }
    }
}

impl EventTransform for Downsample {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        Some(Event { x: ev.x / self.factor, y: ev.y / self.factor, ..ev })
    }
    fn describe(&self) -> String {
        format!("downsample(/{})", self.factor)
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

// ---------------------------------------------------------------------
// Refractory filter
// ---------------------------------------------------------------------

/// Drop events from a pixel within `period_us` of its previous event —
/// the same refractory mechanism the paper adds to its LIF layer to
/// reduce noise, applied at the stream level.
#[derive(Debug)]
pub struct RefractoryFilter {
    period_us: u64,
    resolution: Resolution,
    /// Last accepted timestamp + 1 per pixel (0 = never fired).
    last: Vec<u64>,
}

impl RefractoryFilter {
    /// New filter for a sensor of `resolution`.
    pub fn new(resolution: Resolution, period_us: u64) -> Self {
        RefractoryFilter { period_us, resolution, last: vec![0; resolution.pixels()] }
    }
}

impl EventTransform for RefractoryFilter {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        if !self.resolution.contains(&ev) {
            // Outside the configured geometry (e.g. a fused canvas wider
            // than the assumed sensor): pass through untracked rather
            // than index out of bounds.
            return Some(ev);
        }
        let idx = ev.pixel_index(self.resolution.width);
        let last = self.last[idx];
        // Stored as t+1 so 0 means "never".
        if last != 0 && ev.t < last - 1 + self.period_us {
            return None;
        }
        self.last[idx] = ev.t + 1;
        Some(ev)
    }
    fn describe(&self) -> String {
        format!("refractory({}µs)", self.period_us)
    }
    fn reset(&mut self) {
        self.last.fill(0);
    }
    fn class(&self) -> TransformClass {
        // Per-pixel clocks, no neighbourhood reads: stripes own their
        // pixels outright, no ghosts needed.
        TransformClass::Stateful { halo: 0 }
    }
    fn export_rows(&self, x0: u16, x1: u16) -> Vec<u64> {
        export_state_cols(&self.last, self.resolution, x0, x1)
    }
    fn import_rows(&mut self, x0: u16, x1: u16, rows: &[u64]) {
        import_state_cols(&mut self.last, self.resolution, x0, x1, rows);
    }
}

// ---------------------------------------------------------------------
// Background-activity denoise
// ---------------------------------------------------------------------

/// Classic neighbourhood-support denoiser: keep an event only if one of
/// its 8 spatial neighbours fired within `window_us`. Removes the
/// uncorrelated background activity a real DVS produces in the dark.
#[derive(Debug)]
pub struct BackgroundActivityFilter {
    window_us: u64,
    resolution: Resolution,
    /// Last event time + 1 per pixel.
    last: Vec<u64>,
}

impl BackgroundActivityFilter {
    /// New filter for a sensor of `resolution`.
    pub fn new(resolution: Resolution, window_us: u64) -> Self {
        BackgroundActivityFilter {
            window_us,
            resolution,
            last: vec![0; resolution.pixels()],
        }
    }
}

impl EventTransform for BackgroundActivityFilter {
    fn apply(&mut self, ev: Event) -> Option<Event> {
        if !self.resolution.contains(&ev) {
            // Outside the configured geometry: pass through untracked
            // rather than index out of bounds.
            return Some(ev);
        }
        let (w, h) = (self.resolution.width, self.resolution.height);
        let mut supported = false;
        let x0 = ev.x.saturating_sub(1);
        let x1 = (ev.x + 1).min(w - 1);
        let y0 = ev.y.saturating_sub(1);
        let y1 = (ev.y + 1).min(h - 1);
        for ny in y0..=y1 {
            for nx in x0..=x1 {
                if nx == ev.x && ny == ev.y {
                    continue;
                }
                let t = self.last[ny as usize * w as usize + nx as usize];
                if t != 0 && ev.t < (t - 1).saturating_add(self.window_us) {
                    supported = true;
                }
            }
        }
        self.last[ev.pixel_index(w)] = ev.t + 1;
        supported.then_some(ev)
    }
    fn describe(&self) -> String {
        format!("denoise({}µs)", self.window_us)
    }
    fn reset(&mut self) {
        self.last.fill(0);
    }
    fn class(&self) -> TransformClass {
        // Reads the 8-neighbourhood: shard routers must feed each
        // stripe ghost copies of events within 1 px of its boundary.
        TransformClass::Stateful { halo: 1 }
    }
    fn export_rows(&self, x0: u16, x1: u16) -> Vec<u64> {
        export_state_cols(&self.last, self.resolution, x0, x1)
    }
    fn import_rows(&mut self, x0: u16, x1: u16, rows: &[u64]) {
        import_state_cols(&mut self.last, self.resolution, x0, x1, rows);
    }
}

// ---------------------------------------------------------------------
// Geometric transforms
// ---------------------------------------------------------------------

/// Mirror x within a sensor of the given width.
#[derive(Debug, Clone)]
pub struct FlipX {
    width: u16,
}

impl FlipX {
    /// New horizontal mirror.
    pub fn new(width: u16) -> Self {
        FlipX { width }
    }
}

impl EventTransform for FlipX {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        if ev.x >= self.width {
            return Some(ev); // outside the mirror axis: pass through
        }
        Some(Event { x: self.width - 1 - ev.x, ..ev })
    }
    fn describe(&self) -> String {
        "flip_x".into()
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

/// Mirror y within a sensor of the given height.
#[derive(Debug, Clone)]
pub struct FlipY {
    height: u16,
}

impl FlipY {
    /// New vertical mirror.
    pub fn new(height: u16) -> Self {
        FlipY { height }
    }
}

impl EventTransform for FlipY {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        if ev.y >= self.height {
            return Some(ev); // outside the mirror axis: pass through
        }
        Some(Event { y: self.height - 1 - ev.y, ..ev })
    }
    fn describe(&self) -> String {
        "flip_y".into()
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

/// Swap x and y (rotate+mirror; geometry must be square or tracked by
/// the caller).
#[derive(Debug, Clone)]
pub struct Transpose;

impl EventTransform for Transpose {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        Some(Event { x: ev.y, y: ev.x, ..ev })
    }
    fn describe(&self) -> String {
        "transpose".into()
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

// ---------------------------------------------------------------------
// Time shift
// ---------------------------------------------------------------------

/// Add a constant offset to every timestamp (stream alignment for
/// multi-sensor fusion).
#[derive(Debug, Clone)]
pub struct TimeShift {
    offset_us: u64,
}

impl TimeShift {
    /// Shift by `offset_us` into the future.
    pub fn new(offset_us: u64) -> Self {
        TimeShift { offset_us }
    }
}

impl EventTransform for TimeShift {
    #[inline]
    fn apply(&mut self, ev: Event) -> Option<Event> {
        Some(Event { t: ev.t + self.offset_us, ..ev })
    }
    fn describe(&self) -> String {
        format!("time_shift(+{}µs)", self.offset_us)
    }
    fn class(&self) -> TransformClass {
        TransformClass::Stateless
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    const RES: Resolution = Resolution::new(64, 48);

    #[test]
    fn polarity_filter_splits_stream() {
        let events = synthetic_events(1000, 64, 48);
        let mut on = PolarityFilter::keep(Polarity::On);
        let mut off = PolarityFilter::keep(Polarity::Off);
        let n_on = events.iter().filter(|e| on.apply(**e).is_some()).count();
        let n_off = events.iter().filter(|e| off.apply(**e).is_some()).count();
        assert_eq!(n_on + n_off, events.len());
    }

    #[test]
    fn crop_reorigins_and_bounds() {
        let mut crop = RoiCrop::new(10, 10, 20, 20);
        assert_eq!(crop.apply(Event::on(10, 10, 0)), Some(Event::on(0, 0, 0)));
        assert_eq!(crop.apply(Event::on(29, 29, 0)), Some(Event::on(19, 19, 0)));
        assert_eq!(crop.apply(Event::on(30, 10, 0)), None);
        assert_eq!(crop.apply(Event::on(9, 15, 0)), None);
    }

    #[test]
    fn downsample_divides() {
        let mut d = Downsample::new(4);
        assert_eq!(d.apply(Event::on(63, 47, 5)), Some(Event::on(15, 11, 5)));
        let mut d1 = Downsample::new(1);
        assert_eq!(d1.apply(Event::on(7, 7, 1)), Some(Event::on(7, 7, 1)));
    }

    #[test]
    fn refractory_drops_rapid_repeats() {
        let mut r = RefractoryFilter::new(RES, 100);
        assert!(r.apply(Event::on(5, 5, 1000)).is_some());
        assert!(r.apply(Event::on(5, 5, 1050)).is_none()); // too soon
        assert!(r.apply(Event::on(6, 5, 1050)).is_some()); // other pixel ok
        assert!(r.apply(Event::on(5, 5, 1100)).is_some()); // period elapsed
        r.reset();
        assert!(r.apply(Event::on(5, 5, 1050)).is_some());
    }

    #[test]
    fn refractory_accepts_t_zero() {
        let mut r = RefractoryFilter::new(RES, 100);
        assert!(r.apply(Event::on(0, 0, 0)).is_some());
        assert!(r.apply(Event::on(0, 0, 50)).is_none());
    }

    #[test]
    fn denoise_requires_neighbour_support() {
        let mut f = BackgroundActivityFilter::new(RES, 1000);
        // Lone event: no support, dropped.
        assert!(f.apply(Event::on(10, 10, 100)).is_none());
        // Neighbour within the window: kept.
        assert!(f.apply(Event::on(11, 10, 200)).is_some());
        // Far-away pixel: dropped again.
        assert!(f.apply(Event::on(40, 40, 300)).is_none());
        // Same pixel does not self-support.
        assert!(f.apply(Event::on(40, 40, 301)).is_none());
    }

    #[test]
    fn flips_are_involutions() {
        let events = synthetic_events(200, 64, 48);
        let mut fx = FlipX::new(64);
        let mut fy = FlipY::new(48);
        for ev in events {
            let once = fx.apply(ev).unwrap();
            assert_eq!(fx.apply(once).unwrap(), ev);
            let once = fy.apply(ev).unwrap();
            assert_eq!(fy.apply(once).unwrap(), ev);
        }
    }

    #[test]
    fn transpose_swaps() {
        let mut t = Transpose;
        assert_eq!(t.apply(Event::on(3, 9, 7)), Some(Event::on(9, 3, 7)));
    }

    /// Moving a column's state between instances via export/import must
    /// reproduce the donor's behaviour exactly — the invariant adaptive
    /// re-cuts rely on.
    #[test]
    fn exported_rows_transplant_refractory_state() {
        let mut donor = RefractoryFilter::new(RES, 100);
        assert!(donor.apply(Event::on(5, 5, 1000)).is_some());
        assert!(donor.apply(Event::on(6, 7, 1010)).is_some());
        let mut fresh = RefractoryFilter::new(RES, 100);
        // Without the hand-off, the fresh instance re-admits the repeat.
        assert!(fresh.apply(Event::on(5, 5, 1050)).is_some());
        let mut heir = RefractoryFilter::new(RES, 100);
        heir.import_rows(4, 8, &donor.export_rows(4, 8));
        assert!(heir.apply(Event::on(5, 5, 1050)).is_none(), "state must move");
        assert!(heir.apply(Event::on(6, 7, 1050)).is_none(), "all columns in span");
        assert!(heir.apply(Event::on(5, 5, 1100)).is_some(), "period still elapses");
    }

    #[test]
    fn exported_rows_transplant_denoise_state() {
        let mut donor = BackgroundActivityFilter::new(RES, 1000);
        assert!(donor.apply(Event::on(10, 10, 100)).is_none()); // seeds support
        let mut heir = BackgroundActivityFilter::new(RES, 1000);
        heir.import_rows(10, 11, &donor.export_rows(10, 11));
        assert!(heir.apply(Event::on(11, 10, 200)).is_some(), "support must move");
    }

    #[test]
    fn row_handoff_clamps_and_rejects_mismatches() {
        let mut f = RefractoryFilter::new(RES, 100);
        assert!(f.apply(Event::on(63, 0, 50)).is_some());
        // Span clamped to the canvas: only the last column exports.
        let rows = f.export_rows(63, 200);
        assert_eq!(rows.len(), RES.height as usize);
        // A stateless op exports nothing and ignores imports.
        let mut p = PolarityFilter::keep(Polarity::On);
        assert!(p.export_rows(0, 10).is_empty());
        p.import_rows(0, 10, &rows);
        // A mismatched row count must not scribble over state.
        let mut heir = RefractoryFilter::new(RES, 100);
        heir.import_rows(0, 2, &rows);
        assert!(heir.apply(Event::on(63, 0, 60)).is_some(), "bad import ignored");
    }

    #[test]
    fn classes_match_statefulness() {
        use crate::pipeline::TransformClass as C;
        assert_eq!(PolarityFilter::keep(Polarity::On).class(), C::Stateless);
        assert_eq!(RoiCrop::new(0, 0, 8, 8).class(), C::Stateless);
        assert_eq!(Downsample::new(2).class(), C::Stateless);
        assert_eq!(FlipX::new(8).class(), C::Stateless);
        assert_eq!(FlipY::new(8).class(), C::Stateless);
        assert_eq!(Transpose.class(), C::Stateless);
        assert_eq!(TimeShift::new(10).class(), C::Stateless);
        assert_eq!(RefractoryFilter::new(RES, 100).class(), C::Stateful { halo: 0 });
        assert_eq!(
            BackgroundActivityFilter::new(RES, 100).class(),
            C::Stateful { halo: 1 }
        );
    }
}
