//! Terminal frame viewer: render event frames as ASCII/Unicode art.
//!
//! The paper's ecosystem pairs AEStream with "graphical libraries for
//! visual inspection" (§6); in a terminal-only environment the
//! equivalent is a density renderer — handy for eyeballing whether a
//! recording, filter chain, or the edge detector's output looks sane
//! (`aestream input … output view`).

use crate::aer::Resolution;
use crate::pipeline::framer::Frame;

/// Density glyphs from silent to saturated.
const RAMP: &[char] = &[' ', '·', ':', '+', '*', '#', '@'];

/// Render a frame's |activity| as `rows` lines of `cols` glyphs.
/// The frame is box-downsampled to the requested character grid.
pub fn render_frame(frame: &Frame, cols: usize, rows: usize) -> String {
    render_map(&frame.data, frame.resolution, cols, rows)
}

/// Render any row-major map (frames, spike maps, edge maps).
pub fn render_map(data: &[f32], res: Resolution, cols: usize, rows: usize) -> String {
    let cols = cols.clamp(1, res.width as usize);
    let rows = rows.clamp(1, res.height as usize);
    let (w, h) = (res.width as usize, res.height as usize);
    // Box-filter each character cell.
    let mut cells = vec![0.0f32; cols * rows];
    for y in 0..h {
        let cy = y * rows / h;
        for x in 0..w {
            let cx = x * cols / w;
            cells[cy * cols + cx] += data[y * w + x].abs();
        }
    }
    let max = cells.iter().cloned().fold(0.0f32, f32::max);
    let mut out = String::with_capacity((cols + 1) * rows);
    for row in cells.chunks(cols) {
        for &c in row {
            let idx = if max == 0.0 {
                0
            } else {
                ((c / max) * (RAMP.len() - 1) as f32).round() as usize
            };
            out.push(RAMP[idx.min(RAMP.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::{Event, Resolution};

    #[test]
    fn silent_frame_renders_blank() {
        let frame = Frame::zeroed(Resolution::new(32, 16), 0, 1000);
        let art = render_frame(&frame, 16, 8);
        assert_eq!(art.lines().count(), 8);
        assert!(art.chars().all(|c| c == ' ' || c == '\n'));
    }

    #[test]
    fn hot_pixel_renders_saturated_glyph() {
        let mut frame = Frame::zeroed(Resolution::new(32, 16), 0, 1000);
        for _ in 0..10 {
            frame.accumulate(&Event::on(0, 0, 5));
        }
        let art = render_frame(&frame, 16, 8);
        assert!(art.starts_with('@'), "top-left cell must be saturated: {art:?}");
    }

    #[test]
    fn geometry_clamps() {
        let frame = Frame::zeroed(Resolution::new(4, 4), 0, 1);
        let art = render_frame(&frame, 1000, 1000);
        assert_eq!(art.lines().count(), 4);
        assert_eq!(art.lines().next().unwrap().len(), 4);
    }

    #[test]
    fn edge_map_renders_structure() {
        // A vertical line of activity should occupy one character column.
        let res = Resolution::new(64, 32);
        let mut data = vec![0.0f32; res.pixels()];
        for y in 0..32 {
            data[y * 64 + 32] = 1.0;
        }
        let art = render_map(&data, res, 32, 16);
        let lit_cols: std::collections::HashSet<usize> = art
            .lines()
            .flat_map(|l| {
                l.char_indices().filter(|(_, c)| *c != ' ').map(|(i, _)| i).collect::<Vec<_>>()
            })
            .collect();
        assert_eq!(lit_cols.len(), 1, "one column lit: {art}");
    }
}
