//! Multi-sensor stream fusion — the paper's §6 future-work item
//! ("AEStream is also well suited for multimodal sensing and sensor
//! fusion. Sending multiple inputs to a single neuromorphic compute
//! platform would, for instance, be trivial.").
//!
//! [`merge_streams`] performs a timestamp-ordered k-way merge of event
//! streams; [`SourceLayout`] maps each source into a region of a shared
//! output canvas (the way SPIF multiplexes several sensors into one
//! SpiNNaker address space) by offsetting coordinates and validating
//! bounds.
//!
//! These entry points are *batch-only*: they need every stream fully
//! materialized. The streaming lift — same merge order, same layouts,
//! but over live [`crate::stream::EventSource`]s with per-source carry
//! buffers and O(chunk × sources) memory — is
//! [`crate::stream::FusedSource`].

use crate::aer::{Event, Resolution};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Placement of one source within the fused canvas.
#[derive(Debug, Clone, Copy)]
pub struct SourcePlacement {
    /// Horizontal offset of this source's origin in the canvas.
    pub x_offset: u16,
    /// Vertical offset of this source's origin in the canvas.
    pub y_offset: u16,
    /// The source's own geometry (events outside are dropped).
    pub resolution: Resolution,
}

/// Layout of all fused sources on one canvas.
#[derive(Debug, Clone)]
pub struct SourceLayout {
    /// Fused canvas geometry.
    pub canvas: Resolution,
    /// Per-source placements (index = source id).
    pub placements: Vec<SourcePlacement>,
}

impl SourceLayout {
    /// Side-by-side layout: sources in a single row, left to right.
    ///
    /// The canvas width saturates at `u16::MAX`; sources pushed past the
    /// address space get placements whose events can never fit (callers
    /// that need a hard error should validate the width sum first, as
    /// [`crate::stream::run_topology`] does).
    pub fn side_by_side(resolutions: &[Resolution]) -> SourceLayout {
        let mut placements = Vec::with_capacity(resolutions.len());
        let mut x = 0u16;
        let mut height = 1u16;
        for &res in resolutions {
            placements.push(SourcePlacement { x_offset: x, y_offset: 0, resolution: res });
            x = x.saturating_add(res.width);
            height = height.max(res.height);
        }
        SourceLayout { canvas: Resolution::new(x.max(1), height), placements }
    }

    /// Grid layout: sources in a near-square row-major grid, every cell
    /// sized to the largest source. Like
    /// [`side_by_side`](Self::side_by_side), offsets saturate at the
    /// u16 address space; callers needing a hard error validate first
    /// ([`crate::stream::topology::grid_layout`] does).
    pub fn grid(resolutions: &[Resolution]) -> SourceLayout {
        let k = resolutions.len().max(1);
        let mut cols = 1usize;
        while cols * cols < k {
            cols += 1;
        }
        let rows = k.div_ceil(cols);
        let cell_w = resolutions.iter().map(|r| r.width).max().unwrap_or(1);
        let cell_h = resolutions.iter().map(|r| r.height).max().unwrap_or(1);
        let placements = resolutions
            .iter()
            .enumerate()
            .map(|(i, &res)| SourcePlacement {
                x_offset: cell_w.saturating_mul((i % cols) as u16),
                y_offset: cell_h.saturating_mul((i / cols) as u16),
                resolution: res,
            })
            .collect();
        SourceLayout {
            canvas: Resolution::new(
                cell_w.saturating_mul(cols as u16).max(1),
                cell_h.saturating_mul(rows as u16).max(1),
            ),
            placements,
        }
    }

    /// Explicit layout: each source at its declared canvas offset; the
    /// canvas is the bounding box of all placements. Saturating like
    /// the other constructors
    /// ([`crate::stream::topology::explicit_layout`] validates hard).
    pub fn at_offsets(resolutions: &[Resolution], offsets: &[(u16, u16)]) -> SourceLayout {
        assert_eq!(resolutions.len(), offsets.len(), "one offset per source");
        let mut canvas = Resolution::new(1, 1);
        let placements = resolutions
            .iter()
            .zip(offsets)
            .map(|(&res, &(x, y))| {
                canvas.width = canvas.width.max(x.saturating_add(res.width));
                canvas.height = canvas.height.max(y.saturating_add(res.height));
                SourcePlacement { x_offset: x, y_offset: y, resolution: res }
            })
            .collect();
        SourceLayout { canvas, placements }
    }

    /// Overlay layout: every source shares the canvas origin (no
    /// offsets) and the canvas is the union bounding box — several
    /// sensors interleaved on one address plane, the layout
    /// [`crate::coordinator::run_scenario_fused`] uses to feed multiple
    /// sources into one fixed-geometry compute device.
    pub fn overlay(resolutions: &[Resolution]) -> SourceLayout {
        let mut canvas = Resolution::new(1, 1);
        let mut placements = Vec::with_capacity(resolutions.len());
        for &res in resolutions {
            placements.push(SourcePlacement { x_offset: 0, y_offset: 0, resolution: res });
            canvas.width = canvas.width.max(res.width);
            canvas.height = canvas.height.max(res.height);
        }
        SourceLayout { canvas, placements }
    }

    /// Map one event of `source` onto the canvas. `None` if the source
    /// id is unknown, the event violates the source's geometry, or the
    /// placed coordinate would leave the u16 address space (possible
    /// only for layouts saturated past it).
    #[inline]
    pub fn place(&self, source: usize, ev: &Event) -> Option<Event> {
        let p = self.placements.get(source)?;
        if !p.resolution.contains(ev) {
            return None;
        }
        let x = ev.x.checked_add(p.x_offset)?;
        let y = ev.y.checked_add(p.y_offset)?;
        Some(Event { x, y, ..*ev })
    }
}

/// Heap entry for the k-way merge (min-heap by timestamp, then source
/// id for determinism).
#[derive(PartialEq, Eq)]
struct Head {
    t: u64,
    source: usize,
    index: usize,
}

impl Ord for Head {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t, self.source, self.index).cmp(&(other.t, other.source, other.index))
    }
}

impl PartialOrd for Head {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Timestamp-ordered k-way merge of per-source event streams (each
/// stream must itself be time-ordered). Ties break by source id, making
/// the merge fully deterministic.
pub fn merge_streams(streams: &[&[Event]]) -> Vec<Event> {
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut heap: BinaryHeap<Reverse<Head>> = BinaryHeap::with_capacity(streams.len());
    for (source, s) in streams.iter().enumerate() {
        if let Some(ev) = s.first() {
            heap.push(Reverse(Head { t: ev.t, source, index: 0 }));
        }
    }
    while let Some(Reverse(head)) = heap.pop() {
        let stream = streams[head.source];
        out.push(stream[head.index]);
        let next = head.index + 1;
        if next < stream.len() {
            heap.push(Reverse(Head { t: stream[next].t, source: head.source, index: next }));
        }
    }
    out
}

/// Merge + spatially place several sources onto one canvas in one pass.
/// Returns the fused, time-ordered stream (out-of-bounds events counted
/// in the second return value).
pub fn fuse(streams: &[&[Event]], layout: &SourceLayout) -> (Vec<Event>, u64) {
    // Tag-merge: k-way merge but remembering the source of each event.
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut dropped = 0u64;
    let mut heap: BinaryHeap<Reverse<Head>> = BinaryHeap::with_capacity(streams.len());
    for (source, s) in streams.iter().enumerate() {
        if let Some(ev) = s.first() {
            heap.push(Reverse(Head { t: ev.t, source, index: 0 }));
        }
    }
    while let Some(Reverse(head)) = heap.pop() {
        let stream = streams[head.source];
        match layout.place(head.source, &stream[head.index]) {
            Some(ev) => out.push(ev),
            None => dropped += 1,
        }
        let next = head.index + 1;
        if next < stream.len() {
            heap.push(Reverse(Head { t: stream[next].t, source: head.source, index: next }));
        }
    }
    (out, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::validate_stream;
    use crate::testutil::synthetic_events_seeded;

    #[test]
    fn merge_is_time_ordered_and_complete() {
        let a = synthetic_events_seeded(500, 64, 64, 1);
        let b = synthetic_events_seeded(700, 64, 64, 2);
        let c = synthetic_events_seeded(300, 64, 64, 3);
        let merged = merge_streams(&[&a, &b, &c]);
        assert_eq!(merged.len(), 1500);
        assert!(merged.windows(2).all(|w| w[0].t <= w[1].t), "must be time-ordered");
    }

    #[test]
    fn merge_is_deterministic_on_ties() {
        let a = vec![Event::on(1, 1, 100)];
        let b = vec![Event::off(2, 2, 100)];
        let m1 = merge_streams(&[&a, &b]);
        let m2 = merge_streams(&[&a, &b]);
        assert_eq!(m1, m2);
        assert_eq!(m1[0], a[0], "tie breaks to lower source id");
    }

    #[test]
    fn merge_empty_and_unbalanced() {
        let a: Vec<Event> = vec![];
        let b = vec![Event::on(0, 0, 1)];
        assert_eq!(merge_streams(&[&a, &b]).len(), 1);
        assert!(merge_streams(&[&a, &a]).is_empty());
        assert!(merge_streams(&[]).is_empty());
    }

    #[test]
    fn side_by_side_layout_places_without_overlap() {
        let layout = SourceLayout::side_by_side(&[
            Resolution::new(64, 48),
            Resolution::new(128, 96),
        ]);
        assert_eq!(layout.canvas, Resolution::new(192, 96));
        let left = layout.place(0, &Event::on(63, 47, 0)).unwrap();
        let right = layout.place(1, &Event::on(0, 0, 0)).unwrap();
        assert_eq!((left.x, left.y), (63, 47));
        assert_eq!((right.x, right.y), (64, 0));
        // Out of the source's own bounds: rejected even if canvas fits.
        assert!(layout.place(0, &Event::on(64, 0, 0)).is_none());
        assert!(layout.place(2, &Event::on(0, 0, 0)).is_none());
    }

    #[test]
    fn grid_layout_tiles_row_major() {
        let res = Resolution::new(64, 48);
        let layout = SourceLayout::grid(&[res, res, res]);
        // 3 sources → 2 columns × 2 rows.
        assert_eq!(layout.canvas, Resolution::new(128, 96));
        assert_eq!(
            layout.placements.iter().map(|p| (p.x_offset, p.y_offset)).collect::<Vec<_>>(),
            vec![(0, 0), (64, 0), (0, 48)]
        );
        // Mixed sizes: cells fit the largest source.
        let mixed = SourceLayout::grid(&[Resolution::new(32, 32), Resolution::new(64, 48)]);
        assert_eq!(mixed.canvas, Resolution::new(128, 48));
        assert_eq!(mixed.placements[1].x_offset, 64);
    }

    #[test]
    fn explicit_offsets_place_and_bound() {
        let layout = SourceLayout::at_offsets(
            &[Resolution::new(64, 48), Resolution::new(64, 48)],
            &[(0, 0), (100, 30)],
        );
        assert_eq!(layout.canvas, Resolution::new(164, 78));
        let placed = layout.place(1, &Event::on(5, 5, 0)).unwrap();
        assert_eq!((placed.x, placed.y), (105, 35));
        // Overlapping regions are allowed (that is what overlay is).
        let overlapping = SourceLayout::at_offsets(
            &[Resolution::new(64, 48), Resolution::new(64, 48)],
            &[(0, 0), (10, 0)],
        );
        assert_eq!(overlapping.canvas, Resolution::new(74, 48));
    }

    #[test]
    fn overlay_layout_shares_the_origin() {
        let layout =
            SourceLayout::overlay(&[Resolution::new(64, 48), Resolution::new(128, 96)]);
        assert_eq!(layout.canvas, Resolution::new(128, 96));
        let a = layout.place(0, &Event::on(63, 47, 0)).unwrap();
        let b = layout.place(1, &Event::on(63, 47, 0)).unwrap();
        assert_eq!((a.x, a.y), (b.x, b.y), "overlay must not offset");
        // Bounds are still per-source: source 0 is only 64×48.
        assert!(layout.place(0, &Event::on(64, 0, 0)).is_none());
        assert!(layout.place(1, &Event::on(64, 0, 0)).is_some());
    }

    #[test]
    fn fuse_produces_valid_canvas_stream() {
        let a = synthetic_events_seeded(400, 64, 48, 4);
        let b = synthetic_events_seeded(400, 64, 48, 5);
        let layout =
            SourceLayout::side_by_side(&[Resolution::new(64, 48), Resolution::new(64, 48)]);
        let (fused, dropped) = fuse(&[&a, &b], &layout);
        assert_eq!(dropped, 0);
        assert_eq!(fused.len(), 800);
        assert_eq!(validate_stream(&fused, layout.canvas), None);
        // Events from source 1 live in the right half.
        assert!(fused.iter().any(|e| e.x >= 64));
    }
}
