//! Composable event pipelines.
//!
//! "Functions of identical signatures can be freely combined to create
//! the desired processing pipeline" (paper §4, Fig. 2). The uniform
//! signature here is [`EventTransform::apply`]: event in, zero-or-one
//! event out — pure per-event functions that compose into a [`Pipeline`]
//! and run under any [`crate::engine`].
//!
//! * [`ops`] — the standard transforms (polarity filter, ROI crop,
//!   downsample, refractory period, background-activity denoise,
//!   geometric flips, time shift);
//! * [`framer`] — event → dense-frame binning for tensor consumers;
//! * [`fusion`] — multi-sensor k-way merge + canvas layout (§6 future
//!   work: multimodal sensing);
//! * [`backpressure`] — bounded queues with overflow policies (§6:
//!   embedded bottleneck behaviour, made explicit);
//! * [`registry`] — the Table 1 feature matrix of this library's I/O.

pub mod backpressure;
pub mod framer;
pub mod fusion;
pub mod ops;
pub mod registry;
pub mod viewer;

use crate::aer::{Event, Resolution};

/// Parallelization contract of a transform — the vector-style
/// function/task split, refined for pixel-addressed streams.
///
/// The class tells the topology compiler ([`crate::stream::StageGraph`])
/// how a stage may be spread across shard nodes without changing its
/// output:
///
/// * [`Stateless`](TransformClass::Stateless) — a pure per-event
///   function; any partition of the stream produces the same per-event
///   results, so the stage can run as N shard nodes under any router.
/// * [`Stateful`](TransformClass::Stateful) — state keyed by pixel
///   geometry (refractory clocks, denoise activity maps). Shardable by
///   pixel stripe with one *owned* state copy per shard, because a
///   pixel's events always land in the same stripe; `halo` is the
///   spatial support radius (in pixels) the transform reads *around* an
///   event, which the router satisfies with ghost events from
///   neighbouring stripes (state updates whose outputs are discarded).
///   Stateful transforms must also implement
///   [`EventTransform::export_rows`]/[`EventTransform::import_rows`] so
///   an adaptive re-cut can hand per-column state to the new owner
///   shard.
/// * [`Barrier`](TransformClass::Barrier) — order- or stream-global
///   (frame binning, fusion): must run on a single node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransformClass {
    /// Pure per-event function: shardable under any partition.
    Stateless,
    /// Geometry-keyed state: shardable by pixel stripe; `halo` is the
    /// spatial support radius read around each event (0 = the event's
    /// own pixel only).
    Stateful {
        /// Neighbourhood radius in pixels.
        halo: u16,
    },
    /// Order-sensitive: runs on exactly one node.
    Barrier,
}

impl TransformClass {
    /// `true` if the class permits stripe-sharded execution.
    pub fn shardable(&self) -> bool {
        !matches!(self, TransformClass::Barrier)
    }

    /// The spatial support radius the shard router must cover with
    /// ghost events (0 for stateless and pixel-local stages).
    pub fn halo(&self) -> u16 {
        match self {
            TransformClass::Stateful { halo } => *halo,
            _ => 0,
        }
    }
}

/// A per-event transform: the paper's composable function unit.
///
/// Transforms may be stateful (e.g. refractory filters track last-spike
/// times) but must be deterministic given the event sequence.
pub trait EventTransform: Send {
    /// Process one event; `None` drops it.
    fn apply(&mut self, ev: Event) -> Option<Event>;

    /// Human-readable description (CLI `--describe`, bench labels).
    fn describe(&self) -> String;

    /// Reset internal state (start of a new stream).
    fn reset(&mut self) {}

    /// Parallelization class. The conservative default is
    /// [`TransformClass::Barrier`] (single node); transforms that are
    /// safe to shard must opt in explicitly.
    fn class(&self) -> TransformClass {
        TransformClass::Barrier
    }

    /// Export the per-pixel state rows for canvas columns `x0..x1`
    /// (column-major: for each column, `height` words top to bottom) —
    /// the hand-off half of an adaptive stripe **re-cut**. When the
    /// topology re-cuts stripe boundaries mid-run, each column's state
    /// is exported from its old owner shard and
    /// [`import_rows`](EventTransform::import_rows)ed into the new one,
    /// so geometry-keyed state survives the move and the output stays
    /// byte-identical to the serial pipeline.
    ///
    /// Transforms declaring [`TransformClass::Stateful`] **must**
    /// implement both halves (the registered refractory and denoise
    /// filters do); stateless transforms are free — the defaults export
    /// nothing and ignore imports.
    fn export_rows(&self, _x0: u16, _x1: u16) -> Vec<u64> {
        Vec::new()
    }

    /// Import state rows previously produced by
    /// [`export_rows`](EventTransform::export_rows) for the same column
    /// span (see there for layout and contract).
    fn import_rows(&mut self, _x0: u16, _x1: u16, _rows: &[u64]) {}
}

/// A chain of transforms applied in order, short-circuiting on drop.
#[derive(Default)]
pub struct Pipeline {
    stages: Vec<Box<dyn EventTransform>>,
}

impl Pipeline {
    /// Empty pipeline (identity).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a transform stage. Builder-style.
    pub fn then<T: EventTransform + 'static>(mut self, stage: T) -> Self {
        self.stages.push(Box::new(stage));
        self
    }

    /// Append a boxed transform stage.
    pub fn then_boxed(mut self, stage: Box<dyn EventTransform>) -> Self {
        self.stages.push(stage);
        self
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// `true` if the pipeline is the identity.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Process one event through every stage.
    #[inline]
    pub fn apply(&mut self, ev: Event) -> Option<Event> {
        let mut ev = ev;
        for stage in &mut self.stages {
            match stage.apply(ev) {
                Some(next) => ev = next,
                None => return None,
            }
        }
        Some(ev)
    }

    /// Process a whole slice, returning the surviving events.
    pub fn process(&mut self, events: &[Event]) -> Vec<Event> {
        let mut out = Vec::with_capacity(events.len());
        for &ev in events {
            if let Some(ev) = self.apply(ev) {
                out.push(ev);
            }
        }
        out
    }

    /// Reset every stage.
    pub fn reset(&mut self) {
        for stage in &mut self.stages {
            stage.reset();
        }
    }

    /// `stage1 | stage2 | …` description string.
    pub fn describe(&self) -> String {
        if self.stages.is_empty() {
            return "identity".into();
        }
        self.stages.iter().map(|s| s.describe()).collect::<Vec<_>>().join(" | ")
    }
}

// ------------------------------------------------------------------ spec

/// Geometry-aware stage constructor: canvas in, fresh transform out.
type StageBuilder = Box<dyn Fn(Resolution) -> Box<dyn EventTransform> + Send + Sync>;

/// A *deferred* pipeline stage: a factory that builds a fresh
/// [`EventTransform`] for a given canvas geometry.
///
/// Two things a bare [`Pipeline`] cannot express require the
/// indirection:
///
/// * geometry-keyed stages (refractory, denoise) must be built from the
///   geometry of the *opened* sources — the fused canvas — not from
///   whatever the command line assumed before any header was read;
/// * sharded execution needs N independent instances of a stage, one
///   per shard node, each owning its stripe's state.
pub struct StageSpec {
    name: String,
    class: TransformClass,
    pinned: bool,
    build: StageBuilder,
}

impl StageSpec {
    /// Wrap a geometry-aware constructor. The stage's name and class
    /// are sampled from a throwaway 1×1 instance (both must be
    /// geometry-independent, which holds for every registered op).
    pub fn new<T, F>(build: F) -> Self
    where
        T: EventTransform + 'static,
        F: Fn(Resolution) -> T + Send + Sync + 'static,
    {
        let sample = build(Resolution::new(1, 1));
        StageSpec {
            name: sample.describe(),
            class: sample.class(),
            pinned: false,
            build: Box::new(move |res| Box::new(build(res)) as Box<dyn EventTransform>),
        }
    }

    /// Pin this stage to a single (barrier) node even if its class
    /// would allow sharding — the CLI's `@serial` placement.
    pub fn pinned(mut self) -> Self {
        self.pinned = true;
        self
    }

    /// Stage description (sampled from the constructor).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared parallelization class.
    pub fn class(&self) -> TransformClass {
        self.class
    }

    /// `true` if the stage was pinned to a single node.
    pub fn is_pinned(&self) -> bool {
        self.pinned
    }

    /// Build one instance for canvas `res`.
    pub fn build(&self, res: Resolution) -> Box<dyn EventTransform> {
        (self.build)(res)
    }
}

impl std::fmt::Debug for StageSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StageSpec")
            .field("name", &self.name)
            .field("class", &self.class)
            .field("pinned", &self.pinned)
            .finish()
    }
}

/// An ordered list of deferred stages: what the CLI parses and the
/// topology compiler ([`crate::stream::StageGraph`]) consumes. Build a
/// plain serial [`Pipeline`] from it with
/// [`build_pipeline`](PipelineSpec::build_pipeline).
#[derive(Debug, Default)]
pub struct PipelineSpec {
    stages: Vec<StageSpec>,
}

impl PipelineSpec {
    /// Empty spec (identity pipeline).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a stage. Builder-style.
    pub fn then(mut self, stage: StageSpec) -> Self {
        self.stages.push(stage);
        self
    }

    /// Append a stage in place.
    pub fn push(&mut self, stage: StageSpec) {
        self.stages.push(stage);
    }

    /// The stages, in order.
    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// `true` if the spec is the identity.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Instantiate every stage for canvas `res` as one serial
    /// [`Pipeline`] — the reference execution the sharded graph must
    /// match event for event.
    pub fn build_pipeline(&self, res: Resolution) -> Pipeline {
        let mut p = Pipeline::new();
        for stage in &self.stages {
            p = p.then_boxed(stage.build(res));
        }
        p
    }

    /// `stage1 | stage2 | …` description string.
    pub fn describe(&self) -> String {
        if self.stages.is_empty() {
            return "identity".into();
        }
        self.stages.iter().map(|s| s.name.clone()).collect::<Vec<_>>().join(" | ")
    }
}

#[cfg(test)]
mod tests {
    use super::ops::{PolarityFilter, TimeShift};
    use super::*;
    use crate::aer::Polarity;
    use crate::testutil::synthetic_events;

    #[test]
    fn empty_pipeline_is_identity() {
        let events = synthetic_events(100, 64, 64);
        let mut p = Pipeline::new();
        assert_eq!(p.process(&events), events);
        assert_eq!(p.describe(), "identity");
    }

    #[test]
    fn stages_compose_in_order() {
        let mut p = Pipeline::new()
            .then(PolarityFilter::keep(Polarity::On))
            .then(TimeShift::new(100));
        let events = vec![Event::on(1, 1, 10), Event::off(2, 2, 20), Event::on(3, 3, 30)];
        let out = p.process(&events);
        assert_eq!(out, vec![Event::on(1, 1, 110), Event::on(3, 3, 130)]);
        assert_eq!(p.describe(), "polarity(on) | time_shift(+100µs)");
    }

    #[test]
    fn drop_short_circuits() {
        // A stage after a dropping filter must never see dropped events:
        // verified via a counting stage.
        struct Count(u64);
        impl EventTransform for Count {
            fn apply(&mut self, ev: Event) -> Option<Event> {
                self.0 += 1;
                Some(ev)
            }
            fn describe(&self) -> String {
                "count".into()
            }
        }
        let mut p =
            Pipeline::new().then(PolarityFilter::keep(Polarity::Off)).then(Count(0));
        let events = synthetic_events(1000, 64, 64);
        let kept = p.process(&events).len();
        let on_events = events.iter().filter(|e| e.p.is_on()).count();
        assert_eq!(kept + on_events, events.len());
    }

    #[test]
    fn spec_builds_the_same_pipeline_as_direct_composition() {
        use super::ops::RefractoryFilter;
        let res = Resolution::new(64, 48);
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|_| PolarityFilter::keep(Polarity::On)))
            .then(StageSpec::new(|res| RefractoryFilter::new(res, 100)));
        assert_eq!(spec.describe(), "polarity(on) | refractory(100µs)");
        assert_eq!(spec.len(), 2);
        assert_eq!(spec.stages()[0].class(), TransformClass::Stateless);
        assert_eq!(spec.stages()[1].class(), TransformClass::Stateful { halo: 0 });

        let events = synthetic_events(2000, 64, 48);
        let mut direct = Pipeline::new()
            .then(PolarityFilter::keep(Polarity::On))
            .then(RefractoryFilter::new(res, 100));
        let mut built = spec.build_pipeline(res);
        assert_eq!(built.process(&events), direct.process(&events));
        assert_eq!(built.describe(), direct.describe());
    }

    #[test]
    fn default_class_is_barrier_and_pinning_sticks() {
        struct Opaque;
        impl EventTransform for Opaque {
            fn apply(&mut self, ev: Event) -> Option<Event> {
                Some(ev)
            }
            fn describe(&self) -> String {
                "opaque".into()
            }
        }
        assert_eq!(Opaque.class(), TransformClass::Barrier);
        assert!(!TransformClass::Barrier.shardable());
        assert_eq!(TransformClass::Stateful { halo: 2 }.halo(), 2);
        let spec = StageSpec::new(|_| Opaque).pinned();
        assert!(spec.is_pinned());
        assert_eq!(spec.class(), TransformClass::Barrier);
    }
}
