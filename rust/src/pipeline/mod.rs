//! Composable event pipelines.
//!
//! "Functions of identical signatures can be freely combined to create
//! the desired processing pipeline" (paper §4, Fig. 2). The uniform
//! signature here is [`EventTransform::apply`]: event in, zero-or-one
//! event out — pure per-event functions that compose into a [`Pipeline`]
//! and run under any [`crate::engine`].
//!
//! * [`ops`] — the standard transforms (polarity filter, ROI crop,
//!   downsample, refractory period, background-activity denoise,
//!   geometric flips, time shift);
//! * [`framer`] — event → dense-frame binning for tensor consumers;
//! * [`fusion`] — multi-sensor k-way merge + canvas layout (§6 future
//!   work: multimodal sensing);
//! * [`backpressure`] — bounded queues with overflow policies (§6:
//!   embedded bottleneck behaviour, made explicit);
//! * [`registry`] — the Table 1 feature matrix of this library's I/O.

pub mod backpressure;
pub mod framer;
pub mod fusion;
pub mod ops;
pub mod registry;
pub mod viewer;

use crate::aer::Event;

/// A per-event transform: the paper's composable function unit.
///
/// Transforms may be stateful (e.g. refractory filters track last-spike
/// times) but must be deterministic given the event sequence.
pub trait EventTransform: Send {
    /// Process one event; `None` drops it.
    fn apply(&mut self, ev: Event) -> Option<Event>;

    /// Human-readable description (CLI `--describe`, bench labels).
    fn describe(&self) -> String;

    /// Reset internal state (start of a new stream).
    fn reset(&mut self) {}
}

/// A chain of transforms applied in order, short-circuiting on drop.
#[derive(Default)]
pub struct Pipeline {
    stages: Vec<Box<dyn EventTransform>>,
}

impl Pipeline {
    /// Empty pipeline (identity).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a transform stage. Builder-style.
    pub fn then<T: EventTransform + 'static>(mut self, stage: T) -> Self {
        self.stages.push(Box::new(stage));
        self
    }

    /// Append a boxed transform stage.
    pub fn then_boxed(mut self, stage: Box<dyn EventTransform>) -> Self {
        self.stages.push(stage);
        self
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// `true` if the pipeline is the identity.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Process one event through every stage.
    #[inline]
    pub fn apply(&mut self, ev: Event) -> Option<Event> {
        let mut ev = ev;
        for stage in &mut self.stages {
            match stage.apply(ev) {
                Some(next) => ev = next,
                None => return None,
            }
        }
        Some(ev)
    }

    /// Process a whole slice, returning the surviving events.
    pub fn process(&mut self, events: &[Event]) -> Vec<Event> {
        let mut out = Vec::with_capacity(events.len());
        for &ev in events {
            if let Some(ev) = self.apply(ev) {
                out.push(ev);
            }
        }
        out
    }

    /// Reset every stage.
    pub fn reset(&mut self) {
        for stage in &mut self.stages {
            stage.reset();
        }
    }

    /// `stage1 | stage2 | …` description string.
    pub fn describe(&self) -> String {
        if self.stages.is_empty() {
            return "identity".into();
        }
        self.stages.iter().map(|s| s.describe()).collect::<Vec<_>>().join(" | ")
    }
}

#[cfg(test)]
mod tests {
    use super::ops::{PolarityFilter, TimeShift};
    use super::*;
    use crate::aer::Polarity;
    use crate::testutil::synthetic_events;

    #[test]
    fn empty_pipeline_is_identity() {
        let events = synthetic_events(100, 64, 64);
        let mut p = Pipeline::new();
        assert_eq!(p.process(&events), events);
        assert_eq!(p.describe(), "identity");
    }

    #[test]
    fn stages_compose_in_order() {
        let mut p = Pipeline::new()
            .then(PolarityFilter::keep(Polarity::On))
            .then(TimeShift::new(100));
        let events = vec![Event::on(1, 1, 10), Event::off(2, 2, 20), Event::on(3, 3, 30)];
        let out = p.process(&events);
        assert_eq!(out, vec![Event::on(1, 1, 110), Event::on(3, 3, 130)]);
        assert_eq!(p.describe(), "polarity(on) | time_shift(+100µs)");
    }

    #[test]
    fn drop_short_circuits() {
        // A stage after a dropping filter must never see dropped events:
        // verified via a counting stage.
        struct Count(u64);
        impl EventTransform for Count {
            fn apply(&mut self, ev: Event) -> Option<Event> {
                self.0 += 1;
                Some(ev)
            }
            fn describe(&self) -> String {
                "count".into()
            }
        }
        let mut p =
            Pipeline::new().then(PolarityFilter::keep(Polarity::Off)).then(Count(0));
        let events = synthetic_events(1000, 64, 64);
        let kept = p.process(&events).len();
        let on_events = events.iter().filter(|e| e.p.is_on()).count();
        assert_eq!(kept + on_events, events.len());
    }
}
