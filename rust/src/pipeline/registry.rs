//! The Table 1 feature matrix.
//!
//! Table 1 of the paper surveys open-source AER libraries by language,
//! Python bindings, and native input/output support. This registry holds
//! both the paper's survey rows (verbatim from the table) and *this*
//! library's row computed from what is actually compiled in — the
//! `table1_matrix` example renders the comparison.

/// Kinds of I/O a library can support natively.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Gpu,
    Camera,
    File,
    Network,
}

impl IoKind {
    /// Icon used in the rendered table (the paper uses pictograms).
    pub fn icon(&self) -> &'static str {
        match self {
            IoKind::Gpu => "GPU",
            IoKind::Camera => "CAM",
            IoKind::File => "FILE",
            IoKind::Network => "NET",
        }
    }
}

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct LibraryRow {
    pub name: &'static str,
    pub language: &'static str,
    pub python_bindings: bool,
    pub inputs: &'static [IoKind],
    /// `None` renders as "N/A" (no native outputs).
    pub outputs: Option<&'static [IoKind]>,
}

/// The paper's survey rows (Table 1), excluding AEStream itself.
pub fn paper_rows() -> Vec<LibraryRow> {
    use IoKind::*;
    vec![
        LibraryRow {
            name: "AEDAT",
            language: "Rust",
            python_bindings: true,
            inputs: &[File],
            outputs: None,
        },
        LibraryRow {
            name: "Celex",
            language: "C++",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "Expelliarmus",
            language: "C",
            python_bindings: true,
            inputs: &[File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "jAER",
            language: "Java",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "LibCAER",
            language: "C/C++",
            python_bindings: false,
            inputs: &[Camera, Network],
            outputs: None,
        },
        LibraryRow {
            name: "OpenEB",
            language: "C++",
            python_bindings: true,
            inputs: &[Camera, File, Network],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "Sepia",
            language: "C++",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: None,
        },
    ]
}

/// This library's row, derived from the compiled-in capabilities:
/// file codecs ([`crate::formats`]), SPIF/UDP ([`crate::net`]), the
/// synthetic camera ([`crate::camera`]) and the XLA/PJRT device sink
/// ([`crate::runtime`] — the paper's "GPU" column).
pub fn our_row() -> LibraryRow {
    use IoKind::*;
    LibraryRow {
        name: "aestream (this repo)",
        language: "Rust",
        // Build-time JAX/Pallas, not runtime bindings; still "yes" in the
        // table's sense of a Python-accessible toolchain.
        python_bindings: true,
        inputs: &[Camera, File, Network],
        outputs: Some(&[Gpu, File, Network]),
    }
}

/// Render the full comparison as an aligned text table.
pub fn render_table() -> String {
    let mut rows = paper_rows();
    rows.insert(0, our_row());
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<7} {:<7} {:<18} {:<18}\n",
        "Library", "Lang", "Python", "Inputs", "Outputs"
    ));
    out.push_str(&"-".repeat(74));
    out.push('\n');
    for r in rows {
        let inputs =
            r.inputs.iter().map(|k| k.icon()).collect::<Vec<_>>().join("+");
        let outputs = match r.outputs {
            Some(os) => os.iter().map(|k| k.icon()).collect::<Vec<_>>().join("+"),
            None => "N/A".into(),
        };
        out.push_str(&format!(
            "{:<22} {:<7} {:<7} {:<18} {:<18}\n",
            r.name,
            r.language,
            if r.python_bindings { "Yes" } else { "No" },
            inputs,
            outputs
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rows_match_table_1_shape() {
        let rows = paper_rows();
        assert_eq!(rows.len(), 7);
        // Spot checks against the published table.
        let aedat = rows.iter().find(|r| r.name == "AEDAT").unwrap();
        assert_eq!(aedat.language, "Rust");
        assert!(aedat.outputs.is_none());
        let openeb = rows.iter().find(|r| r.name == "OpenEB").unwrap();
        assert!(openeb.python_bindings);
    }

    #[test]
    fn our_row_claims_match_compiled_capabilities() {
        let row = our_row();
        // File support ⇔ formats module has codecs.
        assert!(row.inputs.contains(&IoKind::File));
        assert!(!crate::formats::Format::ALL.is_empty());
        // Network support ⇔ SPIF codec exists.
        assert!(row.inputs.contains(&IoKind::Network));
        let word = crate::net::spif::pack_word(&crate::aer::Event::on(1, 2, 3));
        assert_eq!(crate::net::spif::unpack_word(word, 3).x, 1);
        // GPU(device) output ⇔ runtime module compiles (asserted by build).
        assert!(row.outputs.unwrap().contains(&IoKind::Gpu));
    }

    #[test]
    fn rendered_table_contains_all_libraries() {
        let table = render_table();
        for name in ["aestream", "AEDAT", "Celex", "Expelliarmus", "jAER", "LibCAER", "OpenEB", "Sepia"]
        {
            assert!(table.contains(name), "missing {name} in rendered table");
        }
    }
}
