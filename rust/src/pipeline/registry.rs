//! The Table 1 feature matrix, plus the transform-op catalog.
//!
//! Table 1 of the paper surveys open-source AER libraries by language,
//! Python bindings, and native input/output support. This registry holds
//! both the paper's survey rows (verbatim from the table) and *this*
//! library's row computed from what is actually compiled in — the
//! `table1_matrix` example renders the comparison.
//!
//! [`transform_ops`] is the second registry: every standard pipeline op
//! with its CLI name and declared [`TransformClass`], so the CLI, the
//! topology compiler, and the sharded-vs-serial equivalence tests all
//! enumerate the same set — an op added here is automatically covered
//! by the stage-graph property tests.

use crate::aer::{Polarity, Resolution};
use crate::pipeline::{ops, StageSpec, TransformClass};

/// One registered pipeline transform: CLI name, declared
/// parallelization class, argument help, and a canonical example
/// constructor (used by tests and benches to exercise every op).
pub struct TransformOp {
    /// CLI `filter` name.
    pub name: &'static str,
    /// Declared class — must match what built instances report.
    pub class: TransformClass,
    /// Argument usage, CLI help.
    pub usage: &'static str,
    /// Canonical geometry-deferred example instance.
    pub example: fn() -> StageSpec,
}

/// Every standard transform with its declared class. The stage-graph
/// equivalence tests iterate this list, so sharding safety is proven
/// per registered op, not per hand-picked case.
pub fn transform_ops() -> Vec<TransformOp> {
    use TransformClass as C;
    vec![
        TransformOp {
            name: "polarity",
            class: C::Stateless,
            usage: "polarity on|off",
            example: || StageSpec::new(|_| ops::PolarityFilter::keep(Polarity::On)),
        },
        TransformOp {
            name: "crop",
            class: C::Stateless,
            usage: "crop X0 Y0 W H",
            example: || StageSpec::new(|_| ops::RoiCrop::new(2, 2, 24, 24)),
        },
        TransformOp {
            name: "downsample",
            class: C::Stateless,
            usage: "downsample FACTOR",
            example: || StageSpec::new(|_| ops::Downsample::new(2)),
        },
        TransformOp {
            name: "refractory",
            class: C::Stateful { halo: 0 },
            usage: "refractory PERIOD_US",
            example: || StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100)),
        },
        TransformOp {
            name: "denoise",
            class: C::Stateful { halo: 1 },
            usage: "denoise WINDOW_US",
            example: || {
                StageSpec::new(|res: Resolution| ops::BackgroundActivityFilter::new(res, 1000))
            },
        },
        TransformOp {
            name: "flip-x",
            class: C::Stateless,
            usage: "flip-x",
            example: || StageSpec::new(|res: Resolution| ops::FlipX::new(res.width)),
        },
        TransformOp {
            name: "flip-y",
            class: C::Stateless,
            usage: "flip-y",
            example: || StageSpec::new(|res: Resolution| ops::FlipY::new(res.height)),
        },
        TransformOp {
            name: "transpose",
            class: C::Stateless,
            usage: "transpose",
            example: || StageSpec::new(|_| ops::Transpose),
        },
        TransformOp {
            name: "time-shift",
            class: C::Stateless,
            usage: "time-shift OFFSET_US",
            example: || StageSpec::new(|_| ops::TimeShift::new(50)),
        },
    ]
}

/// Kinds of I/O a library can support natively.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Gpu,
    Camera,
    File,
    Network,
}

impl IoKind {
    /// Icon used in the rendered table (the paper uses pictograms).
    pub fn icon(&self) -> &'static str {
        match self {
            IoKind::Gpu => "GPU",
            IoKind::Camera => "CAM",
            IoKind::File => "FILE",
            IoKind::Network => "NET",
        }
    }
}

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct LibraryRow {
    pub name: &'static str,
    pub language: &'static str,
    pub python_bindings: bool,
    pub inputs: &'static [IoKind],
    /// `None` renders as "N/A" (no native outputs).
    pub outputs: Option<&'static [IoKind]>,
}

/// The paper's survey rows (Table 1), excluding AEStream itself.
pub fn paper_rows() -> Vec<LibraryRow> {
    use IoKind::*;
    vec![
        LibraryRow {
            name: "AEDAT",
            language: "Rust",
            python_bindings: true,
            inputs: &[File],
            outputs: None,
        },
        LibraryRow {
            name: "Celex",
            language: "C++",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "Expelliarmus",
            language: "C",
            python_bindings: true,
            inputs: &[File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "jAER",
            language: "Java",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "LibCAER",
            language: "C/C++",
            python_bindings: false,
            inputs: &[Camera, Network],
            outputs: None,
        },
        LibraryRow {
            name: "OpenEB",
            language: "C++",
            python_bindings: true,
            inputs: &[Camera, File, Network],
            outputs: Some(&[File]),
        },
        LibraryRow {
            name: "Sepia",
            language: "C++",
            python_bindings: false,
            inputs: &[Camera, File],
            outputs: None,
        },
    ]
}

/// This library's row, derived from the compiled-in capabilities:
/// file codecs ([`crate::formats`]), SPIF/UDP ([`crate::net`]), the
/// synthetic camera ([`crate::camera`]) and the XLA/PJRT device sink
/// ([`crate::runtime`] — the paper's "GPU" column).
pub fn our_row() -> LibraryRow {
    use IoKind::*;
    LibraryRow {
        name: "aestream (this repo)",
        language: "Rust",
        // Build-time JAX/Pallas, not runtime bindings; still "yes" in the
        // table's sense of a Python-accessible toolchain.
        python_bindings: true,
        inputs: &[Camera, File, Network],
        outputs: Some(&[Gpu, File, Network]),
    }
}

/// Render the full comparison as an aligned text table.
pub fn render_table() -> String {
    let mut rows = paper_rows();
    rows.insert(0, our_row());
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<7} {:<7} {:<18} {:<18}\n",
        "Library", "Lang", "Python", "Inputs", "Outputs"
    ));
    out.push_str(&"-".repeat(74));
    out.push('\n');
    for r in rows {
        let inputs =
            r.inputs.iter().map(|k| k.icon()).collect::<Vec<_>>().join("+");
        let outputs = match r.outputs {
            Some(os) => os.iter().map(|k| k.icon()).collect::<Vec<_>>().join("+"),
            None => "N/A".into(),
        };
        out.push_str(&format!(
            "{:<22} {:<7} {:<7} {:<18} {:<18}\n",
            r.name,
            r.language,
            if r.python_bindings { "Yes" } else { "No" },
            inputs,
            outputs
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rows_match_table_1_shape() {
        let rows = paper_rows();
        assert_eq!(rows.len(), 7);
        // Spot checks against the published table.
        let aedat = rows.iter().find(|r| r.name == "AEDAT").unwrap();
        assert_eq!(aedat.language, "Rust");
        assert!(aedat.outputs.is_none());
        let openeb = rows.iter().find(|r| r.name == "OpenEB").unwrap();
        assert!(openeb.python_bindings);
    }

    #[test]
    fn our_row_claims_match_compiled_capabilities() {
        let row = our_row();
        // File support ⇔ formats module has codecs.
        assert!(row.inputs.contains(&IoKind::File));
        assert!(!crate::formats::Format::ALL.is_empty());
        // Network support ⇔ SPIF codec exists.
        assert!(row.inputs.contains(&IoKind::Network));
        let word = crate::net::spif::pack_word(&crate::aer::Event::on(1, 2, 3));
        assert_eq!(crate::net::spif::unpack_word(word, 3).x, 1);
        // GPU(device) output ⇔ runtime module compiles (asserted by build).
        assert!(row.outputs.unwrap().contains(&IoKind::Gpu));
    }

    #[test]
    fn declared_op_classes_match_built_instances() {
        for op in transform_ops() {
            let spec = (op.example)();
            assert_eq!(
                spec.class(),
                op.class,
                "op {:?}: declared class diverges from the instance's",
                op.name
            );
            // Sampled at 1×1 and built at a real geometry, the class
            // must not change (it is a static property of the op).
            let built = spec.build(Resolution::new(64, 64));
            assert_eq!(built.class(), op.class, "op {:?}", op.name);
        }
    }

    #[test]
    fn op_names_are_unique() {
        let ops = transform_ops();
        for (i, a) in ops.iter().enumerate() {
            for b in &ops[i + 1..] {
                assert_ne!(a.name, b.name);
            }
        }
    }

    #[test]
    fn rendered_table_contains_all_libraries() {
        let table = render_table();
        for name in ["aestream", "AEDAT", "Celex", "Expelliarmus", "jAER", "LibCAER", "OpenEB", "Sepia"]
        {
            assert!(table.contains(name), "missing {name} in rendered table");
        }
    }
}
