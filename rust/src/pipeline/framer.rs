//! Event → dense-frame binning.
//!
//! Tensor consumers (the paper's GPU / our XLA device) operate on dense
//! `[H, W]` frames: the framer accumulates the events of each fixed time
//! window into a frame of per-pixel signed event counts (ON − OFF),
//! which is exactly what the Norse/PyTorch path of the paper feeds its
//! spiking network. Also the reference oracle for the L1 Pallas
//! `event_scatter` kernel.

use crate::aer::{Event, Resolution};

/// A dense frame of per-pixel accumulated polarity counts.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Window start (inclusive), µs.
    pub t_start: u64,
    /// Window end (exclusive), µs.
    pub t_end: u64,
    /// Row-major `height × width` signed event counts.
    pub data: Vec<f32>,
    /// Geometry.
    pub resolution: Resolution,
    /// Number of events binned into this frame.
    pub event_count: u64,
}

impl Frame {
    /// Zeroed frame for a window.
    pub fn zeroed(resolution: Resolution, t_start: u64, t_end: u64) -> Self {
        Frame {
            t_start,
            t_end,
            data: vec![0.0; resolution.pixels()],
            resolution,
            event_count: 0,
        }
    }

    /// Accumulate one event (must be within the window; unchecked).
    #[inline]
    pub fn accumulate(&mut self, ev: &Event) {
        self.data[ev.pixel_index(self.resolution.width)] += ev.p.signum();
        self.event_count += 1;
    }

    /// Sum of absolute pixel values (≤ event_count; equality iff no
    /// pixel saw both polarities).
    pub fn l1(&self) -> f64 {
        self.data.iter().map(|v| v.abs() as f64).sum()
    }
}

/// Bins a time-ordered event stream into fixed windows.
///
/// `push` returns completed frames (possibly several, if the stream
/// jumps over empty windows — empty windows are *not* emitted, matching
/// AEStream's behaviour of only shipping frames that carry events unless
/// `emit_empty` is set).
#[derive(Debug)]
pub struct Framer {
    resolution: Resolution,
    window_us: u64,
    /// Emit zero frames for windows with no events.
    pub emit_empty: bool,
    current: Option<Frame>,
}

impl Framer {
    /// New framer with the given window length.
    pub fn new(resolution: Resolution, window_us: u64) -> Self {
        Framer { resolution, window_us: window_us.max(1), emit_empty: false, current: None }
    }

    /// Window length in µs.
    pub fn window_us(&self) -> u64 {
        self.window_us
    }

    /// Sensor geometry frames are binned for.
    pub fn resolution(&self) -> Resolution {
        self.resolution
    }

    /// Grow the binning geometry mid-stream (sources that learn their
    /// extent by observation, e.g. UDP). The in-progress frame is
    /// zero-padded into the new geometry, so windows and event counts
    /// are unaffected. Geometry never shrinks.
    pub fn rebind(&mut self, res: Resolution) {
        let res = Resolution::new(
            res.width.max(self.resolution.width),
            res.height.max(self.resolution.height),
        );
        if res == self.resolution {
            return;
        }
        if let Some(frame) = &mut self.current {
            let mut data = vec![0.0f32; res.pixels()];
            let (old_w, new_w) = (frame.resolution.width as usize, res.width as usize);
            for y in 0..frame.resolution.height as usize {
                data[y * new_w..y * new_w + old_w]
                    .copy_from_slice(&frame.data[y * old_w..(y + 1) * old_w]);
            }
            frame.data = data;
            frame.resolution = res;
        }
        self.resolution = res;
    }

    /// Feed one event; returns any frames completed *before* it.
    pub fn push(&mut self, ev: &Event) -> Vec<Frame> {
        let window_start = (ev.t / self.window_us) * self.window_us;
        let mut completed = Vec::new();
        match &mut self.current {
            Some(frame) if frame.t_start == window_start => {}
            Some(frame) => {
                let prev_end = frame.t_end;
                completed.push(self.current.take().unwrap());
                if self.emit_empty {
                    let mut t = prev_end;
                    while t < window_start {
                        completed.push(Frame::zeroed(self.resolution, t, t + self.window_us));
                        t += self.window_us;
                    }
                }
            }
            None => {}
        }
        let frame = self.current.get_or_insert_with(|| {
            Frame::zeroed(self.resolution, window_start, window_start + self.window_us)
        });
        frame.accumulate(ev);
        completed
    }

    /// End of stream: flush the in-progress frame, if any.
    pub fn finish(&mut self) -> Option<Frame> {
        self.current.take()
    }

    /// Bin a whole slice (convenience for tests/benches).
    pub fn frames_of(resolution: Resolution, window_us: u64, events: &[Event]) -> Vec<Frame> {
        let mut framer = Framer::new(resolution, window_us);
        let mut frames = Vec::new();
        for ev in events {
            frames.extend(framer.push(ev));
        }
        frames.extend(framer.finish());
        frames
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Event;
    use crate::testutil::synthetic_events;

    const RES: Resolution = Resolution::new(32, 32);

    #[test]
    fn bins_by_window() {
        let events = vec![
            Event::on(0, 0, 100),
            Event::off(1, 1, 900),
            Event::on(2, 2, 1100), // next window
        ];
        let frames = Framer::frames_of(RES, 1000, &events);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].t_start, 0);
        assert_eq!(frames[0].event_count, 2);
        assert_eq!(frames[1].t_start, 1000);
        assert_eq!(frames[1].event_count, 1);
    }

    #[test]
    fn event_count_is_conserved() {
        let events = synthetic_events(5000, 32, 32);
        let frames = Framer::frames_of(RES, 700, &events);
        let total: u64 = frames.iter().map(|f| f.event_count).sum();
        assert_eq!(total, 5000);
    }

    #[test]
    fn polarity_accumulates_signed() {
        let events = vec![Event::on(3, 3, 0), Event::on(3, 3, 1), Event::off(3, 3, 2)];
        let frames = Framer::frames_of(RES, 1000, &events);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].data[3 * 32 + 3], 1.0); // +1 +1 -1
    }

    #[test]
    fn empty_windows_skipped_by_default_emitted_on_request() {
        let events = vec![Event::on(0, 0, 0), Event::on(0, 0, 5000)];
        let skipping = Framer::frames_of(RES, 1000, &events);
        assert_eq!(skipping.len(), 2);

        let mut framer = Framer::new(RES, 1000);
        framer.emit_empty = true;
        let mut frames = Vec::new();
        for ev in &events {
            frames.extend(framer.push(ev));
        }
        frames.extend(framer.finish());
        assert_eq!(frames.len(), 6); // windows 0..6000
        assert_eq!(frames.iter().filter(|f| f.event_count == 0).count(), 4);
    }

    #[test]
    fn rebind_grows_without_splitting_the_window() {
        let mut framer = Framer::new(Resolution::new(4, 4), 1000);
        let mut frames = Vec::new();
        frames.extend(framer.push(&Event::on(2, 2, 10)));
        framer.rebind(Resolution::new(100, 90));
        frames.extend(framer.push(&Event::on(99, 89, 20)));
        frames.extend(framer.finish());
        // One window, both events, activity preserved at both pixels.
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].event_count, 2);
        assert_eq!(frames[0].resolution, Resolution::new(100, 90));
        assert_eq!(frames[0].data[2 * 100 + 2], 1.0);
        assert_eq!(frames[0].data[89 * 100 + 99], 1.0);
    }

    #[test]
    fn window_boundary_is_half_open() {
        // t = window_us lands in the *second* window.
        let events = vec![Event::on(0, 0, 999), Event::on(0, 0, 1000)];
        let frames = Framer::frames_of(RES, 1000, &events);
        assert_eq!(frames.len(), 2);
    }

    #[test]
    fn l1_matches_event_count_without_cancellation() {
        let events = vec![Event::on(1, 1, 0), Event::on(2, 2, 1)];
        let frames = Framer::frames_of(RES, 1000, &events);
        assert_eq!(frames[0].l1(), 2.0);
    }
}
