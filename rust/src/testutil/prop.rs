//! Miniature property-testing harness.
//!
//! The offline environment ships no `proptest`/`quickcheck`, so this
//! module provides the 10% that covers our needs: generate N random
//! cases from a seeded [`SplitMix64`], run the property, and on failure
//! *shrink* vectors by bisection before reporting the minimal
//! reproduction (seed + case index are printed so failures replay
//! deterministically).

use super::rng::SplitMix64;

/// Number of cases per property (tuned for single-core CI).
pub const DEFAULT_CASES: usize = 64;

/// Run `property` against `cases` inputs produced by `gen`.
///
/// Panics with the seed and case index on the first failing input.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut property: P)
where
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    let base_seed = prop_seed();
    for case in 0..cases {
        let mut rng = SplitMix64::new(base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9));
        let input = gen(&mut rng);
        if !property(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {base_seed:#x}):\n  input = {input:?}"
            );
        }
    }
}

/// Run a property over random event-vector inputs with shrinking: on
/// failure the vector is bisected to a locally minimal failing slice.
pub fn check_vec<T, G, P>(name: &str, cases: usize, mut gen: G, mut property: P)
where
    G: FnMut(&mut SplitMix64) -> Vec<T>,
    P: FnMut(&[T]) -> bool,
    T: std::fmt::Debug + Clone,
{
    let base_seed = prop_seed();
    for case in 0..cases {
        let mut rng = SplitMix64::new(base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9));
        let input = gen(&mut rng);
        if !property(&input) {
            let minimal = shrink_vec(&input, &mut property);
            panic!(
                "property '{name}' failed at case {case} (seed {base_seed:#x});\n  \
                 shrunk from {} to {} elements:\n  input = {minimal:?}",
                input.len(),
                minimal.len()
            );
        }
    }
}

/// Bisection shrinker: repeatedly try dropping the first/second half and
/// then individual elements while the property still fails.
fn shrink_vec<T, P>(failing: &[T], property: &mut P) -> Vec<T>
where
    P: FnMut(&[T]) -> bool,
    T: Clone,
{
    let mut current: Vec<T> = failing.to_vec();
    loop {
        let mut improved = false;
        // Halves first (log-time progress on big inputs)…
        for (start, end) in [(0, current.len() / 2), (current.len() / 2, current.len())] {
            if end > start && end - start < current.len() {
                let candidate: Vec<T> = current[start..end].to_vec();
                if !property(&candidate) {
                    current = candidate;
                    improved = true;
                    break;
                }
            }
        }
        if improved {
            continue;
        }
        // …then single-element removal.
        let mut i = 0;
        while i < current.len() {
            let mut candidate = current.clone();
            candidate.remove(i);
            if !candidate.is_empty() && !property(&candidate) {
                current = candidate;
                improved = true;
            } else {
                i += 1;
            }
        }
        if !improved {
            return current;
        }
    }
}

/// Stable base seed; override with `AESTREAM_PROP_SEED` for replay.
fn prop_seed() -> u64 {
    std::env::var("AESTREAM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xae57_12ea)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum is commutative", 32, |rng| (rng.next_u64() >> 32, rng.next_u64() >> 32), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_name() {
        check("always false", 4, |rng| rng.next_u64(), |_| false);
    }

    #[test]
    fn shrinker_minimizes_to_single_offender() {
        // Property: no element is divisible by 1000. Failing inputs
        // shrink to exactly one offending element.
        let failing: Vec<u64> = vec![1, 2, 3000, 4, 5];
        let mut prop = |v: &[u64]| v.iter().all(|&x| x % 1000 != 0);
        let minimal = shrink_vec(&failing, &mut prop);
        assert_eq!(minimal, vec![3000]);
    }
}
