//! Deterministic test/bench utilities: a seedable PRNG (no external
//! crates are available offline) and synthetic event stream generators.
//!
//! Also hosts a miniature property-testing harness ([`prop`]) used by the
//! invariant suites in `rust/tests/`.

pub mod prop;
pub mod rng;

pub use rng::SplitMix64;

use crate::aer::{Event, Polarity};

/// Generate `n` deterministic pseudo-random events within a
/// `width × height` sensor, timestamps increasing by 0–3 µs per event.
/// Deterministic across runs (fixed seed) so benches are comparable.
pub fn synthetic_events(n: usize, width: u16, height: u16) -> Vec<Event> {
    synthetic_events_seeded(n, width, height, 0x5eed_cafe_f00d_d00d)
}

/// Seeded variant of [`synthetic_events`].
pub fn synthetic_events_seeded(n: usize, width: u16, height: u16, seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += rng.next_u64() & 3;
            Event {
                t,
                x: (rng.next_u64() % width as u64) as u16,
                y: (rng.next_u64() % height as u64) as u16,
                p: Polarity::from_bool(rng.next_u64() & 1 == 1),
            }
        })
        .collect()
}

/// A spatially skewed stream for adaptive-runtime tests and benches:
/// 90% of events land in the hot left band `[0, width/8)`, the rest
/// spread across the full canvas; timestamps ascend by 1 µs per event
/// (each pixel's stream is time-ordered — the fan-in precondition).
/// The uniform stripe cut is maximally wrong for this shape, which is
/// what the `skew` controller exists to fix.
pub fn hotspot_events_seeded(n: usize, width: u16, height: u16, seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    let hot = (width / 8).max(1);
    (0..n)
        .map(|i| {
            let x = if rng.next_u64() % 10 < 9 {
                (rng.next_u64() % u64::from(hot)) as u16
            } else {
                (rng.next_u64() % u64::from(width)) as u16
            };
            Event {
                t: i as u64,
                x,
                y: (rng.next_u64() % u64::from(height)) as u16,
                p: Polarity::from_bool(rng.next_u64() & 1 == 1),
            }
        })
        .collect()
}

/// A camera-like synthetic trace for copy/decode ablations: a few
/// bursty object hotspots drifting under a slow global pan, over a
/// floor of uniform sensor noise. Events arrive in µs-dense bursts
/// separated by quiet gaps — the texture a real sensor produces under
/// motion, which is what makes batch sizes and copy costs realistic.
/// Deterministic for a seed.
pub fn camera_trace_events_seeded(n: usize, width: u16, height: u16, seed: u64) -> Vec<Event> {
    const OBJECTS: usize = 4;
    let mut rng = SplitMix64::new(seed);
    let w = i64::from(width.max(1));
    let h = i64::from(height.max(1));
    let mut cx = [0i64; OBJECTS];
    let mut cy = [0i64; OBJECTS];
    let mut vx = [0i64; OBJECTS];
    let mut vy = [0i64; OBJECTS];
    for k in 0..OBJECTS {
        cx[k] = rng.next_below(w as u64) as i64;
        cy[k] = rng.next_below(h as u64) as i64;
        vx[k] = rng.next_below(3) as i64 - 1;
        vy[k] = rng.next_below(3) as i64 - 1;
    }
    let spread = (w.min(h) / 16).max(1);
    let mut pan = 0i64;
    let mut t = 0u64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        if i % 256 == 0 {
            // Between bursts: a quiet gap, the pan advances, and every
            // object drifts one step along its velocity.
            t += 50 + rng.next_below(200);
            pan += 1;
            for k in 0..OBJECTS {
                cx[k] += vx[k];
                cy[k] += vy[k];
            }
        } else {
            t += rng.next_below(2);
        }
        let (x, y) = if rng.next_bool(0.85) {
            let k = rng.next_below(OBJECTS as u64) as usize;
            let dx = rng.next_below(2 * spread as u64) as i64 - spread;
            let dy = rng.next_below(2 * spread as u64) as i64 - spread;
            (
                (cx[k] + pan + dx).rem_euclid(w) as u16,
                (cy[k] + dy).rem_euclid(h) as u16,
            )
        } else {
            (rng.next_below(w as u64) as u16, rng.next_below(h as u64) as u16)
        };
        out.push(Event { t, x, y, p: Polarity::from_bool(rng.next_u64() & 1 == 1) });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::{validate_stream, Resolution};

    #[test]
    fn synthetic_events_are_valid_and_deterministic() {
        let a = synthetic_events(1000, 346, 260);
        let b = synthetic_events(1000, 346, 260);
        assert_eq!(a, b);
        assert_eq!(validate_stream(&a, Resolution::new(346, 260)), None);
    }

    #[test]
    fn different_seeds_differ() {
        let a = synthetic_events_seeded(100, 64, 64, 1);
        let b = synthetic_events_seeded(100, 64, 64, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn hotspot_events_skew_left_and_stay_ordered() {
        let events = hotspot_events_seeded(10_000, 128, 64, 3);
        assert_eq!(validate_stream(&events, Resolution::new(128, 64)), None);
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
        let hot = events.iter().filter(|e| e.x < 16).count();
        // 90% targeted + ~12.5% of the uniform remainder ≈ 91%.
        assert!(hot as f64 > 0.85 * events.len() as f64, "hot band holds {hot}");
        // 1-wide canvases must not divide by zero.
        assert_eq!(hotspot_events_seeded(10, 1, 1, 1).len(), 10);
    }

    #[test]
    fn camera_trace_is_valid_bursty_and_clustered() {
        let events = camera_trace_events_seeded(20_000, 346, 260, 9);
        assert_eq!(events, camera_trace_events_seeded(20_000, 346, 260, 9));
        assert_eq!(validate_stream(&events, Resolution::new(346, 260)), None);
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
        // Bursty: inter-burst gaps dwarf the in-burst µs deltas.
        let max_gap =
            events.windows(2).map(|w| w[1].t - w[0].t).max().unwrap();
        assert!(max_gap >= 50, "expected quiet gaps, max delta {max_gap}");
        // Clustered: a 16-bin x histogram is far from uniform.
        let mut bins = [0usize; 16];
        for ev in &events {
            bins[(ev.x as usize * 16) / 346] += 1;
        }
        let peak = *bins.iter().max().unwrap();
        assert!(
            peak > 2 * events.len() / 16,
            "expected hotspots, flat histogram {bins:?}"
        );
        assert_eq!(camera_trace_events_seeded(10, 1, 1, 1).len(), 10);
    }
}
