//! Deterministic test/bench utilities: a seedable PRNG (no external
//! crates are available offline) and synthetic event stream generators.
//!
//! Also hosts a miniature property-testing harness ([`prop`]) used by the
//! invariant suites in `rust/tests/`.

pub mod prop;
pub mod rng;

pub use rng::SplitMix64;

use crate::aer::{Event, Polarity};

/// Generate `n` deterministic pseudo-random events within a
/// `width × height` sensor, timestamps increasing by 0–3 µs per event.
/// Deterministic across runs (fixed seed) so benches are comparable.
pub fn synthetic_events(n: usize, width: u16, height: u16) -> Vec<Event> {
    synthetic_events_seeded(n, width, height, 0x5eed_cafe_f00d_d00d)
}

/// Seeded variant of [`synthetic_events`].
pub fn synthetic_events_seeded(n: usize, width: u16, height: u16, seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += rng.next_u64() & 3;
            Event {
                t,
                x: (rng.next_u64() % width as u64) as u16,
                y: (rng.next_u64() % height as u64) as u16,
                p: Polarity::from_bool(rng.next_u64() & 1 == 1),
            }
        })
        .collect()
}

/// A spatially skewed stream for adaptive-runtime tests and benches:
/// 90% of events land in the hot left band `[0, width/8)`, the rest
/// spread across the full canvas; timestamps ascend by 1 µs per event
/// (each pixel's stream is time-ordered — the fan-in precondition).
/// The uniform stripe cut is maximally wrong for this shape, which is
/// what the `skew` controller exists to fix.
pub fn hotspot_events_seeded(n: usize, width: u16, height: u16, seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    let hot = (width / 8).max(1);
    (0..n)
        .map(|i| {
            let x = if rng.next_u64() % 10 < 9 {
                (rng.next_u64() % u64::from(hot)) as u16
            } else {
                (rng.next_u64() % u64::from(width)) as u16
            };
            Event {
                t: i as u64,
                x,
                y: (rng.next_u64() % u64::from(height)) as u16,
                p: Polarity::from_bool(rng.next_u64() & 1 == 1),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::{validate_stream, Resolution};

    #[test]
    fn synthetic_events_are_valid_and_deterministic() {
        let a = synthetic_events(1000, 346, 260);
        let b = synthetic_events(1000, 346, 260);
        assert_eq!(a, b);
        assert_eq!(validate_stream(&a, Resolution::new(346, 260)), None);
    }

    #[test]
    fn different_seeds_differ() {
        let a = synthetic_events_seeded(100, 64, 64, 1);
        let b = synthetic_events_seeded(100, 64, 64, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn hotspot_events_skew_left_and_stay_ordered() {
        let events = hotspot_events_seeded(10_000, 128, 64, 3);
        assert_eq!(validate_stream(&events, Resolution::new(128, 64)), None);
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
        let hot = events.iter().filter(|e| e.x < 16).count();
        // 90% targeted + ~12.5% of the uniform remainder ≈ 91%.
        assert!(hot as f64 > 0.85 * events.len() as f64, "hot band holds {hot}");
        // 1-wide canvases must not divide by zero.
        assert_eq!(hotspot_events_seeded(10, 1, 1, 1).len(), 10);
    }
}
