//! SplitMix64: a tiny, fast, well-distributed PRNG (Steele et al. 2014).
//! Used for synthetic event generation and the property-test harness —
//! the offline build has no `rand` crate, and determinism across runs is
//! a feature for benchmarking anyway.

/// SplitMix64 state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded constructor.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift (Lemire): unbiased enough for tests/benches.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_first_output() {
        // Reference value from the SplitMix64 reference implementation
        // with seed 0.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(37) < 37);
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        // Mean of U[0,1) over 10k draws: within 2% of 0.5.
        assert!((sum / 10_000.0 - 0.5).abs() < 0.01);
    }
}
