//! AEDAT 2.0 (jAER) — the oldest widely-deployed AER file format.
//!
//! An ASCII header of `#`-prefixed lines beginning `#!AER-DAT2.0`,
//! followed by **big-endian** 8-byte records:
//!
//! ```text
//! u32 address | u32 timestamp (µs)
//! ```
//!
//! with the DVS128/DAVIS address layout (jAER `ApsDvsEventExtractor`):
//! `bit 0 = polarity (1 = ON)`, `bits 1..11 = x`, `bits 12..22 = y`.
//! Timestamps are 32-bit with no overflow epoch (jAER wraps); like the
//! vendor tooling we reject longer streams at encode time.
//!
//! Completes the format matrix: jAER is one of the Table 1 libraries,
//! and its files are the bulk of older public DVS datasets.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::aer::{Event, Polarity, Resolution};

use super::EventCodec;

pub(super) const X_SHIFT: u32 = 1;
pub(super) const Y_SHIFT: u32 = 12;
pub(super) const COORD_MASK: u32 = 0x7FF; // 11 bits

/// The codec object.
pub struct Aedat2;

impl EventCodec for Aedat2 {
    fn name(&self) -> &'static str {
        "aedat2"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        write!(
            w,
            "#!AER-DAT2.0\r\n# This is a raw AE data file - do not edit\r\n\
             # Data format is int32 address, int32 timestamp (8 bytes total), repeated\r\n\
             # Timestamps tick is 1 us\r\n# Source: Davis346 [{}x{}]\r\n",
            res.width, res.height
        )?;
        let mut buf = Vec::with_capacity(8 * events.len());
        for ev in events {
            if ev.t > u32::MAX as u64 {
                bail!("aedat2: timestamp {} exceeds 32 bits", ev.t);
            }
            if ev.x > COORD_MASK as u16 || ev.y > COORD_MASK as u16 {
                bail!("aedat2: coordinate out of 11-bit range: {ev}");
            }
            let addr: u32 = (u32::from(ev.p.is_on()))
                | ((ev.x as u32) << X_SHIFT)
                | ((ev.y as u32) << Y_SHIFT);
            buf.extend_from_slice(&addr.to_be_bytes());
            buf.extend_from_slice(&(ev.t as u32).to_be_bytes());
        }
        w.write_all(&buf)?;
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        if !bytes.starts_with(b"#!AER-DAT2.0") {
            bail!("aedat2: missing #!AER-DAT2.0 signature");
        }
        // Header: consecutive lines starting with '#'.
        let mut off = 0usize;
        while off < bytes.len() && bytes[off] == b'#' {
            match bytes[off..].iter().position(|&b| b == b'\n') {
                Some(nl) => off += nl + 1,
                None => bail!("aedat2: unterminated header"),
            }
        }
        let header = String::from_utf8_lossy(&bytes[..off]).into_owned();
        let body = &bytes[off..];
        if body.len() % 8 != 0 {
            bail!("aedat2: body length {} not a multiple of 8", body.len());
        }
        let mut events = Vec::with_capacity(body.len() / 8);
        for rec in body.chunks_exact(8) {
            let addr = u32::from_be_bytes(rec[0..4].try_into().unwrap());
            let t = u32::from_be_bytes(rec[4..8].try_into().unwrap()) as u64;
            events.push(Event {
                t,
                x: ((addr >> X_SHIFT) & COORD_MASK) as u16,
                y: ((addr >> Y_SHIFT) & COORD_MASK) as u16,
                p: Polarity::from_bool(addr & 1 == 1),
            });
        }
        let res = parse_geometry(&header)
            .unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

/// Parse `[WxH]` out of a `# Source …` header line.
pub(super) fn parse_geometry(header: &str) -> Option<Resolution> {
    let line = header.lines().find(|l| l.contains("Source"))?;
    let open = line.rfind('[')?;
    let close = line.rfind(']')?;
    let (w, h) = line.get(open + 1..close)?.split_once('x')?;
    Some(Resolution::new(w.parse().ok()?, h.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(4000, 346, 260);
        let mut buf = Vec::new();
        Aedat2.encode(&events, Resolution::DAVIS_346, &mut buf).unwrap();
        let (decoded, res) = Aedat2.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::DAVIS_346);
    }

    #[test]
    fn records_are_big_endian() {
        let events = vec![Event::on(1, 0, 0x0102_0304)];
        let mut buf = Vec::new();
        Aedat2.encode(&events, Resolution::new(4, 4), &mut buf).unwrap();
        // Timestamp bytes appear MSB-first at the end of the record.
        assert_eq!(&buf[buf.len() - 4..], &[0x01, 0x02, 0x03, 0x04]);
    }

    #[test]
    fn rejects_oversized_values() {
        let mut buf = Vec::new();
        assert!(Aedat2
            .encode(&[Event::on(0, 0, 1 << 33)], Resolution::new(4, 4), &mut buf)
            .is_err());
        assert!(Aedat2
            .encode(&[Event::on(3000, 0, 0)], Resolution::new(4000, 4), &mut buf)
            .is_err());
    }

    #[test]
    fn rejects_truncation_and_bad_magic() {
        let events = synthetic_events(5, 64, 64);
        let mut buf = Vec::new();
        Aedat2.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(Aedat2.decode(&mut &buf[..]).is_err());
        assert!(Aedat2.decode(&mut &b"#!AER-DAT3.1\r\n"[..]).is_err());
    }
}
